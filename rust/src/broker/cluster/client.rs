//! `ClusterClient`: one [`crate::broker::StreamBroker`] handle over a
//! sharded multi-broker cluster.
//!
//! The client computes ownership locally from the shared [`ClusterSpec`]
//! (rendezvous hash — see [`super::placement`]) and routes every operation
//! to the broker that owns its `(topic, partition)`:
//!
//! - **Publishes** are bucketed per partition client-side (same FNV key
//!   hash as the broker's partitioner, round-robin for key-less records)
//!   and shipped as one partition-targeted `PublishTo` frame per owner —
//!   **pipelined** since PR 5: every bucket's frame is in flight on its
//!   owner's mux before any ack is awaited.
//! - **Fetches** run one long-poll per owning broker, merged through a
//!   small wakeup mux: the first shard with data wakes the caller, late
//!   results are stashed and drained by the next poll (nothing claimed is
//!   ever dropped).
//! - **Consumer groups** are scoped per broker under the hood — each
//!   member broker runs `GroupState` for the partitions it owns — while
//!   this client presents the paper's single-group illusion, merging the
//!   per-shard commit positions into one per-partition vector.
//! - **Failures** heal instead of surfacing mid-poll: wire operations
//!   retry with exponential backoff across broker restarts, `NotOwner`
//!   replies trigger a `ClusterMeta` refresh and a reroute, and a
//!   restarted broker that lost volatile state gets this client's topics
//!   re-ensured and groups re-joined automatically (durable members
//!   recover their shard from their own `--data-dir` and consumers resume
//!   from the committed offsets persisted there).
//!
//! Budgets (`max`/`max_bytes`) apply **per shard**: concurrent long-polls
//! cannot share one budget without a round of coordination, so a merged
//! fetch may return up to `owners × budget` records. Callers that need a
//! hard global cap re-slice locally (the ODS layer's caps are advisory).

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::broker::client::{BrokerClient, PendingPublish};
use crate::broker::embedded::{
    BrokerError, MultiFetch, Result, TopicStats, MAX_WAIT_HORIZON_MS,
};
use crate::broker::group::AssignmentMode;
use crate::broker::protocol::{error_from_code, Request, Response, ACKS_LEADER};
use crate::broker::record::{ProducerRecord, Record};
use crate::broker::topic::key_partition;
use crate::util::fault;

use super::placement::ClusterSpec;
use super::{relock, rread, rwrite};

/// First retry backoff after a transport failure.
const RETRY_BACKOFF_START: Duration = Duration::from_millis(25);
/// Backoff cap (doubling stops here).
const RETRY_BACKOFF_CAP: Duration = Duration::from_millis(1_600);
/// How long one cluster operation keeps retrying a broker before the
/// transport error surfaces — sized to ride out a broker restart.
const RETRY_WINDOW: Duration = Duration::from_secs(15);

/// One in-flight fetch identity: `(group, topic, member)`.
type MuxKey = (String, String, String);

/// Per-shard fetch results awaiting a caller, tagged with their broker.
type ShardResults = Vec<(String, MultiFetch)>;

/// The wakeup mux: per-key result mailbox shared by the per-broker
/// long-poll threads and the caller blocked in
/// [`ClusterClient::fetch_many_wait`]. Results that arrive after their
/// caller returned stay in `ready` and are drained by the next poll, so a
/// shard's claimed records are never dropped on the floor.
#[derive(Default)]
struct FetchMux {
    inner: Mutex<MuxInner>,
    cv: Condvar,
}

#[derive(Default)]
struct MuxInner {
    /// Delivering results, tagged with the shard they came from.
    ready: HashMap<MuxKey, ShardResults>,
    /// Terminal errors (unknown topic/group after self-heal failed).
    errors: HashMap<MuxKey, BrokerError>,
    /// Brokers with an outstanding long-poll per key (spawn guard).
    inflight: HashMap<MuxKey, HashSet<String>>,
}

impl FetchMux {
    /// Register an outstanding long-poll; `false` when one already runs.
    fn mark_inflight(&self, key: &MuxKey, addr: &str) -> bool {
        let mut inner = relock(&self.inner);
        inner.inflight.entry(key.clone()).or_default().insert(addr.to_string())
    }

    fn deliver(&self, key: &MuxKey, addr: &str, mf: MultiFetch) {
        if mf.batches.is_empty() {
            return; // positions were cached by the caller; nothing to wake for
        }
        let mut inner = relock(&self.inner);
        inner.ready.entry(key.clone()).or_default().push((addr.to_string(), mf));
        self.cv.notify_all();
    }

    fn fail(&self, key: &MuxKey, err: BrokerError) {
        let mut inner = relock(&self.inner);
        inner.errors.insert(key.clone(), err);
        self.cv.notify_all();
    }

    /// Drop the inflight mark (always called when a fetcher exits) and
    /// wake waiters so they can respawn or observe the expiry.
    fn finish(&self, key: &MuxKey, addr: &str) {
        let mut inner = relock(&self.inner);
        if let Some(set) = inner.inflight.get_mut(key) {
            set.remove(addr);
            if set.is_empty() {
                inner.inflight.remove(key);
            }
        }
        self.cv.notify_all();
    }

    fn take_ready(&self, key: &MuxKey) -> (ShardResults, Option<BrokerError>) {
        let mut inner = relock(&self.inner);
        (inner.ready.remove(key).unwrap_or_default(), inner.errors.remove(key))
    }

    /// True while any fetcher still has an outstanding long-poll for `key`.
    fn any_inflight(&self, key: &MuxKey) -> bool {
        relock(&self.inner).inflight.get(key).is_some_and(|s| !s.is_empty())
    }

    /// Park until something happens for `key` (result, error, fetcher
    /// exit) or `timeout` elapses.
    fn wait(&self, key: &MuxKey, timeout: Duration) {
        let inner = relock(&self.inner);
        let has_news = inner.ready.get(key).is_some_and(|v| !v.is_empty())
            || inner.errors.contains_key(key);
        if !has_news {
            // Poison-tolerant like every cluster lock: a panicked fetcher
            // must degrade this wait, not crash the consumer.
            let _ = self.cv.wait_timeout(inner, timeout).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// State shared between the client facade and its fetcher threads.
struct Shared {
    spec: RwLock<ClusterSpec>,
    /// One pooled [`BrokerClient`] per member — each pools one data + one
    /// long-poll connection internally.
    conns: Mutex<HashMap<String, Arc<BrokerClient>>>,
    /// topic → partition count (learned from `ensure_topic`/first lookup;
    /// the basis for client-side routing and self-healing re-ensures).
    topics: Mutex<HashMap<String, usize>>,
    /// Joins issued through this client, replayed onto brokers that lost
    /// volatile group state in a restart.
    registrations: Mutex<HashMap<MuxKey, AssignmentMode>>,
    /// (group, topic) → merged per-partition `(position, committed)` —
    /// each shard's owner is authoritative for its partitions.
    positions: Mutex<HashMap<(String, String), Vec<(u64, u64)>>>,
    mux: FetchMux,
    /// Round-robin cursor for key-less publishes.
    rr: AtomicU64,
    /// Failover routing (PR 7): `(topic, partition)` → the follower this
    /// client promoted (or was redirected to) after the static owner died.
    /// Consulted before the spec on every leader resolution.
    overrides: Mutex<HashMap<(String, usize), String>>,
    /// Acknowledgement level stamped on partition-targeted publishes
    /// ([`crate::broker::protocol::ACKS_LEADER`] /
    /// [`crate::broker::protocol::ACKS_QUORUM`]).
    acks: AtomicU8,
}

impl Shared {
    fn client(&self, addr: &str) -> Result<Arc<BrokerClient>> {
        // Fault seam: a scripted partition between this client and `addr` —
        // checked before the connection cache so it covers every call, not
        // just fresh connects.
        if fault::active() && fault::check(fault::site::CLUSTER_CONNECT, addr).is_some() {
            return Err(BrokerError::Transport(format!("injected partition to {addr}")));
        }
        if let Some(c) = relock(&self.conns).get(addr) {
            return Ok(Arc::clone(c));
        }
        let c = Arc::new(BrokerClient::connect(addr)?);
        relock(&self.conns).insert(addr.to_string(), Arc::clone(&c));
        Ok(c)
    }

    fn invalidate(&self, addr: &str) {
        relock(&self.conns).remove(addr);
    }

    fn members(&self) -> Vec<String> {
        rread(&self.spec).members().to_vec()
    }

    fn owner(&self, topic: &str, partition: usize) -> String {
        rread(&self.spec).owner(topic, partition).to_string()
    }

    /// The cluster's replication factor (failover only engages above 1).
    fn replication(&self) -> usize {
        rread(&self.spec).replication()
    }

    /// Current leader for `(topic, partition)`: a failover override wins,
    /// otherwise the static placement owner.
    fn leader_for(&self, topic: &str, partition: usize) -> String {
        if let Some(a) = relock(&self.overrides).get(&(topic.to_string(), partition)) {
            return a.clone();
        }
        self.owner(topic, partition)
    }

    fn set_override(&self, topic: &str, partition: usize, addr: &str) {
        relock(&self.overrides).insert((topic.to_string(), partition), addr.to_string());
    }

    /// Partitions of `topic` grouped by their *current* leader (overrides
    /// applied) — the failover-aware counterpart of `spec.owners`.
    fn leader_groups(&self, topic: &str, parts: usize) -> Vec<(String, Vec<usize>)> {
        let mut out: Vec<(String, Vec<usize>)> = Vec::new();
        for p in 0..parts {
            let addr = self.leader_for(topic, p);
            match out.iter_mut().find(|(a, _)| *a == addr) {
                Some((_, ps)) => ps.push(p),
                None => out.push((addr, vec![p])),
            }
        }
        out
    }

    /// Replica brokers that may hold data for `ps` besides `dead` — the
    /// candidates a read consults when a leader is unreachable.
    fn read_candidates(&self, topic: &str, ps: &[usize], dead: &str) -> Vec<String> {
        let spec = rread(&self.spec);
        let mut out: Vec<String> = Vec::new();
        for &p in ps {
            for r in spec.replicas(topic, p) {
                if r != dead && !out.iter().any(|o| o == r) {
                    out.push(r.to_string());
                }
            }
        }
        out
    }

    /// One operation against one broker, retried with exponential backoff
    /// across transport failures (broker restarts) for [`RETRY_WINDOW`].
    fn with_broker<T>(
        &self,
        addr: &str,
        op: impl Fn(&BrokerClient) -> Result<T>,
    ) -> Result<T> {
        let deadline = Instant::now() + RETRY_WINDOW;
        let mut backoff = RETRY_BACKOFF_START;
        loop {
            match self.client(addr).and_then(|c| op(&c)) {
                Err(BrokerError::Transport(e)) => {
                    self.invalidate(addr);
                    if Instant::now() + backoff > deadline {
                        return Err(BrokerError::Transport(format!("{addr}: {e}")));
                    }
                    crate::obs_counter!("cluster.client.retries").inc();
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(RETRY_BACKOFF_CAP);
                }
                other => return other,
            }
        }
    }

    /// Adopt a fresher member list, asking `prefer` first (usually the
    /// broker that just answered `NotOwner`).
    fn refresh_meta(&self, prefer: &str) {
        let mut candidates = vec![prefer.to_string()];
        candidates.extend(self.members().into_iter().filter(|m| m != prefer));
        for addr in candidates {
            let Ok(client) = self.client(&addr) else { continue };
            let Ok(wire) = client.cluster_meta() else {
                self.invalidate(&addr);
                continue;
            };
            if wire.members.is_empty() {
                continue; // broker not running in cluster mode
            }
            let fresh = ClusterSpec::from_wire(&wire);
            let mut spec = rwrite(&self.spec);
            if fresh.epoch > spec.epoch
                || (fresh.epoch == spec.epoch && fresh.members() != spec.members())
            {
                log::info!(
                    "cluster meta refresh from {addr}: {} members, epoch {}",
                    fresh.len(),
                    fresh.epoch
                );
                *spec = fresh;
            }
            return;
        }
    }

    /// Replay this client's joins for `(group, topic)` on one broker (a
    /// restart drops volatile group membership; cursors are recovered from
    /// the shard's offset journal). `true` when at least one join landed.
    fn rejoin_on(&self, addr: &str, group: &str, topic: &str) -> bool {
        let ours: Vec<(String, AssignmentMode)> = relock(&self.registrations)
            .iter()
            .filter(|((g, t, _), _)| g == group && t == topic)
            .map(|((_, _, m), &mode)| (m.clone(), mode))
            .collect();
        let mut any = false;
        for (member, mode) in ours {
            if self
                .client(addr)
                .and_then(|c| c.join_group(group, topic, &member, mode))
                .is_ok()
            {
                any = true;
            }
        }
        any
    }

    /// Re-create a known topic on one broker (a restarted memory-mode
    /// member lost it; durable members recover their own shard).
    fn reensure_on(&self, addr: &str, topic: &str) -> bool {
        let Some(parts) = relock(&self.topics).get(topic).copied() else {
            return false;
        };
        self.client(addr).and_then(|c| c.ensure_topic(topic, parts)).is_ok()
    }

    /// Fold one shard's cursor positions into the merged view — the shard
    /// owner is authoritative for exactly its partitions.
    fn note_positions(&self, group: &str, topic: &str, addr: &str, mf: &MultiFetch) {
        // Leader-aware (PR 7): after a failover the promoted follower is
        // authoritative for the partitions it took over.
        let leaders: Vec<String> =
            (0..mf.positions.len()).map(|p| self.leader_for(topic, p)).collect();
        let mut cache = relock(&self.positions);
        let entry = cache.entry((group.to_string(), topic.to_string())).or_default();
        if entry.len() < mf.positions.len() {
            entry.resize(mf.positions.len(), (0, 0));
        }
        for (p, &pos) in mf.positions.iter().enumerate() {
            if leaders[p] == addr {
                entry[p] = pos;
            }
        }
    }

    fn merged_positions(&self, group: &str, topic: &str, parts: usize) -> Vec<(u64, u64)> {
        let cache = relock(&self.positions);
        let mut out = cache
            .get(&(group.to_string(), topic.to_string()))
            .cloned()
            .unwrap_or_default();
        out.resize(parts.max(out.len()), (0, 0));
        out
    }
}

/// Client-side handle to a sharded broker cluster. Same surface as
/// [`BrokerClient`] (both implement [`crate::broker::StreamBroker`]), so
/// the DistroStream layer is backend-count agnostic.
pub struct ClusterClient {
    shared: Arc<Shared>,
}

/// One pipelined per-partition bucket of a [`ClusterClient::publish_batch`]
/// awaiting its ack (submission order preserved by the wait loop).
struct InflightBucket {
    partition: usize,
    /// Positions of this bucket's records in the caller's batch.
    indices: Vec<usize>,
    /// The records, retained for the healing fallback path.
    batch: Vec<ProducerRecord>,
    pending: Result<PendingPublish>,
}

impl ClusterClient {
    /// Connect to a cluster described by a static seed list. At least one
    /// seed must be reachable; the reachable seed's own member list is
    /// adopted so a partial seed list self-corrects immediately.
    pub fn connect<S: AsRef<str>>(seeds: &[S]) -> Result<Self> {
        let spec = ClusterSpec::new(seeds.iter().map(|s| s.as_ref().to_string()));
        if spec.is_empty() {
            return Err(BrokerError::Transport("empty cluster seed list".into()));
        }
        let shared = Arc::new(Shared {
            spec: RwLock::new(spec),
            conns: Mutex::new(HashMap::new()),
            topics: Mutex::new(HashMap::new()),
            registrations: Mutex::new(HashMap::new()),
            positions: Mutex::new(HashMap::new()),
            mux: FetchMux::default(),
            rr: AtomicU64::new(0),
            overrides: Mutex::new(HashMap::new()),
            acks: AtomicU8::new(ACKS_LEADER),
        });
        let members = shared.members();
        let mut reachable: Option<String> = None;
        for addr in &members {
            match shared.client(addr) {
                Ok(c) if c.ping().is_ok() => {
                    reachable = Some(addr.clone());
                    break;
                }
                _ => shared.invalidate(addr),
            }
        }
        let Some(first) = reachable else {
            return Err(BrokerError::Transport(format!(
                "no cluster seed reachable ({} tried)",
                members.len()
            )));
        };
        shared.refresh_meta(&first);
        Ok(Self { shared })
    }

    /// The current (possibly refreshed) member list.
    pub fn members(&self) -> Vec<String> {
        self.shared.members()
    }

    /// Snapshot of the active cluster spec.
    pub fn spec(&self) -> ClusterSpec {
        rread(&self.shared.spec).clone()
    }

    /// Set the acknowledgement level for subsequent publishes:
    /// [`crate::broker::protocol::ACKS_LEADER`] (default — leader append
    /// acks) or [`crate::broker::protocol::ACKS_QUORUM`] (leader holds the
    /// ack until every in-sync follower confirms the batch).
    pub fn set_acks(&self, acks: u8) {
        self.shared.acks.store(acks, Ordering::Relaxed);
    }

    // ---- routing helpers -------------------------------------------------

    /// Partition count used for routing `topic` (learned at ensure/create
    /// time, or looked up from any member for pre-existing topics).
    fn partitions_of(&self, topic: &str) -> Result<usize> {
        if let Some(n) = relock(&self.shared.topics).get(topic).copied() {
            return Ok(n);
        }
        let mut last_err = BrokerError::UnknownTopic(topic.into());
        for addr in self.shared.members() {
            match self.shared.with_broker(&addr, |c| c.offsets(topic)) {
                Ok(os) => {
                    let n = os.len().max(1);
                    relock(&self.shared.topics).insert(topic.to_string(), n);
                    return Ok(n);
                }
                Err(e) => last_err = e,
            }
        }
        Err(last_err)
    }

    /// Pick a partition for one producer record: the broker partitioner's
    /// FNV key hash for keyed records (so cluster and single-broker
    /// deployments agree), round-robin otherwise.
    fn route(&self, rec: &ProducerRecord, parts: usize) -> usize {
        match &rec.key {
            Some(k) => key_partition(&k.0, parts),
            None => self.shared.rr.fetch_add(1, Ordering::Relaxed) as usize % parts.max(1),
        }
    }

    /// Ship one partition's batch to its current leader, rerouting on
    /// `NotOwner` (stale spec or fenced leader → refresh + follow the
    /// redirect), re-ensuring the topic on members that lost it in a
    /// restart, and — on replicated clusters — **failing over** to the
    /// most-caught-up follower when the leader is unreachable.
    fn publish_partition(
        &self,
        topic: &str,
        partition: usize,
        recs: Vec<ProducerRecord>,
    ) -> Result<Vec<u64>> {
        let acks = self.shared.acks.load(Ordering::Relaxed);
        let replicated = self.shared.replication() > 1;
        let mut target = self.shared.leader_for(topic, partition);
        let mut reroutes = 0;
        loop {
            // Replicated clusters take a single transport attempt per
            // target: promotion of a live follower must beat the
            // ride-out-a-restart retry window, which stays the (only)
            // healing strategy when there is no replica to promote.
            let res = if replicated {
                self.shared.client(&target).and_then(|c| {
                    match c.rpc_once(Request::PublishTo {
                        topic: topic.to_string(),
                        partition,
                        recs: recs.clone(),
                        acks,
                    })? {
                        Response::PubBatchAck { acks } => {
                            Ok(acks.into_iter().map(|(_, o)| o).collect())
                        }
                        Response::Err { code, msg } => Err(error_from_code(code, msg)),
                        other => Err(BrokerError::Transport(format!(
                            "unexpected publish reply {other:?}"
                        ))),
                    }
                })
            } else {
                self.shared
                    .with_broker(&target, |c| c.publish_to(topic, partition, recs.clone(), acks))
            };
            match res {
                Ok(offsets) => return Ok(offsets),
                Err(BrokerError::NotOwner { owner }) if reroutes < 4 => {
                    reroutes += 1;
                    crate::obs_counter!("cluster.client.reroutes").inc();
                    self.shared.refresh_meta(&target);
                    target = if owner.is_empty() {
                        self.shared.leader_for(topic, partition)
                    } else {
                        // A fenced ex-leader redirects to the broker that
                        // deposed it — remember the promotion.
                        if replicated {
                            self.shared.set_override(topic, partition, &owner);
                        }
                        owner
                    };
                }
                Err(BrokerError::UnknownTopic(t)) if reroutes < 4 => {
                    reroutes += 1;
                    if !self.shared.reensure_on(&target, topic) {
                        return Err(BrokerError::UnknownTopic(t));
                    }
                }
                Err(BrokerError::Transport(e)) if replicated && reroutes < 4 => {
                    reroutes += 1;
                    self.shared.invalidate(&target);
                    match self.fail_over(topic, partition, &target) {
                        Some(next) => target = next,
                        None => return Err(BrokerError::Transport(e)),
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Leader failover (PR 7): probe the partition's surviving replicas,
    /// promote the one with the highest high-watermark (most in-sync) and
    /// remember it as the partition's leader. Returns the promoted address,
    /// or `None` when no replica answered.
    fn fail_over(&self, topic: &str, partition: usize, dead: &str) -> Option<String> {
        let candidates: Vec<String> = {
            let spec = rread(&self.shared.spec);
            spec.replicas(topic, partition).into_iter().map(|s| s.to_string()).collect()
        };
        let mut best: Option<(String, u64)> = None;
        for addr in candidates.iter().filter(|a| a.as_str() != dead) {
            match self.probe_hw(addr, topic, partition) {
                Ok(hw) => {
                    let better = match &best {
                        Some((_, b)) => hw > *b,
                        None => true,
                    };
                    if better {
                        best = Some((addr.clone(), hw));
                    }
                }
                // Live broker that lost the topic (memory-mode restart):
                // promotable, but only if nothing better answers.
                Err(BrokerError::UnknownTopic(_)) => {
                    if best.is_none() {
                        best = Some((addr.clone(), 0));
                    }
                }
                Err(_) => self.shared.invalidate(addr),
            }
        }
        let (addr, hw) = best?;
        let parts = self.partitions_of(topic).ok()?;
        let c = self.shared.client(&addr).ok()?;
        match c.promote(topic, partition, parts) {
            Ok(epoch) => {
                log::warn!(
                    "failover: promoted {addr} (hw {hw}) to lead {topic}[{partition}] \
                     at epoch {epoch} after losing {dead}"
                );
                crate::obs_counter!("cluster.client.failovers").inc();
                self.shared.set_override(topic, partition, &addr);
                Some(addr)
            }
            Err(e) => {
                log::warn!("failover: promote of {addr} for {topic}[{partition}] failed: {e}");
                self.shared.invalidate(&addr);
                None
            }
        }
    }

    /// Single-attempt liveness + catch-up probe: `addr`'s high watermark
    /// for `(topic, partition)`.
    fn probe_hw(&self, addr: &str, topic: &str, partition: usize) -> Result<u64> {
        let c = self.shared.client(addr)?;
        match c.rpc_once(Request::Offsets { topic: topic.to_string() })? {
            Response::OffsetList(os) => Ok(os.get(partition).map(|&(_, hw)| hw).unwrap_or(0)),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected offsets reply {other:?}"))),
        }
    }

    /// One group-scoped call against one broker, self-healing missing
    /// topics (re-ensure) and dropped group membership (re-join) once.
    fn call_healed<T>(
        &self,
        addr: &str,
        group: &str,
        topic: &str,
        op: impl Fn(&BrokerClient) -> Result<T>,
    ) -> Result<T> {
        match self.shared.with_broker(addr, |c| op(c)) {
            Err(BrokerError::UnknownTopic(t)) => {
                if self.shared.reensure_on(addr, topic) {
                    self.shared.with_broker(addr, |c| op(c))
                } else {
                    Err(BrokerError::UnknownTopic(t))
                }
            }
            Err(BrokerError::UnknownGroup(g)) => {
                if self.shared.rejoin_on(addr, group, topic) {
                    self.shared.with_broker(addr, |c| op(c))
                } else {
                    Err(BrokerError::UnknownGroup(g))
                }
            }
            Err(BrokerError::UnknownMember { group: g, member: m }) => {
                if self.shared.rejoin_on(addr, group, topic) {
                    self.shared.with_broker(addr, |c| op(c))
                } else {
                    Err(BrokerError::UnknownMember { group: g, member: m })
                }
            }
            other => other,
        }
    }

    // ---- public API (mirrors BrokerClient) -------------------------------

    /// True when at least one member answers.
    pub fn ping(&self) -> Result<()> {
        let mut last = BrokerError::Transport("empty cluster".into());
        for addr in self.shared.members() {
            match self.shared.client(&addr).and_then(|c| c.ping()) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    self.shared.invalidate(&addr);
                    last = e;
                }
            }
        }
        Err(last)
    }

    /// Create on every member. The owner of partition 0 is the
    /// coordination point: it keeps the exactly-one-winner `TopicExists`
    /// guarantee; the rest are ensured.
    pub fn create_topic(&self, name: &str, partitions: usize) -> Result<()> {
        let coordinator = self.shared.owner(name, 0);
        self.shared.with_broker(&coordinator, |c| c.create_topic(name, partitions))?;
        for addr in self.shared.members() {
            if addr != coordinator {
                self.shared.with_broker(&addr, |c| c.ensure_topic(name, partitions))?;
            }
        }
        relock(&self.shared.topics).insert(name.to_string(), partitions);
        Ok(())
    }

    /// Ensure on every member (cluster topics exist everywhere; data only
    /// lands on owned partitions).
    pub fn ensure_topic(&self, name: &str, partitions: usize) -> Result<()> {
        let mut reached = false;
        let mut last = BrokerError::Transport("empty cluster".into());
        for addr in self.shared.members() {
            match self.shared.with_broker(&addr, |c| c.ensure_topic(name, partitions)) {
                Ok(()) => reached = true,
                // A dead member of a replicated cluster picks the topic up
                // later through the re-ensure self-heal.
                Err(BrokerError::Transport(e)) if self.shared.replication() > 1 => {
                    self.shared.invalidate(&addr);
                    log::warn!("ensure_topic skipping unreachable {addr}: {e}");
                    last = BrokerError::Transport(e);
                }
                Err(e) => return Err(e),
            }
        }
        if !reached {
            return Err(last);
        }
        relock(&self.shared.topics).insert(name.to_string(), partitions);
        Ok(())
    }

    pub fn delete_topic(&self, name: &str) -> Result<()> {
        relock(&self.shared.topics).remove(name);
        relock(&self.shared.positions).retain(|(_, t), _| t != name);
        let mut found = false;
        for addr in self.shared.members() {
            match self.shared.with_broker(&addr, |c| c.delete_topic(name)) {
                Ok(()) => found = true,
                Err(BrokerError::UnknownTopic(_)) => {}
                Err(e) => return Err(e),
            }
        }
        if found {
            Ok(())
        } else {
            Err(BrokerError::UnknownTopic(name.into()))
        }
    }

    /// Union of every reachable member's topics.
    pub fn topic_names(&self) -> Result<Vec<String>> {
        let mut all: Vec<String> = Vec::new();
        let mut reached = false;
        let mut last = BrokerError::Transport("empty cluster".into());
        for addr in self.shared.members() {
            match self.shared.with_broker(&addr, |c| c.topic_names()) {
                Ok(names) => {
                    reached = true;
                    all.extend(names);
                }
                Err(e) => last = e,
            }
        }
        if !reached {
            return Err(last);
        }
        all.sort();
        all.dedup();
        Ok(all)
    }

    /// Cluster-wide stats: per-partition watermarks from each partition's
    /// owner, totals summed across shards. (`segments` includes each
    /// member's empty non-owned partition segments on durable topics.)
    pub fn topic_stats(&self, name: &str) -> Result<TopicStats> {
        let parts = self.partitions_of(name)?;
        let owners = rread(&self.shared.spec).owners(name, parts);
        let mut out = TopicStats {
            partitions: parts,
            records: 0,
            bytes: 0,
            high_watermarks: vec![0; parts],
            start_offsets: vec![0; parts],
            bytes_on_disk: 0,
            segments: 0,
            recovered_records: 0,
        };
        for (addr, ps) in owners {
            let s = self.shared.with_broker(&addr, |c| c.topic_stats(name))?;
            out.records += s.records;
            out.bytes += s.bytes;
            out.bytes_on_disk += s.bytes_on_disk;
            out.segments += s.segments;
            out.recovered_records += s.recovered_records;
            for p in ps {
                if p < s.high_watermarks.len() {
                    out.high_watermarks[p] = s.high_watermarks[p];
                    out.start_offsets[p] = s.start_offsets[p];
                }
            }
        }
        Ok(out)
    }

    pub fn publish(&self, topic: &str, rec: ProducerRecord) -> Result<(usize, u64)> {
        let parts = self.partitions_of(topic)?;
        let partition = self.route(&rec, parts);
        let offsets = self.publish_partition(topic, partition, vec![rec])?;
        let offset = offsets.first().copied().ok_or_else(|| {
            BrokerError::Transport("publish ack missing offset".into())
        })?;
        Ok((partition, offset))
    }

    /// Bucket per partition, ship one `PublishTo` frame per bucket to its
    /// owner; acks return in submission order.
    ///
    /// PR 5: the buckets are **pipelined** — every frame is submitted on
    /// its owner's mux before any ack is awaited, so a multi-shard batch
    /// costs the slowest owner's round trip instead of the sum over
    /// buckets (and buckets sharing one owner ride the same in-flight
    /// window). A bucket whose fast-path submit fails (stale owner, lost
    /// topic, broker restart) falls back to the fully-healed sequential
    /// path for just that bucket.
    pub fn publish_batch(
        &self,
        topic: &str,
        recs: Vec<ProducerRecord>,
    ) -> Result<Vec<(usize, u64)>> {
        if recs.is_empty() {
            return Ok(Vec::new());
        }
        let parts = self.partitions_of(topic)?;
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); parts];
        for (i, rec) in recs.iter().enumerate() {
            buckets[self.route(rec, parts)].push(i);
        }
        let mut slots: Vec<Option<ProducerRecord>> = recs.into_iter().map(Some).collect();
        let mut acks = vec![(0usize, 0u64); slots.len()];
        let mut inflight: Vec<InflightBucket> = Vec::new();
        for (p, bucket) in buckets.iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let batch: Vec<ProducerRecord> = bucket
                .iter()
                .map(|&i| slots[i].take().expect("record consumed twice"))
                .collect();
            let target = self.shared.leader_for(topic, p);
            let acks = self.shared.acks.load(Ordering::Relaxed);
            // The batch is kept (record clones are Arc-cheap) so a failed
            // fast path can be replayed through the healing slow path.
            let pending = self
                .shared
                .client(&target)
                .map(|c| c.publish_to_submit(topic, p, batch.clone(), acks));
            inflight.push(InflightBucket { partition: p, indices: bucket.clone(), batch, pending });
        }
        for ib in inflight {
            let offsets = match ib.pending.and_then(|pending| pending.wait()) {
                Ok(offsets) => offsets,
                // Reroute/heal (NotOwner refresh, re-ensure, reconnect
                // windows) — at-least-once like every transport retry here:
                // an acked-but-unconfirmed fast path may duplicate records,
                // never lose them.
                Err(_) => self.publish_partition(topic, ib.partition, ib.batch)?,
            };
            for (&i, off) in ib.indices.iter().zip(offsets) {
                acks[i] = (ib.partition, off);
            }
        }
        Ok(acks)
    }

    /// Join on every member (the single-group illusion over per-broker
    /// `GroupState`s); remembered for self-healing re-joins after member
    /// restarts. Returns the highest per-shard generation.
    pub fn join_group(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        mode: AssignmentMode,
    ) -> Result<u64> {
        relock(&self.shared.registrations)
            .insert((group.into(), topic.into(), member.into()), mode);
        let mut generation = 0;
        let mut reached = false;
        for addr in self.shared.members() {
            match self.call_healed(&addr, group, topic, |c| {
                c.join_group(group, topic, member, mode)
            }) {
                Ok(g) => {
                    reached = true;
                    generation = generation.max(g);
                }
                // Replicated clusters tolerate a dead member: its
                // partitions' survivors carry the group, and the
                // registration replays when it rejoins.
                Err(BrokerError::Transport(e)) if self.shared.replication() > 1 => {
                    self.shared.invalidate(&addr);
                    log::warn!("join_group skipping unreachable {addr}: {e}");
                }
                Err(e) => return Err(e),
            }
        }
        if !reached {
            return Err(BrokerError::Transport("no cluster member reachable".into()));
        }
        Ok(generation)
    }

    pub fn leave_group(&self, group: &str, topic: &str, member: &str) -> Result<bool> {
        relock(&self.shared.registrations)
            .remove(&(group.to_string(), topic.to_string(), member.to_string()));
        let mut left = false;
        for addr in self.shared.members() {
            match self.shared.with_broker(&addr, |c| c.leave_group(group, topic, member)) {
                Ok(b) => left |= b,
                Err(BrokerError::UnknownGroup(_)) | Err(BrokerError::UnknownMember { .. }) => {}
                Err(BrokerError::Transport(e)) if self.shared.replication() > 1 => {
                    self.shared.invalidate(&addr);
                    log::warn!("leave_group skipping unreachable {addr}: {e}");
                }
                Err(e) => return Err(e),
            }
        }
        Ok(left)
    }

    pub fn poll(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
    ) -> Result<Vec<Arc<Record>>> {
        let mf = self.fetch_many(group, topic, member, max, usize::MAX)?;
        Ok(mf.batches.into_iter().flat_map(|(_, recs)| recs).collect())
    }

    pub fn fetch_many(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
    ) -> Result<MultiFetch> {
        self.fetch_many_wait(group, topic, member, max, max_bytes, 0)
    }

    /// The scale-out long poll: one blocking fetch per owning broker,
    /// merged through the wakeup mux — the first shard with data wakes the
    /// caller; results from slower shards are stashed for the next poll.
    pub fn fetch_many_wait(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
        wait_ms: u64,
    ) -> Result<MultiFetch> {
        let parts = self.partitions_of(topic)?;
        let key: MuxKey = (group.to_string(), topic.to_string(), member.to_string());
        if wait_ms == 0 {
            return self.sweep(&key, parts, max, max_bytes);
        }
        let wait_ms = wait_ms.min(MAX_WAIT_HORIZON_MS);
        let deadline = Instant::now() + Duration::from_millis(wait_ms);
        loop {
            let (ready, err) = self.shared.mux.take_ready(&key);
            if !ready.is_empty() {
                return Ok(self.merge(&key, parts, ready));
            }
            if let Some(e) = err {
                return Err(e);
            }
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                // Deadline passed. A fetcher may have claimed records at
                // the buzzer: wait (briefly, bounded) until either data
                // lands or no fetcher is in flight any more, so records a
                // shard already claimed are returned rather than stranded
                // in the mux — the caller may never poll this key again
                // (the canonical "empty + closed" consumer exit).
                let grace = Instant::now() + Duration::from_millis(25);
                loop {
                    let (ready, err) = self.shared.mux.take_ready(&key);
                    if !ready.is_empty() {
                        return Ok(self.merge(&key, parts, ready));
                    }
                    if let Some(e) = err {
                        return Err(e);
                    }
                    let Some(left) = grace.checked_duration_since(Instant::now()) else {
                        break;
                    };
                    if !self.shared.mux.any_inflight(&key) {
                        break;
                    }
                    self.shared.mux.wait(&key, left.min(Duration::from_millis(5)));
                }
                return Ok(MultiFetch {
                    batches: Vec::new(),
                    positions: self.shared.merged_positions(group, topic, parts),
                });
            };
            self.spawn_fetchers(&key, parts, max, max_bytes, remaining);
            self.shared.mux.wait(&key, remaining.min(Duration::from_millis(250)));
        }
    }

    /// Like [`ClusterClient::call_healed`] but with a **single** transport
    /// attempt per shard (plus the one-shot self-heal retries): the
    /// non-blocking sweep must not stack the cluster-level retry window on
    /// top of `BrokerClient`'s own reconnect window for an unreachable
    /// member. A member that is fully down fails the TCP connect fast and
    /// gets skipped; an in-place restart is still ridden out by the
    /// established socket's reconnect loop.
    fn call_once<T>(
        &self,
        addr: &str,
        group: &str,
        topic: &str,
        op: impl Fn(&BrokerClient) -> Result<T>,
    ) -> Result<T> {
        match self.shared.client(addr).and_then(|c| op(&c)) {
            Err(BrokerError::UnknownTopic(t)) => {
                if self.shared.reensure_on(addr, topic) {
                    self.shared.client(addr).and_then(|c| op(&c))
                } else {
                    Err(BrokerError::UnknownTopic(t))
                }
            }
            Err(BrokerError::UnknownGroup(_)) | Err(BrokerError::UnknownMember { .. })
                if self.shared.rejoin_on(addr, group, topic) =>
            {
                self.shared.client(addr).and_then(|c| op(&c))
            }
            other => other,
        }
    }

    /// Non-blocking sweep (`wait_ms == 0`): drain any prefetched mux
    /// results, else one fetch attempt per leading broker with the
    /// remaining budgets. An unreachable leader is skipped, not fatal —
    /// and on replicated clusters its partitions' followers are consulted
    /// in its place, so a dead leader never makes replicated partitions
    /// invisible to wait-0 polls.
    fn sweep(
        &self,
        key: &MuxKey,
        parts: usize,
        max: usize,
        max_bytes: usize,
    ) -> Result<MultiFetch> {
        let (group, topic, member) = (key.0.as_str(), key.1.as_str(), key.2.as_str());
        let (mut results, err) = self.shared.mux.take_ready(key);
        if results.is_empty() {
            if let Some(e) = err {
                return Err(e);
            }
            let leaders = self.shared.leader_groups(topic, parts);
            let mut got = 0usize;
            let mut got_bytes = 0usize;
            for (addr, ps) in leaders {
                if got >= max || got_bytes >= max_bytes {
                    break;
                }
                let (rmax, rbytes) = (max - got, max_bytes - got_bytes);
                match self.call_once(&addr, group, topic, |c| {
                    c.fetch_many(group, topic, member, rmax, rbytes)
                }) {
                    Ok(mf) => {
                        got += mf.record_count();
                        got_bytes = got_bytes.saturating_add(mf.byte_count());
                        results.push((addr, mf));
                    }
                    Err(BrokerError::Transport(e)) => {
                        self.shared.invalidate(&addr);
                        let mut healed = false;
                        if self.shared.replication() > 1 {
                            // Consult the dead leader's followers: they
                            // carry replicated copies of its partitions, so
                            // the sweep still surfaces their records.
                            for alt in self.shared.read_candidates(topic, &ps, &addr) {
                                match self.call_once(&alt, group, topic, |c| {
                                    c.fetch_many(group, topic, member, rmax, rbytes)
                                }) {
                                    Ok(mf) => {
                                        got += mf.record_count();
                                        got_bytes = got_bytes.saturating_add(mf.byte_count());
                                        results.push((alt, mf));
                                        healed = true;
                                        break;
                                    }
                                    Err(_) => self.shared.invalidate(&alt),
                                }
                            }
                        }
                        if !healed {
                            // Skip this shard for this sweep; the records
                            // stay on the broker and the next poll retries.
                            log::warn!("cluster sweep skipping {addr}: {e}");
                        }
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        Ok(self.merge(key, parts, results))
    }

    fn merge(&self, key: &MuxKey, parts: usize, results: ShardResults) -> MultiFetch {
        let mut map: BTreeMap<usize, Vec<Arc<Record>>> = BTreeMap::new();
        for (addr, mf) in results {
            self.shared.note_positions(&key.0, &key.1, &addr, &mf);
            for (p, recs) in mf.batches {
                map.entry(p).or_default().extend(recs);
            }
        }
        MultiFetch {
            batches: map.into_iter().collect(),
            positions: self.shared.merged_positions(&key.0, &key.1, parts),
        }
    }

    /// Ensure one long-poll fetcher thread per owning broker is in flight
    /// for this key (the spawn is skipped while one still runs).
    fn spawn_fetchers(
        &self,
        key: &MuxKey,
        parts: usize,
        max: usize,
        max_bytes: usize,
        remaining: Duration,
    ) {
        let owners: Vec<String> =
            self.shared.leader_groups(&key.1, parts).into_iter().map(|(a, _)| a).collect();
        for addr in owners {
            if !self.shared.mux.mark_inflight(key, &addr) {
                continue;
            }
            let shared = Arc::clone(&self.shared);
            let tkey = key.clone();
            let taddr = addr.clone();
            let spawned = std::thread::Builder::new()
                .name("cluster-fetch".into())
                .spawn(move || run_fetcher(shared, tkey, taddr, max, max_bytes, remaining));
            if let Err(e) = spawned {
                // Degrade, don't crash the consumer: unmark the in-flight
                // slot so the caller's wait loop re-attempts the spawn
                // (or times out at its own deadline).
                log::error!("cluster fetcher thread failed to spawn: {e} — shard fetch degraded");
                self.shared.mux.finish(key, &addr);
            }
        }
    }

    pub fn commit(&self, group: &str, topic: &str, commits: &[(usize, u64)]) -> Result<()> {
        let mut per_owner: Vec<(String, Vec<(usize, u64)>)> = Vec::new();
        for &(p, off) in commits {
            let addr = self.shared.leader_for(topic, p);
            match per_owner.iter_mut().find(|(a, _)| *a == addr) {
                Some((_, subset)) => subset.push((p, off)),
                None => per_owner.push((addr, vec![(p, off)])),
            }
        }
        for (addr, subset) in per_owner {
            match self.call_healed(&addr, group, topic, |c| c.commit(group, topic, &subset)) {
                Ok(()) => {}
                Err(BrokerError::Transport(e)) if self.shared.replication() > 1 => {
                    // Leader died holding these partitions' cursors: fail
                    // over per partition and land the commit on the
                    // promoted follower (which carries the replicated
                    // group offsets).
                    for (p, off) in subset {
                        let next = self
                            .fail_over(topic, p, &addr)
                            .ok_or_else(|| BrokerError::Transport(e.clone()))?;
                        self.call_healed(&next, group, topic, |c| {
                            c.commit(group, topic, &[(p, off)])
                        })?;
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    pub fn delete_records(&self, topic: &str, partition: usize, up_to: u64) -> Result<usize> {
        let addr = self.shared.leader_for(topic, partition);
        // delete_records is group-less; "" routes heal through re-ensure only.
        self.call_healed(&addr, "", topic, |c| c.delete_records(topic, partition, up_to))
    }

    pub fn offsets(&self, topic: &str) -> Result<Vec<(u64, u64)>> {
        self.per_leader_vec(topic, "", |c, topic| c.offsets(topic))
    }

    /// Merged `(position, committed)` per partition — each partition's
    /// current leader answers for its partitions.
    pub fn positions(&self, group: &str, topic: &str) -> Result<Vec<(u64, u64)>> {
        self.per_leader_vec(topic, group, |c, topic| c.positions(group, topic))
    }

    /// Gather one `(u64, u64)` per partition from each partition's current
    /// leader, failing over to a promoted follower when a leader is
    /// unreachable on a replicated cluster.
    fn per_leader_vec(
        &self,
        topic: &str,
        group: &str,
        op: impl Fn(&BrokerClient, &str) -> Result<Vec<(u64, u64)>>,
    ) -> Result<Vec<(u64, u64)>> {
        let parts = self.partitions_of(topic)?;
        let mut out = vec![(0u64, 0u64); parts];
        for (addr, ps) in self.shared.leader_groups(topic, parts) {
            match self.call_healed(&addr, group, topic, |c| op(c, topic)) {
                Ok(os) => {
                    for p in ps {
                        if p < os.len() {
                            out[p] = os[p];
                        }
                    }
                }
                Err(BrokerError::Transport(e)) if self.shared.replication() > 1 => {
                    for p in ps {
                        let next = self
                            .fail_over(topic, p, &addr)
                            .ok_or_else(|| BrokerError::Transport(e.clone()))?;
                        let os = self.call_healed(&next, group, topic, |c| op(c, topic))?;
                        if p < os.len() {
                            out[p] = os[p];
                        }
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Ok(out)
    }

    pub fn crash_member(&self, group: &str, topic: &str, member: &str) -> Result<()> {
        for addr in self.shared.members() {
            match self.shared.with_broker(&addr, |c| c.crash_member(group, topic, member)) {
                Ok(()) | Err(BrokerError::UnknownGroup(_)) => {}
                Err(BrokerError::Transport(e)) if self.shared.replication() > 1 => {
                    self.shared.invalidate(&addr);
                    log::warn!("crash_member skipping unreachable {addr}: {e}");
                }
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }
}

impl crate::broker::StreamBroker for ClusterClient {
    fn ping(&self) -> Result<()> {
        ClusterClient::ping(self)
    }
    fn create_topic(&self, name: &str, partitions: usize) -> Result<()> {
        ClusterClient::create_topic(self, name, partitions)
    }
    fn ensure_topic(&self, name: &str, partitions: usize) -> Result<()> {
        ClusterClient::ensure_topic(self, name, partitions)
    }
    fn delete_topic(&self, name: &str) -> Result<()> {
        ClusterClient::delete_topic(self, name)
    }
    fn topic_names(&self) -> Result<Vec<String>> {
        ClusterClient::topic_names(self)
    }
    fn topic_stats(&self, name: &str) -> Result<TopicStats> {
        ClusterClient::topic_stats(self, name)
    }
    fn publish(&self, topic: &str, rec: ProducerRecord) -> Result<(usize, u64)> {
        ClusterClient::publish(self, topic, rec)
    }
    fn publish_batch(&self, topic: &str, recs: Vec<ProducerRecord>) -> Result<Vec<(usize, u64)>> {
        ClusterClient::publish_batch(self, topic, recs)
    }
    fn join_group(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        mode: AssignmentMode,
    ) -> Result<u64> {
        ClusterClient::join_group(self, group, topic, member, mode)
    }
    fn leave_group(&self, group: &str, topic: &str, member: &str) -> Result<bool> {
        ClusterClient::leave_group(self, group, topic, member)
    }
    fn poll(&self, group: &str, topic: &str, member: &str, max: usize) -> Result<Vec<Arc<Record>>> {
        ClusterClient::poll(self, group, topic, member, max)
    }
    fn fetch_many_wait(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
        wait_ms: u64,
    ) -> Result<MultiFetch> {
        ClusterClient::fetch_many_wait(self, group, topic, member, max, max_bytes, wait_ms)
    }
    fn commit(&self, group: &str, topic: &str, commits: &[(usize, u64)]) -> Result<()> {
        ClusterClient::commit(self, group, topic, commits)
    }
    fn delete_records(&self, topic: &str, partition: usize, up_to: u64) -> Result<usize> {
        ClusterClient::delete_records(self, topic, partition, up_to)
    }
    fn offsets(&self, topic: &str) -> Result<Vec<(u64, u64)>> {
        ClusterClient::offsets(self, topic)
    }
    fn positions(&self, group: &str, topic: &str) -> Result<Vec<(u64, u64)>> {
        ClusterClient::positions(self, group, topic)
    }
    fn crash_member(&self, group: &str, topic: &str, member: &str) -> Result<()> {
        ClusterClient::crash_member(self, group, topic, member)
    }
}

/// Body of one per-broker long-poll thread: fetch with the caller's
/// remaining wait, retrying transport failures with backoff (broker
/// restarts) and self-healing lost topics/groups; the result (or a
/// terminal error) lands in the mux.
fn run_fetcher(
    shared: Arc<Shared>,
    key: MuxKey,
    addr: String,
    max: usize,
    max_bytes: usize,
    wait: Duration,
) {
    let deadline = Instant::now() + wait;
    let mut backoff = RETRY_BACKOFF_START;
    let (group, topic, member) = (key.0.as_str(), key.1.as_str(), key.2.as_str());
    loop {
        let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
            break;
        };
        let client = match shared.client(&addr) {
            Ok(c) => c,
            Err(_) => {
                std::thread::sleep(backoff.min(remaining));
                backoff = (backoff * 2).min(RETRY_BACKOFF_CAP);
                continue;
            }
        };
        match client.fetch_many_wait(
            group,
            topic,
            member,
            max,
            max_bytes,
            remaining.as_millis() as u64,
        ) {
            Ok(mf) => {
                shared.note_positions(group, topic, &addr, &mf);
                shared.mux.deliver(&key, &addr, mf);
                break;
            }
            Err(BrokerError::Transport(_)) => {
                shared.invalidate(&addr);
                std::thread::sleep(backoff.min(remaining));
                backoff = (backoff * 2).min(RETRY_BACKOFF_CAP);
            }
            Err(BrokerError::UnknownTopic(t)) => {
                if !shared.reensure_on(&addr, topic) {
                    shared.mux.fail(&key, BrokerError::UnknownTopic(t));
                    break;
                }
            }
            Err(BrokerError::UnknownGroup(_)) | Err(BrokerError::UnknownMember { .. }) => {
                if !shared.rejoin_on(&addr, group, topic) {
                    shared.mux.fail(&key, BrokerError::UnknownGroup(group.to_string()));
                    break;
                }
            }
            Err(e) => {
                shared.mux.fail(&key, e);
                break;
            }
        }
    }
    shared.mux.finish(&key, &addr);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::cluster::ClusterView;
    use crate::broker::embedded::BrokerCore;
    use crate::broker::server::BrokerServer;
    use std::net::TcpListener;

    fn start_cluster(n: usize) -> (Vec<BrokerServer>, Vec<String>) {
        let listeners: Vec<TcpListener> =
            (0..n).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
        let addrs: Vec<String> =
            listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
        let spec = ClusterSpec::new(addrs.clone());
        let servers = listeners
            .into_iter()
            .zip(&addrs)
            .map(|(l, a)| {
                BrokerServer::start_cluster(
                    BrokerCore::new(),
                    l,
                    ClusterView::new(spec.clone(), a.clone()),
                )
                .unwrap()
            })
            .collect();
        (servers, addrs)
    }

    #[test]
    fn two_broker_publish_fetch_roundtrip() {
        let (servers, addrs) = start_cluster(2);
        let cc = ClusterClient::connect(&addrs).unwrap();
        cc.ensure_topic("t", 16).unwrap();
        let recs: Vec<ProducerRecord> =
            (0..40u8).map(|i| ProducerRecord::new(vec![i])).collect();
        let acks = cc.publish_batch("t", recs).unwrap();
        assert_eq!(acks.len(), 40);
        // Sharding proof: both broker cores hold a share of the records.
        let counts: Vec<usize> =
            servers.iter().map(|s| s.core().topic_stats("t").unwrap().records).collect();
        assert_eq!(counts.iter().sum::<usize>(), 40);
        assert!(counts.iter().all(|&c| c > 0), "both shards must hold data: {counts:?}");
        // One consumer drains the whole topic through the cluster client.
        cc.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let mut got = Vec::new();
        while got.len() < 40 {
            let mf = cc.fetch_many("g", "t", "m", usize::MAX, usize::MAX).unwrap();
            if mf.batches.is_empty() {
                break;
            }
            got.extend(mf.batches.iter().flat_map(|(_, rs)| rs.iter().map(|r| r.value.0[0])));
        }
        got.sort_unstable();
        assert_eq!(got, (0..40u8).collect::<Vec<_>>());
        // Merged stats agree with the shard sum.
        assert_eq!(cc.topic_stats("t").unwrap().records, 40);
        for s in servers {
            s.shutdown();
        }
    }

    #[test]
    fn keyed_records_agree_with_broker_partitioner() {
        let (servers, addrs) = start_cluster(2);
        let cc = ClusterClient::connect(&addrs).unwrap();
        cc.ensure_topic("t", 4).unwrap();
        let (p1, _) = cc.publish("t", ProducerRecord::with_key(b"k".to_vec(), vec![1])).unwrap();
        let (p2, _) = cc.publish("t", ProducerRecord::with_key(b"k".to_vec(), vec![2])).unwrap();
        assert_eq!(p1, p2, "same key must stick to one partition");
        assert_eq!(p1, key_partition(b"k", 4), "client routing must match the broker hash");
        for s in servers {
            s.shutdown();
        }
    }

    #[test]
    fn partial_seed_list_self_corrects_via_not_owner() {
        let (servers, addrs) = start_cluster(2);
        // A client that only knows one member: every publish it routes to
        // that member for a partition owned by the other must bounce with
        // NotOwner, refresh the member list and land on the right shard.
        let cc = ClusterClient::connect(&addrs[..1]).unwrap();
        // connect() already adopts the contacted broker's member list.
        assert_eq!(cc.members().len(), 2, "meta refresh must widen the view");
        cc.ensure_topic("t", 16).unwrap();
        for i in 0..16u8 {
            cc.publish("t", ProducerRecord::new(vec![i])).unwrap();
        }
        let counts: Vec<usize> =
            servers.iter().map(|s| s.core().topic_stats("t").unwrap().records).collect();
        assert_eq!(counts.iter().sum::<usize>(), 16);
        assert!(counts.iter().all(|&c| c > 0), "records must reach both shards: {counts:?}");
        for s in servers {
            s.shutdown();
        }
    }

    #[test]
    fn fetch_wait_wakes_on_any_shard() {
        use std::time::Instant;
        let (servers, addrs) = start_cluster(2);
        let cc = Arc::new(ClusterClient::connect(&addrs).unwrap());
        cc.ensure_topic("t", 8).unwrap();
        cc.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let consumer = Arc::clone(&cc);
        let waiter = std::thread::spawn(move || {
            let t0 = Instant::now();
            let mf = consumer
                .fetch_many_wait("g", "t", "m", usize::MAX, usize::MAX, 10_000)
                .unwrap();
            (mf.record_count(), t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(50));
        cc.publish("t", ProducerRecord::new(vec![9])).unwrap();
        let (count, waited) = waiter.join().unwrap();
        assert_eq!(count, 1);
        assert!(waited < Duration::from_secs(5), "publish must wake the parked mux");
        for s in servers {
            s.shutdown();
        }
    }

    #[test]
    fn commit_and_positions_merge_across_shards() {
        let (servers, addrs) = start_cluster(2);
        let cc = ClusterClient::connect(&addrs).unwrap();
        cc.ensure_topic("t", 4).unwrap();
        for i in 0..12u8 {
            cc.publish("t", ProducerRecord::new(vec![i])).unwrap();
        }
        cc.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let mf = cc.fetch_many("g", "t", "m", usize::MAX, usize::MAX).unwrap();
        assert_eq!(mf.record_count(), 12);
        assert_eq!(mf.positions.len(), 4);
        // Commit everything at the fetch's claim positions, then delete.
        let commits: Vec<(usize, u64)> =
            mf.positions.iter().enumerate().map(|(p, &(pos, _))| (p, pos)).collect();
        cc.commit("g", "t", &commits).unwrap();
        for (p, &(pos, _)) in mf.positions.iter().enumerate() {
            cc.delete_records("t", p, pos).unwrap();
        }
        assert_eq!(cc.topic_stats("t").unwrap().records, 0);
        let merged = cc.positions("g", "t").unwrap();
        assert_eq!(merged.len(), 4);
        assert_eq!(
            merged.iter().map(|&(_, c)| c).sum::<u64>(),
            12,
            "committed offsets must merge across shards"
        );
        for s in servers {
            s.shutdown();
        }
    }

    #[test]
    fn create_topic_keeps_one_winner() {
        let (servers, addrs) = start_cluster(2);
        let cc = ClusterClient::connect(&addrs).unwrap();
        cc.create_topic("t", 2).unwrap();
        assert!(matches!(cc.create_topic("t", 2), Err(BrokerError::TopicExists(_))));
        cc.delete_topic("t").unwrap();
        assert!(matches!(cc.delete_topic("t"), Err(BrokerError::UnknownTopic(_))));
        for s in servers {
            s.shutdown();
        }
    }
}
