//! The membership plane (PR 10): fenced live partition migration.
//!
//! A `ClusterSpec` change (join or drain) moves only the partitions whose
//! rendezvous argmax changed — ~1/N of them — but each of those must
//! change hands **without losing acked records or consumer positions**,
//! while producers and consumers keep running. This module is the handoff
//! state machine the new owner drives for every moved partition:
//!
//! ```text
//!        old owner (source)                    new owner (this broker)
//!   ──────────────────────────              ──────────────────────────
//!   serving reads + writes          (1)     FetchLog loop from local hw
//!        │  keeps accepting  ◄──────────────  replica_append catch-up
//!        │                          (2)     FetchOffsets → sync_offsets
//!        ▼                          (3)     Fence { by: self }
//!   fenced: epoch bumped,   ◄──────────────
//!   answers NotOwner{new}           (4)     final FetchLog drain of the
//!        │                                  frozen tail + offset re-pull
//!        ▼                          (5)     promote: epoch past fence,
//!   redirects producers                     HaState::promote → serving
//! ```
//!
//! Ordering is what makes this safe. The transfer runs **under the old
//! spec** — clients still route to the source, which keeps accepting
//! writes (dual-accept window: both logs exist, only the source takes
//! traffic). The fence (3) freezes the source *before* the final drain
//! (4), so step 4's watermark is exact; the source answers
//! `NotOwner { new }` from its deposal record from then on, so a producer
//! caught mid-handoff pays exactly one reroute. The new owner promotes
//! (5) **before** the spec flips anywhere, so the redirect target is
//! already serving. Only then does the epoch-bumped spec propagate —
//! broker-to-broker via `SpecSync` gossip, client-side via the existing
//! `ClusterMeta` refresh — and placement catches up with reality.
//!
//! A crash mid-handoff is benign at every step: before (3) the source is
//! still the undisputed owner and nothing was installed anywhere; after
//! (3) the fenced source redirects to a new owner that either finished
//! (serving) or can re-run the pull idempotently (`replica_append` skips
//! duplicate prefixes; offset adoption is forward-only).

use std::collections::HashMap;
use std::time::Instant;

use crate::broker::client::BrokerClient;
use crate::broker::embedded::{BrokerCore, BrokerError, Result};
use crate::util::fault::{self, FaultAction};
use crate::util::trace;

use super::placement::ClusterSpec;
use super::ClusterView;

/// Records per catch-up fetch — the same bound the PR 7 replicator uses,
/// for the same reason: frame size stays bounded however far behind the
/// new owner starts.
const MIGRATE_BATCH: usize = 512;

/// Give up on a catch-up loop that makes no forward progress after this
/// many consecutive rounds (retention-trimmed prefix on the source, or a
/// source answering nonsense) instead of wedging the migration thread.
const STALL_ROUNDS: u32 = 3;

/// Pull `(topic, partition)` from its current owner `from` and take
/// ownership: catch up the log and the consumer-offset journal, fence the
/// source, drain the frozen tail, then promote locally. Returns the new
/// owner's post-promotion fencing epoch.
///
/// Runs on the **new** owner (the joiner pulling its rendezvous share, or
/// a survivor told to take a drained member's partition via
/// `MigratePartition`). Idempotent: re-running after a crash re-ships
/// only what is missing.
pub fn pull_partition(
    core: &BrokerCore,
    view: &ClusterView,
    topic: &str,
    partitions: usize,
    partition: usize,
    from: &str,
) -> Result<u64> {
    let _root = trace::span("migrate.transfer");
    let t0 = Instant::now();
    crate::obs_gauge!("cluster.migration.partitions_moving").add(1);
    let res = pull_partition_inner(core, view, topic, partitions, partition, from);
    crate::obs_gauge!("cluster.migration.partitions_moving").add(-1);
    match &res {
        Ok(_) => {
            crate::obs_counter!("cluster.migration.partitions_moved").inc();
            crate::obs_hist!("cluster.migration.handoff_us")
                .observe(t0.elapsed().as_micros() as u64);
        }
        Err(e) => {
            log::warn!("migration of {topic}[{partition}] from {from} failed: {e}");
            crate::obs_counter!("cluster.migration.failures").inc();
        }
    }
    res
}

fn pull_partition_inner(
    core: &BrokerCore,
    view: &ClusterView,
    topic: &str,
    partitions: usize,
    partition: usize,
    from: &str,
) -> Result<u64> {
    check_seam(topic, partition, from)?;
    core.ensure_topic(topic, partitions.max(1))?;
    let src = BrokerClient::connect(from)?;

    // (1) Catch-up: ship the source's log into the local replica while the
    // source keeps serving traffic (the dual-accept window).
    {
        let _s = trace::span("migrate.catchup");
        catch_up(core, &src, topic, partitions, partition, from)?;
    }

    // (2) Consumer-offset journal, first pass — most of it lands here so
    // the post-fence re-pull is small.
    core.sync_offsets(topic, src.fetch_offsets(topic)?)?;

    // (3) Fence the source: it bumps its epoch past everything it issued,
    // records the deposal and answers `NotOwner { us }` from now on. The
    // log is frozen from this instant.
    let fence_epoch = {
        let _s = trace::span("migrate.fence");
        check_seam(topic, partition, from)?;
        src.fence(topic, partitions, partition, &view.self_addr)?
    };

    // (4) Drain the frozen tail — whatever raced in between (1) and (3) —
    // and re-pull the offsets committed during the window.
    {
        let _s = trace::span("migrate.finalize");
        catch_up(core, &src, topic, partitions, partition, from)?;
        if let Ok(entries) = src.fetch_offsets(topic) {
            let _ = core.sync_offsets(topic, entries);
        }
    }

    // (5) Adopt: make sure our epoch is at least the fence epoch, then
    // promote past it so this broker outranks every epoch the source ever
    // issued, and `ClusterView::leads` flips true *before* any spec does.
    if core.partition_epoch(topic, partition)? < fence_epoch {
        core.set_partition_epoch(topic, partition, fence_epoch)?;
    }
    view.promote(core, topic, partitions, partition)
}

/// Ship records from `src` until the local watermark reaches the source's.
/// Forward-progress is guaranteed by `replica_append`'s idempotent apply;
/// a source whose prefix was retention-trimmed below our watermark cannot
/// be represented as a contiguous local log, so a stalled loop returns
/// with what it has instead of spinning (bounded by [`STALL_ROUNDS`]).
fn catch_up(
    core: &BrokerCore,
    src: &BrokerClient,
    topic: &str,
    partitions: usize,
    partition: usize,
    from: &str,
) -> Result<()> {
    let mut local = core.high_watermark(topic, partition)?;
    let mut stalled = 0u32;
    loop {
        check_seam(topic, partition, from)?;
        let (src_hw, epoch, recs) = src.fetch_log(topic, partition, local, MIGRATE_BATCH)?;
        if !recs.is_empty() {
            let base = recs[0].offset;
            let bytes: u64 = recs.iter().map(|r| r.value.len() as u64).sum();
            let applied = core.replica_append(topic, partitions, partition, epoch, base, recs)?;
            if applied > local {
                crate::obs_counter!("cluster.migration.records_transferred")
                    .add(applied - local);
                crate::obs_counter!("cluster.migration.bytes_transferred").add(bytes);
                local = applied;
                stalled = 0;
            } else {
                stalled += 1;
            }
        } else {
            stalled += 1;
        }
        if local >= src_hw {
            return Ok(());
        }
        if stalled >= STALL_ROUNDS {
            log::warn!(
                "migration catch-up of {topic}[{partition}] from {from} stalled at \
                 {local}/{src_hw} — continuing with a truncated prefix"
            );
            return Ok(());
        }
    }
}

/// The `cluster.migrate` fault seam: scripted chaos can refuse, fail or
/// stall any step of a transfer. Context is `topic[partition]@source`, so
/// schedules can target one partition or one source. `Stall` sleeps in
/// place (stretching the dual-accept window); every other action degrades
/// to failing the step — the most disruptive thing a migration seam can
/// do, per the fault plane's no-silent-no-op rule.
fn check_seam(topic: &str, partition: usize, from: &str) -> Result<()> {
    if !fault::active() {
        return Ok(());
    }
    match fault::check(fault::site::CLUSTER_MIGRATE, &format!("{topic}[{partition}]@{from}")) {
        None => Ok(()),
        Some(FaultAction::Stall(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
        Some(_) => Err(BrokerError::Transport(format!(
            "injected migration fault for {topic}[{partition}] from {from}"
        ))),
    }
}

/// Join a running cluster, driven by the joiner. The seed answers
/// `JoinCluster` with the epoch-bumped spec including us (without
/// installing it); we pull our rendezvous share partition by partition
/// under the old placement, and only once every transfer promoted do we
/// install the new spec and gossip it to every member. Returns the
/// adopted spec and the number of partitions pulled.
///
/// The joiner's server must already be listening (it is the redirect
/// target the moment the first fence lands) with a
/// [`ClusterView::new_joining`] view.
pub fn join(core: &BrokerCore, view: &ClusterView, seed: &str) -> Result<(ClusterSpec, usize)> {
    let seed_client = BrokerClient::connect(seed)?;
    let next = ClusterSpec::from_wire(&seed_client.join_cluster(&view.self_addr)?);
    if !next.contains(&view.self_addr) {
        return Err(BrokerError::Transport(format!(
            "seed {seed} answered a spec without us: {:?}",
            next.members()
        )));
    }
    let cur = view.spec();
    let mut moved = 0usize;
    for (topic, partitions) in cluster_topics(&cur, &view.self_addr) {
        for p in 0..partitions {
            if next.owner(&topic, p) != view.self_addr {
                continue; // not our share
            }
            if !cur.is_empty() && cur.owner(&topic, p) == view.self_addr {
                continue; // already ours (re-join after a crash)
            }
            let source = cur.owner(&topic, p).to_string();
            pull_partition(core, view, &topic, partitions, p, &source)?;
            moved += 1;
        }
    }
    view.install_spec(next.clone());
    gossip(&next, &view.self_addr);
    Ok((next, moved))
}

/// Drain this broker: hand every partition it owns to that partition's
/// next rendezvous owner (which runs [`pull_partition`] against us via
/// `MigratePartition`), then install + gossip the spec without us.
/// Returns the number of partitions handed off. Runs on the **draining**
/// broker, in response to `DrainMember`.
pub fn drain(core: &BrokerCore, view: &ClusterView) -> Result<usize> {
    let cur = view.spec();
    if !cur.contains(&view.self_addr) {
        return Ok(0); // already drained (idempotent retry)
    }
    let next = cur.removed(&view.self_addr);
    if next.is_empty() {
        return Err(BrokerError::Transport(
            "cannot drain the last cluster member — nothing would own the data".into(),
        ));
    }
    let mut conns: HashMap<String, BrokerClient> = HashMap::new();
    let mut moved = 0usize;
    for topic in core.topic_names() {
        let partitions = core.partition_count(&topic)?;
        for p in 0..partitions {
            if cur.owner(&topic, p) != view.self_addr {
                continue;
            }
            let target = next.owner(&topic, p).to_string();
            if !conns.contains_key(&target) {
                conns.insert(target.clone(), BrokerClient::connect(&target)?);
            }
            conns[&target].migrate_partition(&topic, partitions, p, &view.self_addr)?;
            moved += 1;
        }
    }
    view.install_spec(next.clone());
    gossip(&next, &view.self_addr);
    Ok(moved)
}

/// Every topic the cluster serves, with its partition count — collected
/// from each current member (best-effort per member: a dead member's
/// topics are found through the survivors that replicate them).
fn cluster_topics(spec: &ClusterSpec, exclude: &str) -> Vec<(String, usize)> {
    let mut out: Vec<(String, usize)> = Vec::new();
    for m in spec.members() {
        if m == exclude {
            continue;
        }
        let Ok(c) = BrokerClient::connect(m) else {
            continue;
        };
        let Ok(names) = c.topic_names() else {
            continue;
        };
        for t in names {
            let Ok(stats) = c.topic_stats(&t) else {
                continue;
            };
            match out.iter_mut().find(|(name, _)| *name == t) {
                Some((_, n)) => *n = (*n).max(stats.partitions),
                None => out.push((t, stats.partitions)),
            }
        }
    }
    out.sort();
    out
}

/// Best-effort spec gossip: push `spec` to every member except `exclude`.
/// A member that cannot be reached converges later — any peer or client
/// that talks to an updated member adopts the higher epoch, and the
/// drained/joined broker keeps answering `SpecSync` pushes itself.
fn gossip(spec: &ClusterSpec, exclude: &str) {
    for m in spec.members() {
        if m == exclude {
            continue;
        }
        match BrokerClient::connect(m).and_then(|c| c.spec_sync(spec.to_wire())) {
            Ok(_) => {}
            Err(e) => log::warn!("spec gossip to {m} failed (will converge later): {e}"),
        }
    }
}
