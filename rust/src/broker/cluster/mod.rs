//! The cluster plane: sharding topics across N broker processes with
//! deterministic, client-side routing.
//!
//! PRs 1–3 gave the single-broker data plane batching, wakeup-driven
//! delivery and durability; this subsystem removes the last scale cap —
//! one `BrokerCore` per deployment — without touching application code,
//! exactly the property the paper's homogeneous stream representation
//! (§4.2) was designed to preserve:
//!
//! - [`placement`] — a [`ClusterSpec`] (static seed list) and a rendezvous
//!   hash mapping `(topic, partition) → broker`. Pure and shared: every
//!   client computes ownership locally and identically, no coordination
//!   service.
//! - [`ClusterView`] — the broker side of the spec: each member knows its
//!   own address, answers `ClusterMeta`, serves only partitions it owns
//!   and answers `NotOwner { owner_addr }` (wire code 8) for the rest, so
//!   stale or misconfigured clients self-correct.
//! - [`client::ClusterClient`] — a [`crate::broker::BrokerClient`]-shaped
//!   handle over the whole cluster: publishes fan out per owner, fetches
//!   run one long-poll per owning broker merged through a small wakeup
//!   mux, consumer groups are scoped per broker under the hood while the
//!   client presents the paper's single-group illusion (merged commit
//!   positions), and every wire operation retries with exponential backoff
//!   across broker restarts.
//!
//! Every broker runs [`crate::broker::group::GroupState`] only for the
//! partitions it owns (the others stay empty, so their cursors never
//! move); a restarted member recovers its shard from its own `--data-dir`
//! via the PR 3 storage plane, and consumers resume from the committed
//! offsets persisted in that shard's `offsets.log`.

pub mod client;
pub mod migrate;
pub mod placement;
pub mod replicate;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use super::embedded::{BrokerCore, Result};

pub use client::ClusterClient;
pub use placement::{ClusterSpec, PLACEMENT_VERSION};
pub use replicate::{HaState, Replicator};

/// Poison-tolerant mutex lock for the cluster plane's shared state. A
/// panic on one thread (a scripted fault, an assertion in a test sharing
/// the process) poisons the lock; the data under these locks is
/// crash-consistent bookkeeping (watermarks, deposals, routing caches)
/// where a stale read degrades service, while propagating the panic
/// would take the whole broker down — so every cluster hot path degrades
/// instead of crashing.
pub(crate) fn relock<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Poison-tolerant `RwLock` read — see [`relock`].
pub(crate) fn rread<T>(l: &RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Poison-tolerant `RwLock` write — see [`relock`].
pub(crate) fn rwrite<T>(l: &RwLock<T>) -> std::sync::RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// A broker's view of the cluster it belongs to: the shared spec plus its
/// own advertised address. Handed to
/// [`crate::broker::BrokerServer::start_cluster`]; the dispatch layer uses
/// it to enforce ownership (`NotOwner`) and answer `ClusterMeta`.
///
/// Since PR 10 the spec is **dynamic**: membership changes arrive as
/// epoch-bumped specs (`JoinCluster`/`SpecSync`/drain) and are adopted via
/// [`ClusterView::install_spec`], which only ever moves the epoch forward.
/// Everything that reads placement takes a cheap snapshot through
/// [`ClusterView::spec`], so a membership flip is one `RwLock` write and
/// in-flight requests keep routing on whichever spec they snapshotted —
/// at worst one `NotOwner` reroute behind the flip.
#[derive(Debug)]
pub struct ClusterView {
    spec: RwLock<ClusterSpec>,
    /// The address clients reach *this* broker under (must be one of the
    /// spec's members, spelled identically — except during a live join,
    /// see [`ClusterView::new_joining`]).
    pub self_addr: String,
    /// Round-robin cursor for key-less publishes arriving over the legacy
    /// partition-less frames — rotated across the partitions this broker
    /// owns.
    rr: AtomicU64,
    /// Failover bookkeeping (PR 7): partitions this broker was promoted to
    /// lead out-of-placement, and partitions it was fenced away from.
    ha: Arc<HaState>,
    /// The segment-shipping worker, present only when the spec's
    /// replication factor is > 1. Set once by
    /// [`crate::broker::BrokerServer`] at startup.
    replicator: OnceLock<Arc<Replicator>>,
    /// Acks level applied to *legacy* partition-less publishes, which
    /// carry no per-frame level (partition-targeted `PublishTo` frames
    /// ship their own). Set by the broker CLI's `--acks`.
    default_acks: u8,
}

impl ClusterView {
    pub fn new(spec: ClusterSpec, self_addr: impl Into<String>) -> Self {
        let self_addr = self_addr.into();
        debug_assert!(
            spec.contains(&self_addr),
            "self_addr {self_addr:?} is not a cluster member"
        );
        Self {
            spec: RwLock::new(spec),
            self_addr,
            rr: AtomicU64::new(0),
            ha: HaState::new(),
            replicator: OnceLock::new(),
            default_acks: super::protocol::ACKS_LEADER,
        }
    }

    /// A view for a broker that is **joining** a running cluster: it holds
    /// the cluster's current spec but its own address is not in it yet, so
    /// it owns nothing, receives no routed traffic, and can pull its
    /// rendezvous share in peace. [`ClusterView::install_spec`] with the
    /// epoch-bumped spec (which does contain it) completes the join.
    pub fn new_joining(spec: ClusterSpec, self_addr: impl Into<String>) -> Self {
        Self {
            spec: RwLock::new(spec),
            self_addr: self_addr.into(),
            rr: AtomicU64::new(0),
            ha: HaState::new(),
            replicator: OnceLock::new(),
            default_acks: super::protocol::ACKS_LEADER,
        }
    }

    /// Snapshot the current spec. A clone of a few strings — cheap enough
    /// for request paths, and it means a concurrent membership flip never
    /// sees a request half-routed under two specs.
    pub fn spec(&self) -> ClusterSpec {
        rread(&self.spec).clone()
    }

    /// Adopt `next` iff its epoch is newer than the current spec's.
    /// Returns whether the flip happened. Also hands the new spec to the
    /// replication worker (if any), so follower sets follow membership.
    /// Lock poison is tolerated: membership must keep converging even
    /// after an unrelated panic on some other thread.
    pub fn install_spec(&self, next: ClusterSpec) -> bool {
        {
            let mut cur = rwrite(&self.spec);
            if next.epoch <= cur.epoch {
                return false;
            }
            *cur = next.clone();
        }
        if let Some(rep) = self.replicator() {
            rep.update_spec(next);
        }
        crate::obs_counter!("cluster.membership.spec_flips").inc();
        true
    }

    /// Builder: the acks level for legacy partition-less publishes
    /// ([`super::protocol::ACKS_LEADER`] or
    /// [`super::protocol::ACKS_QUORUM`]).
    pub fn with_default_acks(mut self, acks: u8) -> Self {
        self.default_acks = acks;
        self
    }

    /// Acks level applied to legacy partition-less publishes.
    pub fn default_acks(&self) -> u8 {
        self.default_acks
    }

    /// True when this broker owns `(topic, partition)` under the current
    /// spec's placement. Failover-unaware; see [`ClusterView::leads`] for
    /// the authoritative check.
    pub fn owns(&self, topic: &str, partition: usize) -> bool {
        let spec = rread(&self.spec);
        !spec.is_empty() && spec.owner(topic, partition) == self.self_addr
    }

    /// True when this broker is the *current* leader for
    /// `(topic, partition)`: a live promotion wins, a fencing deposal
    /// loses, and otherwise leadership follows the static placement.
    pub fn leads(&self, topic: &str, partition: usize) -> bool {
        if self.ha.promoted_epoch(topic, partition).is_some() {
            return true;
        }
        if self.ha.deposed_info(topic, partition).is_some() {
            return false;
        }
        self.owns(topic, partition)
    }

    /// Best-known current leader address for `(topic, partition)` — the
    /// broker that fenced us if we were deposed, else the static owner.
    /// Used to fill `NotOwner` redirects.
    pub fn leader_of(&self, topic: &str, partition: usize) -> String {
        if let Some((_, by)) = self.ha.deposed_info(topic, partition) {
            if !by.is_empty() {
                return by;
            }
        }
        let spec = rread(&self.spec);
        if spec.is_empty() {
            return self.self_addr.clone();
        }
        spec.owner(topic, partition).to_string()
    }

    /// Promote this broker to leader of `(topic, partition)`: bump the
    /// partition's fencing epoch past everything it has seen, persist it,
    /// and record the promotion so [`ClusterView::leads`] flips true.
    /// Returns the new epoch. Idempotent in effect — repeated calls keep
    /// bumping the epoch, which is harmless (epochs only need to grow).
    pub fn promote(
        &self,
        core: &BrokerCore,
        topic: &str,
        partitions: usize,
        partition: usize,
    ) -> Result<u64> {
        core.ensure_topic(topic, partitions.max(1))?;
        let epoch = core.partition_epoch(topic, partition)? + 1;
        core.set_partition_epoch(topic, partition, epoch)?;
        self.ha.promote(topic, partition, epoch);
        crate::obs_counter!("cluster.failover.promotions").inc();
        Ok(epoch)
    }

    /// Shared failover bookkeeping, for wiring into a [`Replicator`].
    pub fn ha(&self) -> Arc<HaState> {
        Arc::clone(&self.ha)
    }

    /// Install the replication worker (once, at server startup).
    pub fn set_replicator(&self, rep: Arc<Replicator>) {
        let _ = self.replicator.set(rep);
    }

    /// The replication worker, when this member runs with replication > 1.
    pub fn replicator(&self) -> Option<Arc<Replicator>> {
        self.replicator.get().cloned()
    }

    /// The partitions of `topic` this broker owns under a
    /// `partitions`-wide layout.
    pub fn owned_partitions(&self, topic: &str, partitions: usize) -> Vec<usize> {
        rread(&self.spec).owned_by(&self.self_addr, topic, partitions)
    }

    /// Rotate over `owned` for key-less legacy publishes.
    pub fn next_owned(&self, owned: &[usize]) -> Option<usize> {
        if owned.is_empty() {
            return None;
        }
        let i = self.rr.fetch_add(1, Ordering::Relaxed) as usize % owned.len();
        Some(owned[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_ownership_matches_spec() {
        let spec = ClusterSpec::new(["a:1", "b:1"]);
        let va = ClusterView::new(spec.clone(), "a:1");
        let vb = ClusterView::new(spec.clone(), "b:1");
        for p in 0..16 {
            assert_ne!(va.owns("t", p), vb.owns("t", p), "exactly one owner per partition");
            assert_eq!(va.owns("t", p), spec.owner("t", p) == "a:1");
        }
        let owned_a = va.owned_partitions("t", 16);
        let owned_b = vb.owned_partitions("t", 16);
        assert_eq!(owned_a.len() + owned_b.len(), 16);
    }

    #[test]
    fn install_spec_only_moves_forward() {
        let spec = ClusterSpec::new(["a:1", "b:1"]);
        let v = ClusterView::new(spec.clone(), "a:1");
        let stale = spec.clone(); // epoch 0, same as current — must be rejected
        assert!(!v.install_spec(stale));
        let next = spec.joined("c:1");
        assert!(v.install_spec(next.clone()));
        assert_eq!(v.spec(), next);
        // Re-installing the same epoch is a no-op too.
        assert!(!v.install_spec(next));
    }

    #[test]
    fn joining_view_owns_nothing_until_the_spec_flips() {
        let spec = ClusterSpec::new(["a:1", "b:1"]);
        let v = ClusterView::new_joining(spec.clone(), "c:1");
        assert!(v.owned_partitions("t", 16).is_empty());
        let next = spec.joined("c:1");
        assert!(v.install_spec(next));
        assert!(
            !v.owned_partitions("t", 64).is_empty(),
            "after the flip the joiner must hold its rendezvous share"
        );
    }

    #[test]
    fn next_owned_rotates() {
        let spec = ClusterSpec::new(["a:1"]);
        let v = ClusterView::new(spec, "a:1");
        assert_eq!(v.next_owned(&[]), None);
        let owned = vec![3usize, 5, 9];
        let picks: Vec<usize> = (0..6).map(|_| v.next_owned(&owned).unwrap()).collect();
        assert_eq!(picks, vec![3, 5, 9, 3, 5, 9]);
    }
}
