//! The HA plane: leader→follower log shipping with fencing epochs.
//!
//! Every partition has `ClusterSpec::replication()` replicas — the
//! rendezvous ranking's top member leads, the rest follow. The leader's
//! [`Replicator`] streams appended records to the followers over the
//! PR 5 mux plane: the wire `Record` is byte-identical to the CRC-framed
//! segment body, so a follower apply is append + CRC check and leader and
//! follower segment files stay bit-for-bit identical.
//!
//! **Acks.** `acks=leader` returns once the leader appended (replication
//! is asynchronous — Kafka-style, fast but a leader crash can lose the
//! tail). `acks=quorum` blocks the publish until every **in-sync**
//! follower confirmed the records. The in-sync set (ISR) shrinks when a
//! follower dies or falls behind the quorum deadline — so a dead follower
//! costs one deadline, never a wedged publish path — and recovers on a
//! timed rejoin backoff once the follower answers again (the backfill
//! protocol below catches it up first).
//!
//! **Fencing.** Leadership changes bump a per-partition epoch, persisted
//! in the partition's `meta.bin`. Followers refuse `Replicate` frames
//! carrying a stale epoch with [`BrokerError::Fenced`]; a deposed leader
//! sees the refusal, marks itself deposed in [`HaState`] and starts
//! answering `NotOwner { owner: fencer }` — so a stale leader rejoining
//! after a network blip cannot keep accepting writes that the promoted
//! follower would never see.
//!
//! **Backfill.** A follower acks every frame with its high watermark.
//! A watermark short of the shipped range means the follower is missing a
//! prefix (fresh replica, or it was down); the worker rewinds and
//! re-ships from the follower's watermark until it converges.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::broker::client::BrokerClient;
use crate::broker::embedded::{BrokerCore, BrokerError, Result};
use crate::util::trace::{self, TraceCtx};

use super::placement::ClusterSpec;
use super::relock;

/// Records per replication frame — bounds frame size while backfilling a
/// follower that is far behind.
const REPLICATE_BATCH: usize = 512;

/// How long `acks=quorum` waits for a follower before dropping it from
/// the in-sync set (the publish then acks without it).
const QUORUM_WAIT: Duration = Duration::from_secs(2);

/// How long an out-of-sync follower stays benched before the worker
/// probes it again.
const REJOIN_BACKOFF: Duration = Duration::from_millis(750);

/// Worker park slice: bounds shutdown latency when the queue is idle.
const IDLE_PARK: Duration = Duration::from_millis(100);

/// Per-broker leadership bookkeeping, shared between the dispatch layer
/// (`ClusterView`) and the [`Replicator`]:
///
/// * `promoted` — partitions this broker leads **beyond** what the static
///   placement says (client-driven failover), with the fencing epoch it
///   was promoted at.
/// * `deposed` — partitions this broker must stop leading because a
///   follower fenced it (a newer leader exists), with the fencer's epoch
///   and address (the `NotOwner` redirect target).
#[derive(Debug, Default)]
pub struct HaState {
    promoted: Mutex<HashMap<(String, usize), u64>>,
    deposed: Mutex<HashMap<(String, usize), (u64, String)>>,
}

impl HaState {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Record a promotion: this broker now leads `(topic, partition)` at
    /// `epoch`. Clears any deposal (a re-promotion outranks it).
    pub fn promote(&self, topic: &str, partition: usize, epoch: u64) {
        let key = (topic.to_string(), partition);
        relock(&self.deposed).remove(&key);
        let mut promoted = relock(&self.promoted);
        let e = promoted.entry(key).or_insert(0);
        *e = (*e).max(epoch);
    }

    /// Epoch this broker was promoted at for `(topic, partition)`, if any.
    pub fn promoted_epoch(&self, topic: &str, partition: usize) -> Option<u64> {
        relock(&self.promoted).get(&(topic.to_string(), partition)).copied()
    }

    /// Record a deposal: a follower fenced this broker's replication at
    /// `epoch`, enforced by `by`. Ignored if this broker was itself
    /// promoted at an equal-or-newer epoch (it IS the newest leader).
    pub fn depose(&self, topic: &str, partition: usize, epoch: u64, by: &str) {
        let key = (topic.to_string(), partition);
        if relock(&self.promoted).get(&key).is_some_and(|&own| own >= epoch) {
            return;
        }
        relock(&self.promoted).remove(&key);
        relock(&self.deposed).insert(key, (epoch, by.to_string()));
    }

    /// `(epoch, fencer address)` if this broker was deposed for
    /// `(topic, partition)` — the dispatch layer's `NotOwner` redirect.
    pub fn deposed_info(&self, topic: &str, partition: usize) -> Option<(u64, String)> {
        relock(&self.deposed).get(&(topic.to_string(), partition)).cloned()
    }
}

/// One queued shipping task.
struct Job {
    topic: String,
    partitions: usize,
    partition: usize,
    /// First offset this job must make visible on followers.
    base: u64,
    /// Records appended by the triggering publish.
    count: u64,
    /// Also ship the topic's consumer-group cursors.
    ship_offsets: bool,
    /// Trace context of the triggering publish — the shipping worker's
    /// spans (and the Replicate frames it sends) chain onto it.
    ctx: TraceCtx,
}

/// Follower shipping state keyed by `(follower addr, topic, partition)`.
type ReplicaKey = (String, String, usize);

#[derive(Default)]
struct Inner {
    jobs: VecDeque<Job>,
    /// Highest watermark each follower confirmed.
    watermarks: HashMap<ReplicaKey, u64>,
    /// Followers dropped from the in-sync set, with their bench time.
    out_of_sync: HashMap<ReplicaKey, Instant>,
}

/// The leader-side replication worker: one background thread draining a
/// job queue, one lazily-connected [`BrokerClient`] per follower.
pub struct Replicator {
    core: Arc<BrokerCore>,
    /// The membership spec the follower sets derive from. Mutable since
    /// PR 10: an epoch-bumped spec installed by the membership plane
    /// (join/drain) re-targets shipping without restarting the worker.
    spec: Mutex<ClusterSpec>,
    self_addr: String,
    ha: Arc<HaState>,
    inner: Mutex<Inner>,
    /// Signals the worker that jobs arrived (or shutdown).
    job_cv: Condvar,
    /// Signals quorum waiters that watermarks (or the ISR) changed.
    ack_cv: Condvar,
    shutdown: AtomicBool,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl std::fmt::Debug for Replicator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Replicator").field("self_addr", &self.self_addr).finish_non_exhaustive()
    }
}

impl Replicator {
    /// Spawn the shipping worker for a broker that replicates (call only
    /// when `spec.replication() > 1`).
    pub fn start(
        core: Arc<BrokerCore>,
        spec: ClusterSpec,
        self_addr: impl Into<String>,
        ha: Arc<HaState>,
    ) -> Arc<Self> {
        let rep = Arc::new(Self {
            core,
            spec: Mutex::new(spec),
            self_addr: self_addr.into(),
            ha,
            inner: Mutex::new(Inner::default()),
            job_cv: Condvar::new(),
            ack_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            worker: Mutex::new(None),
        });
        let w = Arc::clone(&rep);
        // Spawn failure (fd/thread exhaustion) degrades to an unshipped
        // queue — quorum waits then bench every follower and publishes
        // keep acking at leader durability — instead of crashing the
        // broker that was asked to replicate.
        match std::thread::Builder::new()
            .name(format!("replicator-{}", rep.self_addr))
            .spawn(move || w.run())
        {
            Ok(handle) => *relock(&rep.worker) = Some(handle),
            Err(e) => log::error!(
                "replicator worker thread failed to spawn: {e} — replication degraded \
                 (publishes ack at leader durability only)"
            ),
        }
        rep
    }

    /// Adopt an epoch-bumped membership spec: follower sets computed after
    /// this call follow the new placement. Already-queued jobs re-read the
    /// spec when they ship, so a drain that removed a member stops
    /// shipping to it without draining the queue first. Older epochs are
    /// ignored (gossip can race).
    pub fn update_spec(&self, next: ClusterSpec) {
        let mut spec = relock(&self.spec);
        if next.epoch > spec.epoch {
            *spec = next;
            drop(spec);
            self.ack_cv.notify_all();
        }
    }

    /// Stop the worker (idempotent; joins the thread).
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.job_cv.notify_all();
        let handle = relock(&self.worker).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    /// Queue `count` freshly appended records of `(topic, partition)`
    /// (offsets `[base, base + count)`) for shipping to the followers.
    /// `ctx` is the publishing request's trace context (or
    /// [`TraceCtx::NONE`]): the ship spans and the follower applies they
    /// trigger stitch into the publish's trace.
    pub fn enqueue(
        &self,
        topic: &str,
        partitions: usize,
        partition: usize,
        base: u64,
        count: u64,
        ctx: TraceCtx,
    ) {
        if count == 0 {
            return;
        }
        let mut inner = relock(&self.inner);
        inner.jobs.push_back(Job {
            topic: topic.to_string(),
            partitions,
            partition,
            base,
            count,
            ship_offsets: false,
            ctx,
        });
        self.job_cv.notify_all();
    }

    /// Queue a consumer-group cursor sync for `topic` (commit path: the
    /// followers must know the resume points before a failover needs
    /// them).
    pub fn enqueue_offsets(&self, topic: &str, partitions: usize) {
        let mut inner = relock(&self.inner);
        // Coalesce: a pending offset sync for the topic already covers it.
        if inner.jobs.iter().any(|j| j.ship_offsets && j.topic == topic) {
            return;
        }
        inner.jobs.push_back(Job {
            topic: topic.to_string(),
            partitions,
            partition: 0,
            base: 0,
            count: 0,
            ship_offsets: true,
            ctx: TraceCtx::NONE,
        });
        self.job_cv.notify_all();
    }

    /// Block an `acks=quorum` publish until every in-sync follower of
    /// `(topic, partition)` confirmed offsets `< target`, this broker was
    /// fenced (→ [`BrokerError::Fenced`]), or [`QUORUM_WAIT`] elapsed —
    /// laggards are then dropped from the in-sync set and the publish
    /// acks without them (the ISR may legitimately shrink to just the
    /// leader: availability over replica count, exactly like Kafka's
    /// `min.insync.replicas=1`).
    pub fn wait_quorum(&self, topic: &str, partition: usize, target: u64) -> Result<()> {
        let _span = trace::span("quorum.wait");
        let deadline = Instant::now() + QUORUM_WAIT;
        let followers = self.followers(topic, partition);
        let mut inner = relock(&self.inner);
        loop {
            if let Some((epoch, by)) = self.ha.deposed_info(topic, partition) {
                return Err(BrokerError::Fenced { epoch, by });
            }
            let pending: Vec<&String> = followers
                .iter()
                .filter(|f| {
                    let key = (f.to_string(), topic.to_string(), partition);
                    !inner.out_of_sync.contains_key(&key)
                        && inner.watermarks.get(&key).copied().unwrap_or(0) < target
                })
                .collect();
            if pending.is_empty() {
                return Ok(());
            }
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                // Deadline: bench the laggards so the next publish does
                // not pay this wait again; they rejoin via backfill.
                let now = Instant::now();
                let lagging: Vec<String> = pending.into_iter().cloned().collect();
                for f in lagging {
                    log::warn!(
                        "quorum wait: follower {f} lagging on {topic}[{partition}] — \
                         dropping from in-sync set"
                    );
                    inner.out_of_sync.insert((f, topic.to_string(), partition), now);
                }
                crate::obs_gauge!("replicate.isr_benched").set(inner.out_of_sync.len() as i64);
                self.ack_cv.notify_all();
                return Ok(());
            };
            let (g, _) = self.ack_cv.wait_timeout(inner, remaining).unwrap_or_else(|e| e.into_inner());
            inner = g;
        }
    }

    /// Highest watermark `follower` confirmed for `(topic, partition)`
    /// (tests / introspection).
    pub fn follower_watermark(&self, follower: &str, topic: &str, partition: usize) -> u64 {
        relock(&self.inner)
            .watermarks
            .get(&(follower.to_string(), topic.to_string(), partition))
            .copied()
            .unwrap_or(0)
    }

    /// The follower replicas of `(topic, partition)` — the placement's
    /// replica list minus this broker.
    fn followers(&self, topic: &str, partition: usize) -> Vec<String> {
        let spec = relock(&self.spec);
        if spec.is_empty() {
            return Vec::new();
        }
        spec.replicas(topic, partition)
            .into_iter()
            .filter(|a| *a != self.self_addr)
            .map(str::to_string)
            .collect()
    }

    // ---- worker ---------------------------------------------------------

    fn run(self: Arc<Self>) {
        // Follower connections are worker-local: lazily opened, dropped on
        // transport failure so the next probe reconnects.
        let mut conns: HashMap<String, BrokerClient> = HashMap::new();
        loop {
            let job = {
                let mut inner = relock(&self.inner);
                loop {
                    if self.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    if let Some(job) = inner.jobs.pop_front() {
                        break job;
                    }
                    let (g, _) = self.job_cv.wait_timeout(inner, IDLE_PARK).unwrap_or_else(|e| e.into_inner());
                    inner = g;
                }
            };
            if job.ship_offsets {
                self.ship_offsets(&job, &mut conns);
            } else {
                // The guard makes the publish ctx ambient on this worker
                // thread, so the Replicate frames shipped below carry it.
                let _s = trace::span_in(job.ctx, "replicate.ship");
                self.ship_records(&job, &mut conns);
            }
        }
    }

    /// Ship one record job to every follower (benched followers are
    /// probed again once their backoff elapsed — that probe is also the
    /// rejoin path, because the backfill loop catches them up).
    fn ship_records(&self, job: &Job, conns: &mut HashMap<String, BrokerClient>) {
        if self.ha.deposed_info(&job.topic, job.partition).is_some() {
            return; // fenced: a newer leader owns this partition now
        }
        let Ok(epoch) = self.core.partition_epoch(&job.topic, job.partition) else {
            return; // topic deleted since the job was queued
        };
        let target = job.base + job.count;
        for follower in self.followers(&job.topic, job.partition) {
            let key = (follower.clone(), job.topic.clone(), job.partition);
            {
                let inner = relock(&self.inner);
                if inner.watermarks.get(&key).copied().unwrap_or(0) >= target {
                    continue; // a later job already covered this range
                }
                if let Some(benched_at) = inner.out_of_sync.get(&key) {
                    if benched_at.elapsed() < REJOIN_BACKOFF {
                        continue;
                    }
                }
            }
            match self.ship_to(&follower, job, epoch, target, conns) {
                Ok(hw) => {
                    let mut inner = relock(&self.inner);
                    let wm = inner.watermarks.entry(key.clone()).or_insert(0);
                    let prev = *wm;
                    *wm = (*wm).max(hw);
                    let lag = target.saturating_sub(*wm);
                    if hw >= target {
                        inner.out_of_sync.remove(&key); // caught up: rejoin
                    }
                    let benched = inner.out_of_sync.len();
                    drop(inner);
                    crate::obs_counter!("replicate.shipped_records").add(hw.saturating_sub(prev));
                    crate::util::obs::gauge(&format!(
                        "replicate.lag_records{{{follower}/{}/{}}}",
                        job.topic, job.partition
                    ))
                    .set(lag as i64);
                    crate::obs_gauge!("replicate.isr_benched").set(benched as i64);
                    self.ack_cv.notify_all();
                }
                Err(BrokerError::Fenced { epoch, by }) => {
                    log::warn!(
                        "replication of {}[{}] fenced at epoch {epoch} by {by} — \
                         stepping down",
                        job.topic,
                        job.partition
                    );
                    self.ha.depose(&job.topic, job.partition, epoch, &by);
                    self.ack_cv.notify_all();
                    return; // deposed: stop shipping this partition
                }
                Err(e) => {
                    log::warn!(
                        "replication to {follower} for {}[{}] failed: {e} — \
                         dropping from in-sync set",
                        job.topic,
                        job.partition
                    );
                    conns.remove(&follower);
                    let mut inner = relock(&self.inner);
                    inner.out_of_sync.insert(key, Instant::now());
                    crate::obs_gauge!("replicate.isr_benched").set(inner.out_of_sync.len() as i64);
                    drop(inner);
                    self.ack_cv.notify_all();
                }
            }
        }
    }

    /// Drive one follower to `target`, backfilling as needed. Returns the
    /// follower's final confirmed watermark.
    fn ship_to(
        &self,
        follower: &str,
        job: &Job,
        epoch: u64,
        target: u64,
        conns: &mut HashMap<String, BrokerClient>,
    ) -> Result<u64> {
        if !conns.contains_key(follower) {
            conns.insert(follower.to_string(), BrokerClient::connect(follower)?);
        }
        let client = &conns[follower];
        let mut from = job.base;
        loop {
            let recs = self.core.read_records(
                &job.topic,
                job.partition,
                from,
                REPLICATE_BATCH,
            )?;
            // Retention may have trimmed below `from`; ship what exists.
            let base = recs.first().map_or(from, |r| r.offset);
            let shipped = recs.len() as u64;
            let hw = client.replicate(
                &job.topic,
                job.partitions,
                job.partition,
                epoch,
                base,
                recs.iter().map(|r| (**r).clone()).collect(),
            )?;
            if hw >= target {
                return Ok(hw);
            }
            if hw >= from && shipped > 0 && hw > base {
                from = hw; // forward progress (possibly a partial apply)
            } else if hw < from {
                from = hw; // follower is behind: backfill from its hw
            } else {
                // No progress possible (e.g. the prefix was retention-
                // trimmed away here): report what the follower has.
                return Ok(hw);
            }
        }
    }

    /// Ship the topic's consumer-group cursors to every follower of every
    /// partition (deduplicated). Best-effort single attempts: a dead
    /// follower picks the cursors up with the next sync after it rejoins.
    fn ship_offsets(&self, job: &Job, conns: &mut HashMap<String, BrokerClient>) {
        let entries = self.core.group_offset_entries(&job.topic);
        if entries.is_empty() {
            return;
        }
        let mut targets: Vec<String> = Vec::new();
        for p in 0..job.partitions {
            for f in self.followers(&job.topic, p) {
                if !targets.contains(&f) {
                    targets.push(f);
                }
            }
        }
        for follower in targets {
            if !conns.contains_key(&follower) {
                match BrokerClient::connect(&follower) {
                    Ok(c) => {
                        conns.insert(follower.clone(), c);
                    }
                    Err(_) => continue,
                }
            }
            if conns[&follower].sync_offsets(&job.topic, entries.clone()).is_err() {
                conns.remove(&follower);
            }
        }
    }
}

impl Drop for Replicator {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.job_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ha_state_promote_depose_precedence() {
        let ha = HaState::new();
        assert_eq!(ha.promoted_epoch("t", 0), None);
        assert_eq!(ha.deposed_info("t", 0), None);
        ha.promote("t", 0, 3);
        assert_eq!(ha.promoted_epoch("t", 0), Some(3));
        // A stale fencer (older epoch) cannot depose a newer promotion.
        ha.depose("t", 0, 2, "b:1");
        assert_eq!(ha.promoted_epoch("t", 0), Some(3));
        assert_eq!(ha.deposed_info("t", 0), None);
        // A newer fencer wins: promotion cleared, redirect recorded.
        ha.depose("t", 0, 5, "b:1");
        assert_eq!(ha.promoted_epoch("t", 0), None);
        assert_eq!(ha.deposed_info("t", 0), Some((5, "b:1".to_string())));
        // Re-promotion at a yet-newer epoch clears the deposal.
        ha.promote("t", 0, 6);
        assert_eq!(ha.promoted_epoch("t", 0), Some(6));
        assert_eq!(ha.deposed_info("t", 0), None);
    }

    #[test]
    fn quorum_wait_benches_lagging_followers() {
        // A replicator whose follower never answers must not wedge the
        // quorum publish path: the wait expires, the follower leaves the
        // in-sync set, and later waits return immediately.
        let core = BrokerCore::new();
        core.create_topic("t", 1).unwrap();
        let spec =
            ClusterSpec::new(["127.0.0.1:1", "127.0.0.1:2"]).with_replication(2);
        let rep = Replicator::start(core, spec, "127.0.0.1:1", HaState::new());
        let t0 = Instant::now();
        rep.wait_quorum("t", 0, 5).unwrap();
        assert!(t0.elapsed() >= QUORUM_WAIT, "first wait pays the deadline");
        let t0 = Instant::now();
        rep.wait_quorum("t", 0, 5).unwrap();
        assert!(t0.elapsed() < QUORUM_WAIT / 2, "benched follower skips the wait");
        rep.stop();
    }

    #[test]
    fn update_spec_retargets_followers() {
        let core = BrokerCore::new();
        core.create_topic("t", 1).unwrap();
        let spec = ClusterSpec::new(["127.0.0.1:1", "127.0.0.1:2"]).with_replication(2);
        let rep = Replicator::start(core, spec.clone(), "127.0.0.1:1", HaState::new());
        assert_eq!(rep.followers("t", 0), vec!["127.0.0.1:2".to_string()]);
        // Draining :2 re-clamps replication to the lone survivor.
        rep.update_spec(spec.removed("127.0.0.1:2"));
        assert!(rep.followers("t", 0).is_empty());
        // A stale spec (older epoch) cannot roll membership back.
        rep.update_spec(spec);
        assert!(rep.followers("t", 0).is_empty());
        rep.stop();
    }

    #[test]
    fn deposed_replicator_fails_quorum_waits() {
        let core = BrokerCore::new();
        core.create_topic("t", 1).unwrap();
        let spec =
            ClusterSpec::new(["127.0.0.1:1", "127.0.0.1:2"]).with_replication(2);
        let ha = HaState::new();
        let rep = Replicator::start(core, spec, "127.0.0.1:1", Arc::clone(&ha));
        ha.depose("t", 0, 4, "127.0.0.1:2");
        match rep.wait_quorum("t", 0, 1) {
            Err(BrokerError::Fenced { epoch, by }) => {
                assert_eq!(epoch, 4);
                assert_eq!(by, "127.0.0.1:2");
            }
            other => panic!("expected Fenced, got {other:?}"),
        }
        rep.stop();
    }
}
