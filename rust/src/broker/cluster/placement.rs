//! Topic placement: the deterministic `(topic, partition) → broker` map
//! every cluster participant computes locally.
//!
//! The paper's Distributed Stream Library hides the streaming back-end
//! behind a homogeneous stream representation (§4.2) precisely so the
//! back-end can grow from one broker to many without touching application
//! code. Placement is the piece that makes "many" work without a
//! coordination service: a **rendezvous hash** (highest-random-weight)
//! over the member list. Every client and every broker evaluates the same
//! pure function over the same [`ClusterSpec`], so they agree on ownership
//! with zero messages — and a broker that receives traffic for a partition
//! it does not own answers `NotOwner { owner_addr }` so stale clients
//! self-correct (see [`super::client::ClusterClient`]).
//!
//! Rendezvous hashing keeps the map stable under membership change: when a
//! member is added or removed, only the partitions whose argmax changes
//! move — on average `1/N` of them — unlike modulo placement, which
//! reshuffles almost everything.
//!
//! Since PR 7 the same weights also order the **replica list**: sorting
//! members by descending weight gives `[leader, follower, follower, …]`,
//! of which the top `replication` entries host the partition. The top-1
//! is the same argmax as before, so replication is placement-compatible
//! with single-owner clusters — and when the leader dies, the next
//! in-line follower is the natural promotion target (removing the leader
//! from the member list makes today's second exactly tomorrow's first).

use crate::broker::protocol::ClusterMetaWire;

/// Version of the placement function. Carried in [`ClusterMetaWire`] so a
/// future algorithm change can be detected across mixed-version clusters
/// instead of silently splitting ownership.
pub const PLACEMENT_VERSION: u32 = 1;

/// The shared cluster description: an epoch, the placement version and the
/// sorted member address list. Built from a static seed list (CLI flags or
/// env); every participant holding an equal `ClusterSpec` computes equal
/// ownership.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterSpec {
    /// Bumped when the member list changes (static clusters stay at 0).
    pub epoch: u64,
    /// Placement algorithm version (see [`PLACEMENT_VERSION`]).
    pub version: u32,
    /// Sorted, deduplicated broker addresses.
    members: Vec<String>,
    /// Replicas per partition (leader + followers). `1` = the pre-PR 7
    /// single-owner behaviour; always clamped to the member count.
    replication: usize,
}

impl ClusterSpec {
    /// Build a spec from a seed list. Members are sorted and deduplicated
    /// so every participant normalises to the same list regardless of the
    /// order its flags were given in.
    pub fn new<I, S>(seeds: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut members: Vec<String> = seeds.into_iter().map(Into::into).collect();
        members.sort();
        members.dedup();
        Self { epoch: 0, version: PLACEMENT_VERSION, members, replication: 1 }
    }

    /// Builder: set the replicas-per-partition count (clamped to
    /// `[1, member count]` so a degenerate flag never produces an empty or
    /// impossible replica list).
    pub fn with_replication(mut self, replication: usize) -> Self {
        self.replication = replication.clamp(1, self.members.len().max(1));
        self
    }

    /// Replicas per partition (1 = unreplicated).
    pub fn replication(&self) -> usize {
        self.replication
    }

    /// The sorted member addresses.
    pub fn members(&self) -> &[String] {
        &self.members
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn contains(&self, addr: &str) -> bool {
        self.members.iter().any(|m| m == addr)
    }

    /// Index of the member owning `(topic, partition)` — the rendezvous
    /// argmax. Ties break to the lower index; with a sorted member list
    /// that is deterministic across processes.
    pub fn owner_index(&self, topic: &str, partition: usize) -> usize {
        assert!(!self.members.is_empty(), "placement over an empty cluster");
        let mut best = 0usize;
        let mut best_w = weight(&self.members[0], topic, partition);
        for (i, m) in self.members.iter().enumerate().skip(1) {
            let w = weight(m, topic, partition);
            if w > best_w {
                best = i;
                best_w = w;
            }
        }
        best
    }

    /// Address of the member owning `(topic, partition)` — with
    /// replication, the partition's **leader**.
    pub fn owner(&self, topic: &str, partition: usize) -> &str {
        &self.members[self.owner_index(topic, partition)]
    }

    /// Member indices hosting `(topic, partition)`, ordered by descending
    /// rendezvous weight (ties → lower index): `[leader, follower, …]`,
    /// `min(replication, members)` entries, all distinct. Index 0 is
    /// always [`ClusterSpec::owner_index`], so a replicated spec places
    /// leaders exactly where an unreplicated one places owners.
    pub fn replica_indices(&self, topic: &str, partition: usize) -> Vec<usize> {
        assert!(!self.members.is_empty(), "placement over an empty cluster");
        let mut ranked: Vec<(u64, usize)> = self
            .members
            .iter()
            .enumerate()
            .map(|(i, m)| (weight(m, topic, partition), i))
            .collect();
        // Descending weight; equal weights break to the lower index (the
        // same tie rule as `owner_index`).
        ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        ranked.into_iter().take(self.replication).map(|(_, i)| i).collect()
    }

    /// Replica addresses of `(topic, partition)`: `[leader, follower, …]`.
    pub fn replicas(&self, topic: &str, partition: usize) -> Vec<&str> {
        self.replica_indices(topic, partition)
            .into_iter()
            .map(|i| self.members[i].as_str())
            .collect()
    }

    /// Does `addr` host `(topic, partition)` as leader or follower?
    pub fn is_replica(&self, addr: &str, topic: &str, partition: usize) -> bool {
        self.replicas(topic, partition).iter().any(|r| *r == addr)
    }

    /// Partitions of `topic` owned by `addr` under a `partitions`-wide
    /// layout.
    pub fn owned_by(&self, addr: &str, topic: &str, partitions: usize) -> Vec<usize> {
        (0..partitions).filter(|&p| self.owner(topic, p) == addr).collect()
    }

    /// Owner address → owned partitions for one topic (only owners with at
    /// least one partition appear). Iteration order follows the member
    /// list, so it is deterministic too.
    pub fn owners(&self, topic: &str, partitions: usize) -> Vec<(String, Vec<usize>)> {
        let mut out: Vec<(String, Vec<usize>)> = Vec::new();
        for p in 0..partitions {
            let addr = self.owner(topic, p);
            match out.iter_mut().find(|(a, _)| a.as_str() == addr) {
                Some((_, ps)) => ps.push(p),
                None => out.push((addr.to_string(), vec![p])),
            }
        }
        out
    }

    /// Derive the spec after `member` joins: same placement version, the
    /// member list re-normalised with the newcomer, replication re-clamped
    /// and — the part that makes the change *observable* — the epoch bumped
    /// past this spec's. Every participant comparing epochs adopts the
    /// higher one, so a join propagates by gossip without a coordinator.
    /// Joining a member that is already present still bumps the epoch (the
    /// caller asked for a membership event; an idempotent re-join must
    /// still win the gossip race against the stale spec).
    pub fn joined(&self, member: &str) -> ClusterSpec {
        let mut next =
            Self::new(self.members.iter().cloned().chain(std::iter::once(member.to_string())))
                .with_replication(self.replication);
        next.epoch = self.epoch + 1;
        next.version = self.version;
        next
    }

    /// Derive the spec after `member` leaves (drain/decommission): the
    /// member list without it, replication re-clamped to the survivors,
    /// epoch bumped. Removing the last member is the caller's error —
    /// placement over an empty cluster is meaningless — so the survivors
    /// list may be empty here and callers must check [`Self::is_empty`]
    /// before using the result for ownership.
    pub fn removed(&self, member: &str) -> ClusterSpec {
        let mut next = Self::new(self.members.iter().filter(|m| *m != member).cloned())
            .with_replication(self.replication);
        next.epoch = self.epoch + 1;
        next.version = self.version;
        next
    }

    /// Wire form (the `ClusterMeta` response payload).
    pub fn to_wire(&self) -> ClusterMetaWire {
        ClusterMetaWire {
            epoch: self.epoch,
            version: self.version,
            members: self.members.clone(),
            replication: self.replication as u32,
        }
    }

    /// Rehydrate from the wire form (re-normalising the member list).
    /// A pre-replication peer sends `replication: 0`, which clamps to 1.
    pub fn from_wire(wire: &ClusterMetaWire) -> Self {
        let mut spec = Self::new(wire.members.iter().cloned())
            .with_replication((wire.replication as usize).max(1));
        spec.epoch = wire.epoch;
        spec.version = wire.version;
        spec
    }
}

/// Rendezvous weight of `(member, topic, partition)` — built on the same
/// FNV-1a fold as the broker partitioner (`topic::fnv1a`), so there is
/// exactly one hash implementation in the tree. `0xFF` separators keep
/// `("ab", "c")` and `("a", "bc")` from colliding.
fn weight(member: &str, topic: &str, partition: usize) -> u64 {
    use crate::broker::topic::{fnv1a, FNV_OFFSET};
    let mut h = fnv1a(FNV_OFFSET, member.as_bytes());
    h = fnv1a(h, &[0xFF]);
    h = fnv1a(h, topic.as_bytes());
    h = fnv1a(h, &[0xFF]);
    fnv1a(h, &(partition as u64).to_le_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n: usize) -> ClusterSpec {
        ClusterSpec::new((0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)))
    }

    #[test]
    fn normalises_member_order_and_duplicates() {
        let a = ClusterSpec::new(["b:1", "a:1", "b:1"]);
        let b = ClusterSpec::new(["a:1", "b:1"]);
        assert_eq!(a, b);
        assert_eq!(a.members(), &["a:1".to_string(), "b:1".to_string()]);
    }

    #[test]
    fn ownership_is_deterministic_across_instances() {
        let a = spec(4);
        let b = spec(4);
        for p in 0..64 {
            assert_eq!(a.owner("t", p), b.owner("t", p));
            assert_eq!(a.owner_index("t", p), b.owner_index("t", p));
        }
    }

    #[test]
    fn ownership_spreads_across_members() {
        let s = spec(4);
        let owners = s.owners("events", 64);
        assert!(owners.len() >= 3, "64 partitions over 4 members must spread: {owners:?}");
        let total: usize = owners.iter().map(|(_, ps)| ps.len()).sum();
        assert_eq!(total, 64, "every partition has exactly one owner");
        // No member should own a wildly disproportionate share.
        for (addr, ps) in &owners {
            assert!(ps.len() <= 40, "{addr} owns {} of 64 partitions", ps.len());
        }
    }

    #[test]
    fn removing_a_member_only_moves_its_partitions() {
        let four = spec(4);
        let mut members = four.members().to_vec();
        let removed = members.remove(3);
        let three = ClusterSpec::new(members);
        let mut moved = 0;
        for p in 0..64 {
            let before = four.owner("t", p);
            let after = three.owner("t", p);
            if before == removed {
                moved += 1;
                assert_ne!(after, removed);
            } else {
                assert_eq!(before, after, "partition {p} moved although its owner survived");
            }
        }
        assert!(moved > 0, "the removed member owned nothing — degenerate test");
    }

    #[test]
    fn single_member_owns_everything() {
        let s = spec(1);
        for p in 0..16 {
            assert_eq!(s.owner_index("t", p), 0);
        }
        assert_eq!(s.owned_by(&s.members()[0].clone(), "t", 16).len(), 16);
    }

    #[test]
    fn wire_roundtrip_preserves_placement() {
        let s = spec(3);
        let back = ClusterSpec::from_wire(&s.to_wire());
        assert_eq!(back, s);
        for p in 0..32 {
            assert_eq!(back.owner("x", p), s.owner("x", p));
        }
    }

    #[test]
    fn replica_lists_are_distinct_and_lead_with_the_owner() {
        let s = spec(4).with_replication(3);
        for p in 0..64 {
            let reps = s.replica_indices("t", p);
            assert_eq!(reps.len(), 3);
            let uniq: std::collections::HashSet<usize> = reps.iter().copied().collect();
            assert_eq!(uniq.len(), 3, "replicas must be distinct members");
            assert_eq!(reps[0], s.owner_index("t", p), "top-1 must stay the argmax owner");
        }
    }

    #[test]
    fn replication_clamps_to_member_count() {
        let s = spec(2).with_replication(9);
        assert_eq!(s.replication(), 2);
        assert_eq!(s.replicas("t", 0).len(), 2);
        let s1 = spec(3).with_replication(0);
        assert_eq!(s1.replication(), 1, "replication 0 is meaningless — clamp to 1");
    }

    #[test]
    fn killed_leader_promotes_the_next_ranked_follower() {
        // Removing the leader from the member list must make the old
        // second-ranked replica the new leader — that is what makes the
        // ordered list a promotion order.
        let four = spec(4).with_replication(2);
        for p in 0..32 {
            let reps: Vec<String> =
                four.replicas("t", p).into_iter().map(str::to_string).collect();
            let survivors: Vec<String> =
                four.members().iter().filter(|m| **m != reps[0]).cloned().collect();
            let three = ClusterSpec::new(survivors).with_replication(2);
            assert_eq!(
                three.owner("t", p),
                reps[1],
                "partition {p}: the surviving follower must inherit leadership"
            );
        }
    }

    #[test]
    fn wire_roundtrip_preserves_replication() {
        let s = spec(3).with_replication(2);
        let back = ClusterSpec::from_wire(&s.to_wire());
        assert_eq!(back, s);
        assert_eq!(back.replication(), 2);
        for p in 0..16 {
            assert_eq!(back.replicas("t", p), s.replicas("t", p));
        }
    }

    #[test]
    fn joined_bumps_epoch_and_moves_a_bounded_share() {
        let three = spec(3);
        let four = three.joined("127.0.0.1:9003");
        assert_eq!(four.epoch, three.epoch + 1);
        assert_eq!(four.version, three.version);
        assert_eq!(four.len(), 4);
        let parts = 64usize;
        let mut moved = 0;
        for p in 0..parts {
            let before = three.owner("t", p);
            let after = four.owner("t", p);
            if before != after {
                moved += 1;
                assert_eq!(after, "127.0.0.1:9003", "partition {p} moved to a non-joiner");
            }
        }
        // Rendezvous: the joiner takes ~1/N; allow generous slack but
        // reject a reshuffle (modulo placement would move ~3/4 here).
        assert!(moved > 0, "the joiner took nothing — degenerate placement");
        assert!(moved <= parts / 2, "join moved {moved}/{parts} partitions — not rendezvous");
    }

    #[test]
    fn removed_bumps_epoch_and_moves_only_the_leaver_share() {
        let four = spec(4);
        let leaver = four.members()[2].clone();
        let three = four.removed(&leaver);
        assert_eq!(three.epoch, four.epoch + 1);
        assert_eq!(three.len(), 3);
        assert!(!three.contains(&leaver));
        for p in 0..64 {
            if four.owner("t", p) != leaver {
                assert_eq!(four.owner("t", p), three.owner("t", p), "partition {p} swapped owners needlessly");
            } else {
                assert_ne!(three.owner("t", p), leaver);
            }
        }
    }

    #[test]
    fn joined_is_idempotent_on_members_but_not_on_epoch() {
        let s = spec(3);
        let again = s.joined(&s.members()[0].clone());
        assert_eq!(again.members(), s.members(), "re-joining an existing member adds nothing");
        assert_eq!(again.epoch, s.epoch + 1, "but the membership event still bumps the epoch");
    }

    #[test]
    fn removed_reclamps_replication_to_survivors() {
        let s = spec(2).with_replication(2);
        let one = s.removed(&s.members()[1].clone());
        assert_eq!(one.replication(), 1, "replication must re-clamp to the survivor count");
    }

    #[test]
    fn different_topics_place_independently() {
        let s = spec(4);
        let a: Vec<usize> = (0..16).map(|p| s.owner_index("topic-a", p)).collect();
        let b: Vec<usize> = (0..16).map(|p| s.owner_index("topic-b", p)).collect();
        assert_ne!(a, b, "two topics should not share a placement layout");
    }
}
