//! `OffsetStore`: the per-topic consumer-group cursor journal.
//!
//! An append-only file of CRC-framed entries, one per cursor change
//! (claim, commit, crash rewind). Last entry per `(group, partition)` wins.
//! The journal is replayed on open (torn tail truncated, like segments) and
//! **compacted** — both at open and in place whenever the file outgrows a
//! small multiple of its live size — so it stays O(groups × partitions) on
//! disk no matter how many fetches run between restarts.
//!
//! Restart semantics: the broker replays `committed` as the resume point —
//! claims made by consumers that died with the process are redelivered
//! (at-least-once), exactly like [`GroupState::rewind_to_committed`] after
//! a member crash. The claim `position` is journalled too, for
//! introspection and forensics.
//!
//! [`GroupState::rewind_to_committed`]: crate::broker::group::GroupState::rewind_to_committed

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{self, Write};
use std::path::{Path, PathBuf};

use log::{error, warn};

// The `Wire` impl for `AssignmentMode` lives in `broker::protocol`.
use crate::broker::group::AssignmentMode;
use crate::util::bytes::ByteWriter;
use crate::util::fault;
use crate::util::wire::Wire;

use super::{crc32, scan_frames};

/// Floor for the compaction trigger: journals smaller than this are never
/// rewritten mid-flight.
const COMPACT_MIN_BYTES: u64 = 64 * 1024;

/// One journalled cursor state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OffsetEntry {
    pub group: String,
    pub mode: AssignmentMode,
    pub partition: u64,
    /// Claim position at journal time (forensics; not the resume point).
    pub position: u64,
    /// Commit point — where the group resumes after a restart.
    pub committed: u64,
}

crate::wire_struct!(OffsetEntry {
    group: String,
    mode: AssignmentMode,
    partition: u64,
    position: u64,
    committed: u64,
});

/// Append-only cursor journal for one topic, compacted when it outgrows
/// its live entry set.
#[derive(Debug)]
pub struct OffsetStore {
    path: PathBuf,
    file: Option<File>,
    /// Last entry per `(group, partition)` — what a compaction rewrites.
    live: BTreeMap<(String, u64), OffsetEntry>,
    /// Current file length.
    bytes: u64,
    /// Compact when `bytes` reaches this (re-derived after each compaction).
    threshold: u64,
    scratch: ByteWriter,
    failed: bool,
}

/// Append one `[len|crc|body]` frame for `e` — the single frame writer
/// shared by `note` and both compaction paths (the scanner side is
/// [`scan_frames`]).
fn put_frame(w: &mut ByteWriter, e: &OffsetEntry) {
    let body = {
        let mut b = ByteWriter::new();
        e.encode(&mut b);
        b.into_vec()
    };
    w.put_u32(body.len() as u32);
    w.put_u32(crc32(&body));
    w.put_raw(&body);
}

/// Serialise a whole live set as one compacted journal image.
fn compacted_image(live: &BTreeMap<(String, u64), OffsetEntry>) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(live.len() * 64);
    for e in live.values() {
        put_frame(&mut w, e);
    }
    w.into_vec()
}

impl OffsetStore {
    /// Open the journal at `path`, replay it (last entry per
    /// `(group, partition)` wins, torn tail discarded), compact it on disk
    /// and return the live entries sorted by `(group, partition)`.
    pub fn open(path: &Path) -> io::Result<(Self, Vec<OffsetEntry>)> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let data = std::fs::read(path).unwrap_or_default();
        let mut live: BTreeMap<(String, u64), OffsetEntry> = BTreeMap::new();
        let valid = scan_frames(&data, |_, body| match OffsetEntry::decode_exact(body) {
            Ok(e) => {
                live.insert((e.group.clone(), e.partition), e);
                true
            }
            Err(_) => false,
        });
        if valid < data.len() {
            warn!(
                "offset journal {path:?}: discarding {} torn tail bytes",
                data.len() - valid
            );
        }
        // Compact: rewrite only the live entries (atomic tmp + rename).
        let image = compacted_image(&live);
        let tmp = path.with_extension("log.tmp");
        std::fs::write(&tmp, &image)?;
        std::fs::rename(&tmp, path)?;
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        let bytes = image.len() as u64;
        let entries: Vec<OffsetEntry> = live.values().cloned().collect();
        Ok((
            Self {
                path: path.to_path_buf(),
                file: Some(file),
                live,
                bytes,
                threshold: COMPACT_MIN_BYTES.max(bytes * 4),
                scratch: ByteWriter::new(),
                failed: false,
            },
            entries,
        ))
    }

    /// Journal one cursor change; compacts in place when the file has
    /// outgrown its live set. I/O errors degrade the store (logged)
    /// instead of failing the fetch/commit path.
    pub fn note(&mut self, e: &OffsetEntry) {
        if self.failed {
            return;
        }
        // Fault seam: a scripted journal-append failure (exercises the
        // degrade path without real disk trouble).
        if fault::active()
            && fault::check(fault::site::OFFSETS_NOTE, &self.path.to_string_lossy()).is_some()
        {
            let err = fault::injected_error(fault::site::OFFSETS_NOTE);
            self.degrade("append", &err);
            return;
        }
        self.live.insert((e.group.clone(), e.partition), e.clone());
        self.scratch.clear();
        put_frame(&mut self.scratch, e);
        let res = match self.file.as_mut() {
            Some(f) => f.write_all(self.scratch.as_slice()),
            None => Err(io::Error::new(io::ErrorKind::Other, "journal not open")),
        };
        match res {
            Ok(()) => {
                self.bytes += self.scratch.len() as u64;
                if self.bytes >= self.threshold {
                    self.compact();
                }
            }
            Err(err) => self.degrade("append", &err),
        }
    }

    /// Rewrite the journal as just its live entries (atomic tmp + rename),
    /// then re-derive the next compaction threshold.
    fn compact(&mut self) {
        let res = (|| -> io::Result<()> {
            let image = compacted_image(&self.live);
            let tmp = self.path.with_extension("log.tmp");
            std::fs::write(&tmp, &image)?;
            std::fs::rename(&tmp, &self.path)?;
            self.file = Some(OpenOptions::new().create(true).append(true).open(&self.path)?);
            self.bytes = image.len() as u64;
            self.threshold = COMPACT_MIN_BYTES.max(self.bytes * 4);
            Ok(())
        })();
        if let Err(err) = res {
            self.degrade("compact", &err);
        }
    }

    fn degrade(&mut self, what: &str, err: &io::Error) {
        error!(
            "offset journal {:?}: {what} failed ({err}) — cursor persistence degraded",
            self.path
        );
        self.failed = true;
    }

    /// True after an I/O error degraded this journal.
    pub fn failed(&self) -> bool {
        self.failed
    }

    /// Current journal length in bytes (tests).
    pub fn len_bytes(&self) -> u64 {
        self.bytes
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hybridws-offs-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d.join("offsets.log")
    }

    fn entry(group: &str, partition: u64, position: u64, committed: u64) -> OffsetEntry {
        OffsetEntry {
            group: group.into(),
            mode: AssignmentMode::Shared,
            partition,
            position,
            committed,
        }
    }

    #[test]
    fn journal_replays_last_entry_per_cursor() {
        let path = tmp_path("replay");
        let (mut store, entries) = OffsetStore::open(&path).unwrap();
        assert!(entries.is_empty());
        store.note(&entry("g1", 0, 3, 0));
        store.note(&entry("g1", 0, 7, 4)); // supersedes the first
        store.note(&entry("g1", 1, 2, 2));
        store.note(&entry("g2", 0, 9, 9));
        assert!(!store.failed());
        drop(store);
        let (_, entries) = OffsetStore::open(&path).unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0], entry("g1", 0, 7, 4));
        assert_eq!(entries[1], entry("g1", 1, 2, 2));
        assert_eq!(entries[2], entry("g2", 0, 9, 9));
        std::fs::remove_dir_all(path.parent().unwrap()).unwrap();
    }

    #[test]
    fn open_compacts_the_journal() {
        let path = tmp_path("compact");
        let (mut store, _) = OffsetStore::open(&path).unwrap();
        for i in 0..200u64 {
            store.note(&entry("g", 0, i, i));
        }
        drop(store);
        let grown = std::fs::metadata(&path).unwrap().len();
        let (_, entries) = OffsetStore::open(&path).unwrap();
        assert_eq!(entries.len(), 1);
        let compacted = std::fs::metadata(&path).unwrap().len();
        assert!(compacted < grown / 10, "{compacted} vs {grown}");
        std::fs::remove_dir_all(path.parent().unwrap()).unwrap();
    }

    #[test]
    fn journal_growth_is_bounded_between_restarts() {
        // A hot consumer journalling one cursor forever must trigger the
        // in-place compaction: the file stays near COMPACT_MIN_BYTES, not
        // O(fetches).
        let path = tmp_path("bounded");
        let (mut store, _) = OffsetStore::open(&path).unwrap();
        // ~40 B/frame → 100k notes ≈ 4 MB without compaction.
        for i in 0..100_000u64 {
            store.note(&entry("g", i % 4, i, i));
        }
        assert!(!store.failed());
        assert!(
            store.len_bytes() < 2 * COMPACT_MIN_BYTES,
            "journal must compact in place, got {} bytes",
            store.len_bytes()
        );
        drop(store);
        let (_, entries) = OffsetStore::open(&path).unwrap();
        assert_eq!(entries.len(), 4, "one live entry per partition survives");
        assert_eq!(entries[3], entry("g", 3, 99_999, 99_999));
        std::fs::remove_dir_all(path.parent().unwrap()).unwrap();
    }

    #[test]
    fn torn_tail_is_discarded() {
        let path = tmp_path("torn");
        let (mut store, _) = OffsetStore::open(&path).unwrap();
        store.note(&entry("g", 0, 5, 5));
        store.note(&entry("g", 1, 6, 6));
        drop(store);
        let len = std::fs::metadata(&path).unwrap().len();
        OpenOptions::new().write(true).open(&path).unwrap().set_len(len - 3).unwrap();
        let (_, entries) = OffsetStore::open(&path).unwrap();
        assert_eq!(entries, vec![entry("g", 0, 5, 5)], "torn final entry dropped");
        std::fs::remove_dir_all(path.parent().unwrap()).unwrap();
    }

    #[test]
    fn entry_wire_roundtrip() {
        let e = OffsetEntry {
            group: "app".into(),
            mode: AssignmentMode::Partitioned,
            partition: 3,
            position: 10,
            committed: 8,
        };
        assert_eq!(OffsetEntry::decode_exact(&e.encode_vec()).unwrap(), e);
    }
}
