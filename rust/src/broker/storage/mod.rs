//! Durable broker storage: a segmented on-disk log per partition plus a
//! per-topic consumer-offset journal.
//!
//! The paper hides the streaming back-end behind the DistroStream API so it
//! can ride on durable brokers like Kafka (§4). This subsystem gives our
//! Kafka substitute the matching durability slice:
//!
//! - [`log::DiskLog`] — fixed-size segments ([`segment::Segment`]) holding
//!   CRC-framed records, a sparse offset index rebuilt on startup, torn-tail
//!   truncation, and time/size retention that drops whole sealed segments.
//! - [`offsets::OffsetStore`] — an append-only journal of consumer-group
//!   cursors, compacted on open, so groups resume from their committed
//!   offsets after a broker restart.
//! - [`StorageMode`] / [`BrokerConfig`] — per-topic storage selection; the
//!   default stays [`StorageMode::Memory`], which is byte-for-byte the
//!   pre-durability broker (same hot path, same Arc-identity zero-copy).
//!
//! Layout under a disk topic:
//!
//! ```text
//! <data_dir>/<topic>/
//!     p0/00000000000000000000.seg     segment files (base offset in name)
//!     p0/meta.bin                     persisted log-start offset
//!     p1/...
//!     offsets.log                     consumer-group cursor journal
//! ```
//!
//! Durability contract: every append is written to the OS before the
//! publish acks (process-crash safe); files are fsynced when a segment
//! seals. Recovery re-scans every frame, verifies CRCs and offset density,
//! and truncates — never propagates — a torn tail.

pub mod log;
pub mod offsets;
pub mod segment;

use std::path::{Path, PathBuf};

// `self::` disambiguates the local `log` module from the `log` crate.
pub use self::log::DiskLog;
pub use self::offsets::{OffsetEntry, OffsetStore};
pub use self::segment::Segment;

/// Default segment size (8 MiB) — small enough that retention has useful
/// granularity, large enough that the sparse index stays tiny.
pub const DEFAULT_SEGMENT_BYTES: u64 = 8 * 1024 * 1024;

/// What to keep on disk. `None` fields mean "keep forever"; retention only
/// ever drops whole **sealed** segments (the active segment is never
/// reclaimed), so enforcement is O(segments), not O(records).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Retention {
    /// Drop oldest sealed segments while the partition exceeds this many
    /// bytes on disk.
    pub max_bytes: Option<u64>,
    /// Drop sealed segments whose newest record is older than this.
    pub max_age_ms: Option<u64>,
}

impl Retention {
    /// Keep everything (the default).
    pub fn keep_forever() -> Self {
        Self::default()
    }

    pub fn max_bytes(mut self, n: u64) -> Self {
        self.max_bytes = Some(n);
        self
    }

    pub fn max_age_ms(mut self, ms: u64) -> Self {
        self.max_age_ms = Some(ms);
        self
    }
}

/// Per-topic storage backend selection.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum StorageMode {
    /// In-memory only (the pre-durability broker; zero-copy hot path).
    #[default]
    Memory,
    /// Segmented on-disk log under `data_dir/<topic>/p<partition>/`.
    Disk { data_dir: PathBuf, segment_bytes: u64, retention: Retention },
}

impl StorageMode {
    /// Disk mode with default segment size and keep-forever retention.
    pub fn disk(data_dir: impl Into<PathBuf>) -> Self {
        StorageMode::Disk {
            data_dir: data_dir.into(),
            segment_bytes: DEFAULT_SEGMENT_BYTES,
            retention: Retention::default(),
        }
    }

    /// Override the segment size (no-op on `Memory`).
    pub fn segment_bytes(self, n: u64) -> Self {
        match self {
            StorageMode::Disk { data_dir, retention, .. } => {
                StorageMode::Disk { data_dir, segment_bytes: n.max(1), retention }
            }
            m => m,
        }
    }

    /// Override the retention policy (no-op on `Memory`).
    pub fn retention(self, retention: Retention) -> Self {
        match self {
            StorageMode::Disk { data_dir, segment_bytes, .. } => {
                StorageMode::Disk { data_dir, segment_bytes, retention }
            }
            m => m,
        }
    }

    pub fn is_disk(&self) -> bool {
        matches!(self, StorageMode::Disk { .. })
    }
}

/// Broker-wide storage configuration: a default mode plus per-topic
/// overrides. [`super::embedded::BrokerCore::with_config`] recovers every
/// durable topic found under the configured data dirs at boot.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct BrokerConfig {
    pub default_mode: StorageMode,
    /// Exact-name overrides, checked before `default_mode`.
    pub topic_modes: Vec<(String, StorageMode)>,
    /// Boot recovery deletes stale [`is_session_scoped_topic`] dirs
    /// (anonymous `dstream-<id>` topics) instead of re-opening them.
    /// Enabled by deployments that own the dstream namespace
    /// (`CometBuilder::data_dir`); off by default so a standalone broker
    /// never deletes a user topic that merely matches the pattern.
    pub reap_session_scoped: bool,
}

impl BrokerConfig {
    /// Everything in memory (identical to `BrokerCore::new`).
    pub fn memory() -> Self {
        Self::default()
    }

    /// Every topic durable under `data_dir` (default segments/retention).
    pub fn disk(data_dir: impl Into<PathBuf>) -> Self {
        Self {
            default_mode: StorageMode::disk(data_dir),
            topic_modes: Vec::new(),
            reap_session_scoped: false,
        }
    }

    /// Replace the default mode (builder style).
    pub fn default_mode(mut self, mode: StorageMode) -> Self {
        self.default_mode = mode;
        self
    }

    /// Per-topic override (builder style).
    pub fn topic_mode(mut self, topic: &str, mode: StorageMode) -> Self {
        self.topic_modes.push((topic.to_string(), mode));
        self
    }

    /// Enable boot-time reaping of stale session-scoped (anonymous
    /// `dstream-<id>`) topic dirs — see the field docs.
    pub fn reap_session_scoped(mut self, on: bool) -> Self {
        self.reap_session_scoped = on;
        self
    }

    /// Storage mode for one topic.
    pub fn mode_for(&self, topic: &str) -> &StorageMode {
        self.topic_modes
            .iter()
            .find(|(t, _)| t == topic)
            .map(|(_, m)| m)
            .unwrap_or(&self.default_mode)
    }

    /// True when any topic could be durable.
    pub fn any_disk(&self) -> bool {
        self.default_mode.is_disk() || self.topic_modes.iter().any(|(_, m)| m.is_disk())
    }
}

// ---- CRC32 (IEEE) ------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// Incremental CRC32 (IEEE, the Kafka/zlib polynomial) — lets the segment
/// writer checksum header + key + value slices without concatenating them.
#[derive(Debug, Clone, Copy)]
pub struct Crc32(u32);

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self(0xFFFF_FFFF)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = CRC_TABLE[((self.0 ^ b as u32) & 0xFF) as usize] ^ (self.0 >> 8);
        }
    }

    pub fn finish(self) -> u32 {
        self.0 ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC32 of a buffer.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

// ---- frame scanning (shared by segments and the offsets journal) -------

/// Byte overhead per frame: `body_len: u32` + `crc: u32`.
pub(crate) const FRAME_HEADER: usize = 8;

/// Scan `data` as a sequence of `[len][crc][body]` frames, calling
/// `on_body(frame_start, body)` for each valid frame. Returns the length of
/// the valid prefix — anything past it (a torn or corrupt tail) should be
/// truncated by the caller. `on_body` returning `false` rejects the frame
/// (semantic corruption, e.g. a non-dense offset), also ending the scan.
pub(crate) fn scan_frames(data: &[u8], mut on_body: impl FnMut(usize, &[u8]) -> bool) -> usize {
    let mut pos = 0usize;
    loop {
        let rest = data.len() - pos;
        if rest < FRAME_HEADER {
            return pos; // torn header (or clean end when rest == 0)
        }
        let len = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(data[pos + 4..pos + 8].try_into().unwrap());
        if len > crate::util::bytes::MAX_LEN as usize || rest - FRAME_HEADER < len {
            return pos; // insane length or torn body
        }
        let body = &data[pos + FRAME_HEADER..pos + FRAME_HEADER + len];
        if crc32(body) != crc || !on_body(pos, body) {
            return pos; // bit rot or semantic corruption
        }
        pos += FRAME_HEADER + len;
    }
}

// ---- topic directory names ---------------------------------------------

/// Escape a topic name into a filesystem-safe directory name. Reversible
/// (`%XX` escapes), so boot-time recovery can list `<data_dir>/*` and
/// reconstruct the topic names.
pub fn topic_dir_name(topic: &str) -> String {
    let mut out = String::with_capacity(topic.len());
    for b in topic.bytes() {
        match b {
            b'a'..=b'z' | b'A'..=b'Z' | b'0'..=b'9' | b'.' | b'_' | b'-' => out.push(b as char),
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

/// True for topic names of **anonymous** object streams (`dstream-<id>`,
/// see `crate::dstream::api::topic_for`). Stream ids are dense per registry
/// session, so these topics are only meaningful within one deployment
/// lifetime: boot recovery deletes them instead of resurrecting them — a
/// new session's stream id 0 must see an empty topic, not a previous
/// session's leftovers. Aliased streams use the disjoint `dstream-a-…`
/// namespace and do recover.
pub fn is_session_scoped_topic(name: &str) -> bool {
    name.strip_prefix("dstream-")
        .is_some_and(|rest| !rest.is_empty() && rest.bytes().all(|b| b.is_ascii_digit()))
}

/// True when `dir` has the on-disk structure of a broker topic: at least
/// one `p<N>` partition directory or an `offsets.log` journal. Boot
/// recovery uses this to leave foreign directories in a shared data dir
/// alone instead of registering them as phantom topics (and writing
/// segment files into them).
pub fn looks_like_topic_dir(dir: &Path) -> bool {
    if dir.join("offsets.log").is_file() {
        return true;
    }
    let Ok(entries) = std::fs::read_dir(dir) else {
        return false;
    };
    entries.flatten().any(|e| {
        e.path().is_dir()
            && e.file_name()
                .to_str()
                .and_then(|n| n.strip_prefix('p'))
                .is_some_and(|rest| !rest.is_empty() && rest.bytes().all(|b| b.is_ascii_digit()))
    })
}

/// Invert [`topic_dir_name`]. `None` on malformed escapes (foreign dirs).
pub fn topic_from_dir_name(dir: &str) -> Option<String> {
    let bytes = dir.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            let hex = bytes.get(i + 1..i + 3)?;
            let s = std::str::from_utf8(hex).ok()?;
            out.push(u8::from_str_radix(s, 16).ok()?);
            i += 3;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8(out).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // The classic IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // Incremental == one-shot.
        let mut c = Crc32::new();
        c.update(b"1234");
        c.update(b"56789");
        assert_eq!(c.finish(), 0xCBF4_3926);
    }

    #[test]
    fn scan_frames_accepts_valid_and_truncates_torn() {
        let mut data = Vec::new();
        for body in [&b"hello"[..], &b""[..], &b"world!"[..]] {
            data.extend_from_slice(&(body.len() as u32).to_le_bytes());
            data.extend_from_slice(&crc32(body).to_le_bytes());
            data.extend_from_slice(body);
        }
        let full = data.len();
        let mut seen = Vec::new();
        assert_eq!(scan_frames(&data, |_, b| {
            seen.push(b.to_vec());
            true
        }), full);
        assert_eq!(seen.len(), 3);
        // Torn tail: every proper prefix of the final frame scans to the
        // boundary after the second frame.
        let second_end = full - (FRAME_HEADER + 6);
        for cut in second_end..full {
            assert_eq!(scan_frames(&data[..cut], |_, _| true), second_end, "cut {cut}");
        }
        // Bit rot in a body is caught by the CRC.
        let mut rotten = data.clone();
        rotten[FRAME_HEADER + 1] ^= 0x40;
        assert_eq!(scan_frames(&rotten, |_, _| true), 0);
    }

    #[test]
    fn topic_dir_name_roundtrips() {
        for t in ["dstream-3", "plain", "has space", "slash/dots..", "pct%20", "uni-ü"] {
            let dir = topic_dir_name(t);
            assert!(dir.bytes().all(|b| b.is_ascii_alphanumeric() || b"._-%".contains(&b)));
            assert_eq!(topic_from_dir_name(&dir).as_deref(), Some(t), "{t}");
        }
        assert_eq!(topic_from_dir_name("bad%zz"), None);
        assert_eq!(topic_from_dir_name("bad%2"), None);
    }

    #[test]
    fn session_scoped_topic_names_are_recognised() {
        assert!(is_session_scoped_topic("dstream-0"));
        assert!(is_session_scoped_topic("dstream-123"));
        assert!(!is_session_scoped_topic("dstream-a-numbers"), "aliased streams recover");
        assert!(!is_session_scoped_topic("dstream-a-7"), "alias \"7\" is not id 7");
        assert!(!is_session_scoped_topic("dstream-"));
        assert!(!is_session_scoped_topic("events"));
    }

    #[test]
    fn topic_dir_structure_check() {
        let base = std::env::temp_dir()
            .join(format!("hybridws-topicdir-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let topic = base.join("t");
        std::fs::create_dir_all(topic.join("p0")).unwrap();
        assert!(looks_like_topic_dir(&topic), "p0/ marks a topic dir");
        let journal_only = base.join("j");
        std::fs::create_dir_all(&journal_only).unwrap();
        std::fs::write(journal_only.join("offsets.log"), b"").unwrap();
        assert!(looks_like_topic_dir(&journal_only), "offsets.log marks a topic dir");
        let foreign = base.join("photos");
        std::fs::create_dir_all(&foreign).unwrap();
        std::fs::write(foreign.join("cat.jpg"), b"meow").unwrap();
        assert!(!looks_like_topic_dir(&foreign), "foreign dirs are not topics");
        assert!(!looks_like_topic_dir(&base.join("missing")));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn storage_mode_builders() {
        let m = StorageMode::disk("/tmp/x").segment_bytes(1024).retention(
            Retention::keep_forever().max_bytes(1 << 20).max_age_ms(60_000),
        );
        match &m {
            StorageMode::Disk { data_dir, segment_bytes, retention } => {
                assert_eq!(data_dir, &PathBuf::from("/tmp/x"));
                assert_eq!(*segment_bytes, 1024);
                assert_eq!(retention.max_bytes, Some(1 << 20));
                assert_eq!(retention.max_age_ms, Some(60_000));
            }
            StorageMode::Memory => panic!("expected disk"),
        }
        assert!(m.is_disk());
        assert!(!StorageMode::Memory.segment_bytes(9).is_disk());
    }

    #[test]
    fn broker_config_mode_lookup() {
        let cfg = BrokerConfig::memory().topic_mode("hot", StorageMode::disk("/tmp/d"));
        assert!(!cfg.default_mode.is_disk());
        assert!(cfg.mode_for("hot").is_disk());
        assert!(!cfg.mode_for("other").is_disk());
        assert!(cfg.any_disk());
        assert!(!BrokerConfig::memory().any_disk());
        assert!(BrokerConfig::disk("/tmp/d").mode_for("anything").is_disk());
    }
}
