//! `DiskLog`: the segmented on-disk log behind one partition.
//!
//! The in-memory [`crate::broker::partition::PartitionLog`] stays the
//! serving path (fetches hand out the same `Arc` records, zero-copy); the
//! disk log is its durable write-through twin. On open it replays every
//! valid record back into memory, so a restarted broker serves exactly what
//! it acked before the crash.
//!
//! - **Roll**: when the active segment reaches `segment_bytes` it is sealed
//!   (fsync) and a fresh segment starting at the next offset becomes
//!   active.
//! - **Retention**: sealed segments are dropped whole while the partition
//!   exceeds [`Retention::max_bytes`] or the segment's newest record is
//!   older than [`Retention::max_age_ms`]. The advanced log start is
//!   persisted and returned so the in-memory log trims to match.
//! - **Record deletion** (the exactly-once consumer path) advances the
//!   persisted log start; sealed segments entirely below it are deleted.
//! - **Failure policy**: a disk I/O error flips the log into a degraded
//!   memory-only mode (logged loudly) rather than poisoning the publish
//!   path — the broker keeps serving, durability resumes on restart.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use log::{error, warn};

use crate::broker::record::{now_ms, Record};
use crate::util::fault;

use super::segment::{parse_segment_name, Segment};
use super::{crc32, Retention};

/// Per-partition metadata file holding the persisted log-start offset and
/// the replication fencing epoch.
const META_FILE: &str = "meta.bin";

/// Segmented append-only log for one partition.
#[derive(Debug)]
pub struct DiskLog {
    dir: PathBuf,
    segment_bytes: u64,
    retention: Retention,
    /// Sealed segments, ascending by base offset.
    sealed: Vec<Segment>,
    active: Segment,
    /// First live offset (survives restarts via `meta.bin`).
    start: u64,
    /// Replication fencing epoch (survives restarts via `meta.bin`): a
    /// restarted ex-leader rejoins knowing which leadership generation it
    /// last saw, so a stale epoch cannot silently accept writes.
    epoch: u64,
    /// Records replayed into memory by the last `open`.
    recovered: u64,
    /// Disk write failed — serve memory-only from here on.
    failed: bool,
}

impl DiskLog {
    /// Open (or create) the log under `dir`, recovering all live records.
    /// Returns the log plus the replayed records (dense offsets, ending at
    /// the recovered high watermark).
    pub fn open(
        dir: &Path,
        segment_bytes: u64,
        retention: Retention,
    ) -> io::Result<(Self, Vec<Arc<Record>>)> {
        std::fs::create_dir_all(dir)?;
        let (start, epoch) = read_meta(&dir.join(META_FILE));
        let mut bases: Vec<u64> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| parse_segment_name(e.file_name().to_str()?))
            .collect();
        bases.sort_unstable();
        let mut segments: Vec<Segment> = Vec::with_capacity(bases.len());
        let mut records: Vec<Arc<Record>> = Vec::new();
        for base in bases {
            let path = dir.join(super::segment::segment_file_name(base));
            let (seg, recs) = Segment::open(&path)?;
            if let Some(prev) = segments.last() {
                if seg.base() != prev.next_offset() {
                    // A hole between segments (a truncated predecessor):
                    // everything past it is unreachable — drop it rather
                    // than serve a log with missing offsets.
                    warn!(
                        "disk log {dir:?}: segment {base} does not follow {} — discarding it \
                         and later segments",
                        prev.next_offset()
                    );
                    seg.delete()?;
                    continue;
                }
            }
            records.extend(recs.into_iter().filter(|r| r.offset >= start));
            segments.push(seg);
        }
        let mut active = match segments.pop() {
            Some(mut last) => {
                last.reopen_append()?;
                last
            }
            None => Segment::create(dir, start)?,
        };
        // All sealed segments already fully below the persisted start are
        // dead weight from a pre-crash deletion — reap them now.
        let mut sealed = Vec::new();
        for seg in segments {
            if seg.next_offset() <= start {
                seg.delete()?;
            } else {
                sealed.push(seg);
            }
        }
        if active.next_offset() <= start && active.record_count() > 0 && sealed.is_empty() {
            // Every record in the active segment was deleted; start a fresh
            // segment at the live watermark so recovery stays O(live data).
            active.seal()?;
            let empty = Segment::create(dir, start)?;
            std::mem::replace(&mut active, empty).delete()?;
        }
        let mut log = Self {
            dir: dir.to_path_buf(),
            segment_bytes: segment_bytes.max(1),
            retention,
            sealed,
            active,
            start,
            epoch,
            recovered: 0,
            failed: false,
        };
        // Live segment files this log contributes to the process-wide
        // gauge; retention/deletion sites decrement it symmetrically.
        crate::obs_gauge!("storage.segments").add(log.sealed.len() as i64 + 1);
        // Apply retention to what was recovered: a restart must not
        // resurrect sealed segments that aged out (or overflowed the byte
        // cap) while the broker was down or idle.
        if let Some(new_start) = log.enforce_retention()? {
            records.retain(|r| r.offset >= new_start);
        }
        log.recovered = records.len() as u64;
        Ok((log, records))
    }

    /// Durably append one record (dense: `rec.offset` must be the next
    /// offset). Rolls and applies retention at segment boundaries. Returns
    /// the new log-start offset when retention advanced it (the caller
    /// trims its in-memory mirror to match). I/O errors degrade the log to
    /// memory-only instead of failing the publish.
    pub fn append(&mut self, rec: &Record) -> Option<u64> {
        if self.failed {
            return None;
        }
        let _s = crate::util::trace::span("segment.write");
        match self.try_append(rec) {
            Ok(advanced) => advanced,
            Err(e) => {
                error!(
                    "disk log {:?}: append failed ({e}) — degrading to memory-only",
                    self.dir
                );
                self.failed = true;
                None
            }
        }
    }

    fn try_append(&mut self, rec: &Record) -> io::Result<Option<u64>> {
        let mut advanced = None;
        if self.active.bytes() >= self.segment_bytes && self.active.record_count() > 0 {
            self.active.seal()?;
            let fresh = Segment::create(&self.dir, rec.offset)?;
            self.sealed.push(std::mem::replace(&mut self.active, fresh));
            crate::obs_counter!("storage.segments.sealed").inc();
            crate::obs_gauge!("storage.segments").add(1);
            advanced = self.enforce_retention()?;
        }
        let before = self.active.bytes();
        self.active.append(rec)?;
        crate::obs_counter!("storage.bytes_written")
            .add(self.active.bytes().saturating_sub(before));
        Ok(advanced)
    }

    /// Drop sealed segments violating the retention policy; persist and
    /// return the advanced start (if any).
    fn enforce_retention(&mut self) -> io::Result<Option<u64>> {
        let now = now_ms();
        let mut advanced = None;
        while let Some(oldest) = self.sealed.first() {
            let over_bytes =
                self.retention.max_bytes.is_some_and(|cap| self.bytes_on_disk() > cap);
            let too_old = self
                .retention
                .max_age_ms
                .is_some_and(|age| oldest.last_ts_ms().saturating_add(age) < now);
            if !over_bytes && !too_old {
                break;
            }
            let seg = self.sealed.remove(0);
            self.start = self.start.max(seg.next_offset());
            advanced = Some(self.start);
            seg.delete()?;
            crate::obs_counter!("storage.segments.reaped").inc();
            crate::obs_gauge!("storage.segments").sub(1);
        }
        if advanced.is_some() {
            write_meta(&self.dir.join(META_FILE), self.start, self.epoch)?;
        }
        Ok(advanced)
    }

    /// Advance the log start (record deletion); drops whole sealed segments
    /// below it and persists the new start. Degrades on I/O error like
    /// [`DiskLog::append`].
    pub fn set_start(&mut self, up_to: u64) {
        let up_to = up_to.min(self.next_offset());
        if self.failed || up_to <= self.start {
            return;
        }
        self.start = up_to;
        let res = (|| -> io::Result<()> {
            while self.sealed.first().is_some_and(|s| s.next_offset() <= up_to) {
                self.sealed.remove(0).delete()?;
                crate::obs_counter!("storage.segments.reaped").inc();
                crate::obs_gauge!("storage.segments").sub(1);
            }
            write_meta(&self.dir.join(META_FILE), self.start, self.epoch)
        })();
        if let Err(e) = res {
            error!(
                "disk log {:?}: start persist failed ({e}) — degrading to memory-only",
                self.dir
            );
            self.failed = true;
        }
    }

    /// Read one record from disk (tests / recovery verification — the
    /// serving path reads the in-memory mirror).
    pub fn read(&self, offset: u64) -> io::Result<Option<Record>> {
        if offset < self.start || offset >= self.next_offset() {
            return Ok(None);
        }
        let seg = if offset >= self.active.base() {
            &self.active
        } else {
            let i = self.sealed.partition_point(|s| s.base() <= offset);
            if i == 0 {
                return Ok(None); // below the oldest retained segment
            }
            &self.sealed[i - 1]
        };
        seg.read(offset)
    }

    /// Seal the active segment (flush + fsync; clean shutdown).
    pub fn sync(&mut self) -> io::Result<()> {
        self.active.seal()?;
        self.active.reopen_append()
    }

    /// First live offset.
    pub fn start(&self) -> u64 {
        self.start
    }

    /// Replication fencing epoch last adopted by this partition.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Persist a newly adopted fencing epoch (promotion / leader adopt).
    /// Degrades to memory-only on I/O error like [`DiskLog::append`] — the
    /// in-memory epoch still advances, so fencing keeps working for the
    /// life of the process.
    pub fn set_epoch(&mut self, epoch: u64) {
        if epoch <= self.epoch {
            return;
        }
        self.epoch = epoch;
        if self.failed {
            return;
        }
        if let Err(e) = write_meta(&self.dir.join(META_FILE), self.start, self.epoch) {
            error!(
                "disk log {:?}: epoch persist failed ({e}) — degrading to memory-only",
                self.dir
            );
            self.failed = true;
        }
    }

    /// Offset the next append must carry (recovered high watermark).
    pub fn next_offset(&self) -> u64 {
        self.active.next_offset()
    }

    /// Total bytes across sealed + active segment files.
    pub fn bytes_on_disk(&self) -> u64 {
        self.sealed.iter().map(Segment::bytes).sum::<u64>() + self.active.bytes()
    }

    /// Segment count (sealed + active).
    pub fn segment_count(&self) -> usize {
        self.sealed.len() + 1
    }

    /// Records replayed into memory by `open`.
    pub fn recovered(&self) -> u64 {
        self.recovered
    }

    /// True after an I/O error degraded this log to memory-only.
    pub fn failed(&self) -> bool {
        self.failed
    }

    /// Directory backing this log.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

// ---- meta file (persisted log start) -----------------------------------

/// `meta.bin` = `crc32(body): u32 | start: u64 | epoch: u64`. Atomic tmp +
/// rename; any corruption falls back to `(0, 0)` (recovery then serves
/// everything still on disk — safe, merely conservative). Pre-epoch
/// 12-byte files (`crc | start`) still read back: epoch defaults to 0, so
/// a data dir written by an older broker upgrades in place.
fn read_meta(path: &Path) -> (u64, u64) {
    let Ok(data) = std::fs::read(path) else {
        return (0, 0);
    };
    let body = match data.len() {
        12 | 20 => &data[4..],
        _ => return (0, 0),
    };
    let crc = u32::from_le_bytes(data[0..4].try_into().unwrap());
    if crc32(body) != crc {
        warn!("disk log meta {path:?} corrupt — falling back to start 0");
        return (0, 0);
    }
    let start = u64::from_le_bytes(body[0..8].try_into().unwrap());
    let epoch = if body.len() == 16 {
        u64::from_le_bytes(body[8..16].try_into().unwrap())
    } else {
        0
    };
    (start, epoch)
}

fn write_meta(path: &Path, start: u64, epoch: u64) -> io::Result<()> {
    // Fault seam: a scripted failure persisting the log-start offset.
    if fault::active() && fault::check(fault::site::LOG_META, &path.to_string_lossy()).is_some() {
        return Err(fault::injected_error(fault::site::LOG_META));
    }
    let mut body = Vec::with_capacity(16);
    body.extend_from_slice(&start.to_le_bytes());
    body.extend_from_slice(&epoch.to_le_bytes());
    let mut data = Vec::with_capacity(20);
    data.extend_from_slice(&crc32(&body).to_le_bytes());
    data.extend_from_slice(&body);
    let tmp = path.with_extension("bin.tmp");
    std::fs::write(&tmp, &data)?;
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::wire::Blob;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hybridws-dlog-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn rec(offset: u64, payload: Vec<u8>) -> Record {
        Record { offset, timestamp_ms: now_ms(), key: None, value: Blob::new(payload) }
    }

    #[test]
    fn append_roll_and_recover_across_segments() {
        let dir = tmp_dir("roll");
        let (mut log, recs) = DiskLog::open(&dir, 256, Retention::default()).unwrap();
        assert!(recs.is_empty());
        for i in 0..40u64 {
            assert!(log.append(&rec(i, vec![i as u8; 32])).is_none());
        }
        assert!(log.segment_count() > 1, "small segment_bytes must roll");
        assert!(!log.failed());
        let bytes = log.bytes_on_disk();
        drop(log);
        let (back, recs) = DiskLog::open(&dir, 256, Retention::default()).unwrap();
        assert_eq!(recs.len(), 40);
        assert_eq!(back.recovered(), 40);
        assert_eq!(back.next_offset(), 40);
        assert_eq!(back.bytes_on_disk(), bytes);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.offset, i as u64);
            assert_eq!(r.value.as_slice(), &vec![i as u8; 32][..]);
        }
        // Point reads cross the segment boundary correctly.
        assert_eq!(back.read(0).unwrap().unwrap().offset, 0);
        assert_eq!(back.read(39).unwrap().unwrap().offset, 39);
        assert!(back.read(40).unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn byte_retention_drops_sealed_segments() {
        let dir = tmp_dir("retention");
        let retention = Retention::default().max_bytes(600);
        let (mut log, _) = DiskLog::open(&dir, 128, retention).unwrap();
        let mut advanced = 0u64;
        for i in 0..60u64 {
            if let Some(s) = log.append(&rec(i, vec![0u8; 24])) {
                advanced = s;
            }
        }
        assert!(advanced > 0, "retention must advance the start");
        assert_eq!(log.start(), advanced);
        assert!(log.bytes_on_disk() <= 600 + 256, "bounded by cap + one segment slack");
        drop(log);
        // The advanced start survives a restart (open-time enforcement may
        // advance it further if the close left the log over the cap).
        let (back, recs) = DiskLog::open(&dir, 128, retention).unwrap();
        assert!(back.start() >= advanced, "{} < {advanced}", back.start());
        assert!(back.bytes_on_disk() <= 600 + 256, "open must re-enforce the cap");
        assert_eq!(recs.first().unwrap().offset, back.start());
        assert_eq!(recs.last().unwrap().offset, 59);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn set_start_persists_and_reaps() {
        let dir = tmp_dir("setstart");
        let (mut log, _) = DiskLog::open(&dir, 128, Retention::default()).unwrap();
        for i in 0..30u64 {
            log.append(&rec(i, vec![7u8; 24]));
        }
        let segs_before = log.segment_count();
        log.set_start(25);
        assert_eq!(log.start(), 25);
        assert!(log.segment_count() < segs_before, "fully-deleted segments reaped");
        assert!(log.read(10).unwrap().is_none(), "deleted records unreadable");
        drop(log);
        let (back, recs) = DiskLog::open(&dir, 128, Retention::default()).unwrap();
        assert_eq!(back.start(), 25);
        assert_eq!(recs.iter().map(|r| r.offset).collect::<Vec<_>>(), vec![25, 26, 27, 28, 29]);
        // New appends continue the dense sequence.
        let (mut back2, _) = DiskLog::open(&dir, 128, Retention::default()).unwrap();
        back2.append(&rec(30, vec![1]));
        assert_eq!(back2.next_offset(), 31);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fully_deleted_log_restarts_at_watermark() {
        let dir = tmp_dir("alldel");
        let (mut log, _) = DiskLog::open(&dir, 1 << 20, Retention::default()).unwrap();
        for i in 0..5u64 {
            log.append(&rec(i, vec![1, 2, 3]));
        }
        log.set_start(5);
        drop(log);
        let (back, recs) = DiskLog::open(&dir, 1 << 20, Retention::default()).unwrap();
        assert!(recs.is_empty());
        assert_eq!(back.start(), 5);
        assert_eq!(back.next_offset(), 5, "watermark survives total deletion");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn meta_roundtrip_and_corruption_fallback() {
        let dir = tmp_dir("meta");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(META_FILE);
        assert_eq!(read_meta(&path), (0, 0), "missing meta reads as (0, 0)");
        write_meta(&path, 12345, 7).unwrap();
        assert_eq!(read_meta(&path), (12345, 7));
        std::fs::write(&path, b"garbage, not a valid meta").unwrap();
        assert_eq!(read_meta(&path), (0, 0));
        // Pre-epoch 12-byte format still reads back with epoch 0.
        let start_bytes = 99u64.to_le_bytes();
        let mut legacy = Vec::new();
        legacy.extend_from_slice(&crc32(&start_bytes).to_le_bytes());
        legacy.extend_from_slice(&start_bytes);
        std::fs::write(&path, &legacy).unwrap();
        assert_eq!(read_meta(&path), (99, 0), "legacy meta upgrades in place");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn epoch_survives_restart_and_never_regresses() {
        let dir = tmp_dir("epoch");
        {
            let (mut log, _) = DiskLog::open(&dir, 1 << 20, Retention::default()).unwrap();
            assert_eq!(log.epoch(), 0);
            log.set_epoch(3);
            log.set_epoch(2); // stale adopt: ignored
            assert_eq!(log.epoch(), 3);
            log.append(&rec(0, vec![1]));
        }
        let (back, recs) = DiskLog::open(&dir, 1 << 20, Retention::default()).unwrap();
        assert_eq!(back.epoch(), 3, "fencing epoch survives the restart");
        assert_eq!(recs.len(), 1, "records unaffected by epoch writes");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
