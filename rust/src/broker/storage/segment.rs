//! One segment file: a CRC-framed run of records starting at a fixed base
//! offset, plus its sparse in-memory offset index.
//!
//! File format — a sequence of frames, no file header:
//!
//! ```text
//! frame := body_len: u32 | crc32(body): u32 | body
//! body  := offset: u64 | timestamp_ms: u64 | key: Option<Blob> | value: Blob
//! ```
//!
//! `body` is byte-identical to the wire encoding of
//! [`crate::broker::Record`], so recovery is `Record::decode_exact` behind a
//! CRC check. The writer assembles the frame header + record header in a
//! reused scratch buffer and then writes the value bytes **directly from
//! the producer's `Arc` allocation** — the same `SharedBytes` the in-memory
//! log serves to consumers — so a disk publish adds no payload copy.
//!
//! The sparse index (`offset → file position`, one entry per
//! [`INDEX_STRIDE`] bytes) is not persisted: it is rebuilt by the recovery
//! scan on open, which also verifies every CRC, enforces offset density and
//! truncates a torn tail in place.

use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use log::warn;

use crate::broker::record::Record;
use crate::util::fault;
use crate::util::wire::Wire;

use super::{crc32, scan_frames, Crc32, FRAME_HEADER};

/// Sparse-index granularity: one entry per this many file bytes.
pub const INDEX_STRIDE: u64 = 4096;

/// Width of the zero-padded base offset in segment file names.
const NAME_DIGITS: usize = 20;

/// One segment file (`<base:020>.seg`).
#[derive(Debug)]
pub struct Segment {
    path: PathBuf,
    /// Offset of the first record this segment holds.
    base: u64,
    /// Offset the next appended record must have.
    next: u64,
    /// Valid file length in bytes.
    bytes: u64,
    /// Timestamp of the newest record (age-based retention).
    last_ts_ms: u64,
    /// Sparse `(offset, file position)` index, ascending in both fields.
    index: Vec<(u64, u64)>,
    /// Append handle — `Some` only while this is the active segment.
    file: Option<File>,
    /// Reused frame-assembly buffer (frame header + record header + key).
    scratch: Vec<u8>,
}

/// `<base>.seg` file name for a base offset.
pub fn segment_file_name(base: u64) -> String {
    format!("{base:020}.seg")
}

/// Parse the base offset out of a segment file name.
pub fn parse_segment_name(name: &str) -> Option<u64> {
    let stem = name.strip_suffix(".seg")?;
    if stem.len() != NAME_DIGITS {
        return None;
    }
    stem.parse().ok()
}

impl Segment {
    /// Create a fresh, empty segment starting at `base`.
    pub fn create(dir: &Path, base: u64) -> io::Result<Self> {
        let path = dir.join(segment_file_name(base));
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        Ok(Self {
            path,
            base,
            next: base,
            bytes: 0,
            last_ts_ms: 0,
            index: Vec::new(),
            file: Some(file),
            scratch: Vec::new(),
        })
    }

    /// Open an existing segment: scan every frame (verifying CRC, record
    /// decode and offset density), rebuild the sparse index, truncate any
    /// torn/corrupt tail in place, and return the recovered records.
    /// The segment comes back sealed — call [`Segment::reopen_append`] on
    /// the one that becomes active.
    pub fn open(path: &Path) -> io::Result<(Self, Vec<Arc<Record>>)> {
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or_default();
        let base = parse_segment_name(name).ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidInput, format!("bad segment name {name:?}"))
        })?;
        let data = std::fs::read(path)?;
        let mut records: Vec<Arc<Record>> = Vec::new();
        let mut index: Vec<(u64, u64)> = Vec::new();
        let mut last_indexed = 0u64;
        let mut last_ts = 0u64;
        let valid = scan_frames(&data, |pos, body| {
            let Ok(rec) = Record::decode_exact(body) else {
                return false;
            };
            if rec.offset != base + records.len() as u64 {
                return false; // non-dense offset: treat as corruption
            }
            let pos = pos as u64;
            if index.is_empty() || pos - last_indexed >= INDEX_STRIDE {
                index.push((rec.offset, pos));
                last_indexed = pos;
            }
            last_ts = rec.timestamp_ms;
            records.push(Arc::new(rec));
            true
        });
        if valid < data.len() {
            warn!(
                "segment {path:?}: truncating {} torn/corrupt tail bytes at {valid}",
                data.len() - valid
            );
            OpenOptions::new().write(true).open(path)?.set_len(valid as u64)?;
        }
        let next = base + records.len() as u64;
        Ok((
            Self {
                path: path.to_path_buf(),
                base,
                next,
                bytes: valid as u64,
                last_ts_ms: last_ts,
                index,
                file: None,
                scratch: Vec::new(),
            },
            records,
        ))
    }

    /// Re-open the append handle (recovery promotes the last segment back
    /// to active).
    pub fn reopen_append(&mut self) -> io::Result<()> {
        self.file = Some(OpenOptions::new().create(true).append(true).open(&self.path)?);
        Ok(())
    }

    /// Append one record. `rec.offset` must equal [`Segment::next_offset`].
    /// The value bytes are written straight from the record's `Arc`
    /// allocation (no intermediate copy).
    pub fn append(&mut self, rec: &Record) -> io::Result<()> {
        debug_assert_eq!(rec.offset, self.next, "segment appends must be dense");
        let file = self
            .file
            .as_mut()
            .ok_or_else(|| io::Error::new(io::ErrorKind::Other, "segment is sealed"))?;
        // Fault seam: scripted disk trouble at the append boundary. `Fail`
        // rejects outright; `ShortWrite` tears the frame header mid-write;
        // `Corrupt` flips a framed byte after the CRC was computed. All
        // surface as io::Error so `DiskLog`'s degrade policy kicks in.
        let injected = if fault::active() {
            fault::check(fault::site::SEG_APPEND, &self.path.to_string_lossy())
        } else {
            None
        };
        if matches!(injected, Some(fault::FaultAction::Fail)) {
            return Err(fault::injected_error(fault::site::SEG_APPEND));
        }
        // Record header (everything before the value bytes), byte-identical
        // to the wire encoding of `Record` minus the trailing value bytes.
        self.scratch.clear();
        self.scratch.extend_from_slice(&[0u8; FRAME_HEADER]); // len + crc placeholders
        self.scratch.extend_from_slice(&rec.offset.to_le_bytes());
        self.scratch.extend_from_slice(&rec.timestamp_ms.to_le_bytes());
        match &rec.key {
            None => self.scratch.push(0),
            Some(k) => {
                self.scratch.push(1);
                self.scratch.extend_from_slice(&(k.len() as u32).to_le_bytes());
                self.scratch.extend_from_slice(k);
            }
        }
        self.scratch.extend_from_slice(&(rec.value.len() as u32).to_le_bytes());
        let head = &self.scratch[FRAME_HEADER..];
        let body_len = head.len() + rec.value.len();
        let mut crc = Crc32::new();
        crc.update(head);
        crc.update(&rec.value);
        let crc = crc.finish();
        // Patch the placeholders, then two writes: [len|crc|head] + value —
        // the value bytes go out straight from the shared Arc allocation.
        self.scratch[0..4].copy_from_slice(&(body_len as u32).to_le_bytes());
        self.scratch[4..8].copy_from_slice(&crc.to_le_bytes());
        match injected {
            Some(fault::FaultAction::ShortWrite) => {
                // Half a frame header reaches the disk, then the "crash".
                file.write_all(&self.scratch[..FRAME_HEADER / 2])?;
                return Err(fault::injected_error(fault::site::SEG_APPEND));
            }
            Some(fault::FaultAction::Corrupt) => {
                // A full-length frame whose bytes no longer match its CRC.
                let mut torn = self.scratch.clone();
                let last = torn.len() - 1;
                torn[last] ^= 0xFF;
                file.write_all(&torn)?;
                file.write_all(&rec.value)?;
                return Err(fault::injected_error(fault::site::SEG_APPEND));
            }
            // `Fail` returned above; any other scripted action degrades to
            // a plain failure rather than silently no-opping.
            Some(_) => return Err(fault::injected_error(fault::site::SEG_APPEND)),
            None => {}
        }
        file.write_all(&self.scratch)?;
        file.write_all(&rec.value)?;
        let pos = self.bytes;
        if self.index.is_empty() || pos - self.index.last().unwrap().1 >= INDEX_STRIDE {
            self.index.push((rec.offset, pos));
        }
        self.bytes += (FRAME_HEADER + body_len) as u64;
        self.last_ts_ms = rec.timestamp_ms;
        self.next += 1;
        Ok(())
    }

    /// Seal: fsync and drop the append handle. Idempotent.
    pub fn seal(&mut self) -> io::Result<()> {
        if let Some(file) = self.file.take() {
            // Fault seam: a scripted fsync failure at seal time.
            if fault::active()
                && fault::check(fault::site::SEG_SEAL, &self.path.to_string_lossy()).is_some()
            {
                return Err(fault::injected_error(fault::site::SEG_SEAL));
            }
            file.sync_all()?;
        }
        Ok(())
    }

    /// Delete the backing file (retention / topic deletion).
    pub fn delete(mut self) -> io::Result<()> {
        self.file = None;
        std::fs::remove_file(&self.path)
    }

    /// Read one record from disk by offset, seeking via the sparse index
    /// (recovery verification and tests; the serving path reads memory).
    pub fn read(&self, offset: u64) -> io::Result<Option<Record>> {
        if offset < self.base || offset >= self.next {
            return Ok(None);
        }
        // Greatest index entry at or below the target.
        let i = self.index.partition_point(|&(o, _)| o <= offset);
        let (_, mut pos) = if i == 0 { (self.base, 0) } else { self.index[i - 1] };
        let mut f = File::open(&self.path)?;
        f.seek(SeekFrom::Start(pos))?;
        let mut header = [0u8; FRAME_HEADER];
        while pos < self.bytes {
            f.read_exact(&mut header)?;
            let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(header[4..8].try_into().unwrap());
            let mut body = vec![0u8; len];
            f.read_exact(&mut body)?;
            pos += (FRAME_HEADER + len) as u64;
            // Body starts with the offset (little-endian u64).
            if body.len() >= 8 && u64::from_le_bytes(body[0..8].try_into().unwrap()) == offset {
                if crc32(&body) != crc {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("crc mismatch reading offset {offset}"),
                    ));
                }
                return Record::decode_exact(&body)
                    .map(Some)
                    .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()));
            }
        }
        Ok(None)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn base(&self) -> u64 {
        self.base
    }

    /// Offset the next append gets (== base + record count).
    pub fn next_offset(&self) -> u64 {
        self.next
    }

    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn record_count(&self) -> u64 {
        self.next - self.base
    }

    /// Newest record timestamp (0 when empty).
    pub fn last_ts_ms(&self) -> u64 {
        self.last_ts_ms
    }

    /// Sparse-index entry count (tests).
    pub fn index_len(&self) -> usize {
        self.index.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::record::now_ms;
    use crate::util::wire::Blob;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hybridws-seg-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn rec(offset: u64, payload: &[u8]) -> Record {
        Record { offset, timestamp_ms: now_ms(), key: None, value: Blob::new(payload.to_vec()) }
    }

    #[test]
    fn append_reopen_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let mut seg = Segment::create(&dir, 5).unwrap();
        for i in 0..10u64 {
            seg.append(&rec(5 + i, &[i as u8; 16])).unwrap();
        }
        seg.seal().unwrap();
        let (back, records) = Segment::open(seg.path()).unwrap();
        assert_eq!(back.base(), 5);
        assert_eq!(back.next_offset(), 15);
        assert_eq!(records.len(), 10);
        for (i, r) in records.iter().enumerate() {
            assert_eq!(r.offset, 5 + i as u64);
            assert_eq!(r.value.as_slice(), &[i as u8; 16]);
        }
        // Point reads go through the sparse index.
        assert_eq!(back.read(7).unwrap().unwrap().value.as_slice(), &[2u8; 16]);
        assert!(back.read(4).unwrap().is_none());
        assert!(back.read(15).unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn keys_survive_the_disk_roundtrip() {
        let dir = tmp_dir("keys");
        let mut seg = Segment::create(&dir, 0).unwrap();
        let r = Record {
            offset: 0,
            timestamp_ms: 42,
            key: Some(Blob::new(b"k1".to_vec())),
            value: Blob::new(b"v1".to_vec()),
        };
        seg.append(&r).unwrap();
        seg.seal().unwrap();
        let (_, records) = Segment::open(seg.path()).unwrap();
        assert_eq!(*records[0], r);
    }

    #[test]
    fn torn_tail_is_truncated_not_propagated() {
        let dir = tmp_dir("torn");
        let mut seg = Segment::create(&dir, 0).unwrap();
        for i in 0..3u64 {
            seg.append(&rec(i, &[7u8; 32])).unwrap();
        }
        seg.seal().unwrap();
        let path = seg.path().to_path_buf();
        let full = std::fs::metadata(&path).unwrap().len();
        // Cut 10 bytes into the final frame.
        OpenOptions::new().write(true).open(&path).unwrap().set_len(full - 10).unwrap();
        let (back, records) = Segment::open(&path).unwrap();
        assert_eq!(records.len(), 2, "torn final record discarded");
        assert_eq!(back.next_offset(), 2);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), back.bytes());
        // The truncated file appends cleanly from the recovered watermark.
        let (mut back, _) = Segment::open(&path).unwrap();
        back.reopen_append().unwrap();
        back.append(&rec(2, b"replacement")).unwrap();
        back.seal().unwrap();
        let (_, records) = Segment::open(&path).unwrap();
        assert_eq!(records.len(), 3);
        assert_eq!(records[2].value.as_slice(), b"replacement");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_byte_is_caught_by_crc() {
        let dir = tmp_dir("crc");
        let mut seg = Segment::create(&dir, 0).unwrap();
        for i in 0..2u64 {
            seg.append(&rec(i, &[9u8; 24])).unwrap();
        }
        seg.seal().unwrap();
        let path = seg.path().to_path_buf();
        let mut data = std::fs::read(&path).unwrap();
        let n = data.len();
        data[n - 5] ^= 0xFF; // inside the last value
        std::fs::write(&path, &data).unwrap();
        let (_, records) = Segment::open(&path).unwrap();
        assert_eq!(records.len(), 1, "corrupt record dropped, prefix kept");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sparse_index_stays_sparse() {
        let dir = tmp_dir("sparse");
        let mut seg = Segment::create(&dir, 0).unwrap();
        for i in 0..256u64 {
            seg.append(&rec(i, &[0u8; 100])).unwrap();
        }
        // ~130 B/frame → ~33 KiB file → ≈ 9 index entries, not 256.
        assert!(seg.index_len() < 16, "index has {} entries", seg.index_len());
        assert!(seg.index_len() >= 2);
        seg.seal().unwrap();
        let (back, _) = Segment::open(seg.path()).unwrap();
        assert_eq!(back.index_len(), seg.index_len(), "rebuild matches append-time index");
        for probe in [0u64, 1, 100, 255] {
            assert_eq!(back.read(probe).unwrap().unwrap().offset, probe);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn segment_names_roundtrip() {
        assert_eq!(segment_file_name(0), "00000000000000000000.seg");
        assert_eq!(parse_segment_name(&segment_file_name(12345)), Some(12345));
        assert_eq!(parse_segment_name("junk.seg"), None);
        assert_eq!(parse_segment_name("meta.bin"), None);
    }
}
