//! Topics: named sets of partitions plus the producer-side partitioner
//! and the **publish notifier** — the wait-list that turns consumer polls
//! from sleep-spin loops into event-driven wakeups.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::partition::PartitionLog;
use super::record::{ProducerRecord, Record};
use super::storage::{topic_dir_name, StorageMode};
use crate::util::trace::{self, TraceCtx};

/// FNV-1a offset basis — the one hash constant shared by the partitioner
/// and the cluster placement function.
pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into an FNV-1a state. The single implementation behind
/// [`key_partition`] and the cluster rendezvous weight, so the two can
/// never diverge.
pub(crate) fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// FNV-1a key hash → partition, shared by the broker-side partitioner and
/// cluster-aware clients routing keyed records locally: both MUST pick the
/// same partition for the same key, or a key's records would split across
/// shards.
pub fn key_partition(key: &[u8], partitions: usize) -> usize {
    (fnv1a(FNV_OFFSET, key) % partitions.max(1) as u64) as usize
}

/// A topic with `n` independently-locked partitions.
#[derive(Debug)]
pub struct Topic {
    pub name: String,
    partitions: Vec<Mutex<PartitionLog>>,
    /// Round-robin cursor for key-less records.
    rr: AtomicU64,
    /// Publish notifier: a lock-free sequence number bumped on every
    /// append batch, plus a wait-list that long-polling fetches park on.
    /// One notifier per topic (not per partition): consumers drain all
    /// their partitions per fetch anyway. The fast path (no parked
    /// waiters — the common case for busy producers) costs two atomic ops
    /// per publish; the `Mutex`/`Condvar` pair is touched only when a
    /// waiter is actually parked, so producers keep PR 1's
    /// one-lock-per-partition scaling.
    publish_seq: AtomicU64,
    waiters: AtomicU64,
    wait_lock: Mutex<()>,
    wait_cv: Condvar,
    /// Trace context of the most recent **sampled** publish, stashed so
    /// the fetch that its wakeup satisfies can chain a `fetch.wakeup`
    /// span onto the publish's trace. Two relaxed atomics, not one
    /// locked pair: racing sampled publishes may interleave the halves,
    /// which at worst files the wakeup under a sibling span of the same
    /// workload — an orphan in the stitched tree, never corruption.
    pub_trace: AtomicU64,
    pub_span: AtomicU64,
}

impl Topic {
    pub fn new(name: &str, partitions: usize) -> Self {
        assert!(partitions > 0, "topic needs >= 1 partition");
        Self::from_logs(name, (0..partitions).map(|_| PartitionLog::new()).collect())
    }

    /// Open a topic under a storage mode. `Memory` is [`Topic::new`];
    /// `Disk` opens (and crash-recovers) one [`PartitionLog`] per
    /// `<data_dir>/<topic>/p<i>` directory. Existing partition directories
    /// win over the requested count, so a recovered topic keeps its layout
    /// even if the caller asks for fewer partitions.
    pub fn open(name: &str, partitions: usize, mode: &StorageMode) -> std::io::Result<Self> {
        assert!(partitions > 0, "topic needs >= 1 partition");
        let StorageMode::Disk { data_dir, segment_bytes, retention } = mode else {
            return Ok(Self::new(name, partitions));
        };
        let tdir = data_dir.join(topic_dir_name(name));
        let mut count = partitions.max(1);
        if let Ok(entries) = std::fs::read_dir(&tdir) {
            for e in entries.flatten() {
                if let Some(p) = e
                    .file_name()
                    .to_str()
                    .and_then(|n| n.strip_prefix('p'))
                    .and_then(|n| n.parse::<usize>().ok())
                {
                    count = count.max(p + 1);
                }
            }
        }
        let logs = (0..count)
            .map(|p| {
                PartitionLog::open_disk(&tdir.join(format!("p{p}")), *segment_bytes, *retention)
            })
            .collect::<std::io::Result<Vec<_>>>()?;
        Ok(Self::from_logs(name, logs))
    }

    fn from_logs(name: &str, logs: Vec<PartitionLog>) -> Self {
        assert!(!logs.is_empty(), "topic needs >= 1 partition");
        Self {
            name: name.to_string(),
            partitions: logs.into_iter().map(Mutex::new).collect(),
            rr: AtomicU64::new(0),
            publish_seq: AtomicU64::new(0),
            waiters: AtomicU64::new(0),
            wait_lock: Mutex::new(()),
            wait_cv: Condvar::new(),
            pub_trace: AtomicU64::new(0),
            pub_span: AtomicU64::new(0),
        }
    }

    /// Stash the ambient trace context for the next fetch wakeup (no-op
    /// for unsampled publishes). Called **before** [`Topic::notify_publish`]
    /// so a woken fetch observes it.
    fn stash_publish_ctx(&self) {
        let ctx = trace::current();
        if ctx.sampled() {
            self.pub_trace.store(ctx.trace_id, Ordering::Relaxed);
            self.pub_span.store(ctx.span_id, Ordering::Relaxed);
        }
    }

    /// Take (at most once) the trace context of the publish that most
    /// recently appended to this topic — the fetch-wakeup linkage.
    pub fn take_publish_ctx(&self) -> TraceCtx {
        let trace_id = self.pub_trace.swap(0, Ordering::Relaxed);
        let span_id = self.pub_span.swap(0, Ordering::Relaxed);
        TraceCtx { trace_id, span_id }
    }

    // ---- publish notifier ----------------------------------------------

    /// Snapshot the publish sequence number. Take it **before** checking
    /// for data: a publish that lands between the check and
    /// [`Topic::wait_publish`] bumps the sequence, so the wait returns
    /// immediately instead of losing the wakeup.
    pub fn publish_seq(&self) -> u64 {
        self.publish_seq.load(Ordering::SeqCst)
    }

    /// Wake every parked waiter (called after each append; also used by
    /// topic deletion and group rewinds so blocked fetches re-check).
    pub fn notify_publish(&self) {
        self.publish_seq.fetch_add(1, Ordering::SeqCst);
        if self.waiters.load(Ordering::SeqCst) > 0 {
            // Taking the lock orders this notify after a waiter's in-lock
            // sequence check: the waiter either saw the new sequence or is
            // parked and receives the notification. Skipped entirely when
            // nobody waits.
            let _guard = self.wait_lock.lock().unwrap();
            self.wait_cv.notify_all();
        }
    }

    /// Park until the publish sequence moves past `seen` or `timeout`
    /// elapses. Returns `true` when woken by a publish.
    pub fn wait_publish(&self, seen: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        self.waiters.fetch_add(1, Ordering::SeqCst);
        let mut guard = self.wait_lock.lock().unwrap();
        let woken = loop {
            if self.publish_seq.load(Ordering::SeqCst) != seen {
                break true;
            }
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                break false;
            };
            let (g, _) = self.wait_cv.wait_timeout(guard, remaining).unwrap();
            guard = g;
        };
        drop(guard);
        self.waiters.fetch_sub(1, Ordering::SeqCst);
        woken
    }

    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    /// Partition selection: key hash when present, else round-robin.
    pub fn pick_partition(&self, rec: &ProducerRecord) -> usize {
        match &rec.key {
            Some(k) => key_partition(&k.0, self.partitions.len()),
            None => {
                (self.rr.fetch_add(1, Ordering::Relaxed) % self.partitions.len() as u64) as usize
            }
        }
    }

    /// Append to the chosen partition; returns (partition, offset).
    pub fn publish(&self, rec: ProducerRecord) -> (usize, u64) {
        let _s = trace::span("partition.append");
        let p = self.pick_partition(&rec);
        let offset = self.partitions[p].lock().unwrap().append(rec);
        self.stash_publish_ctx();
        self.notify_publish();
        (p, offset)
    }

    /// Append to an explicit partition; returns the offset.
    pub fn publish_to(&self, partition: usize, rec: ProducerRecord) -> u64 {
        let _s = trace::span("partition.append");
        let offset = self.partitions[partition].lock().unwrap().append(rec);
        self.stash_publish_ctx();
        self.notify_publish();
        offset
    }

    /// Append a whole batch to one explicit partition under a **single**
    /// lock acquisition (the cluster `PublishTo` frame); returns the
    /// assigned offsets in order, with one wakeup per batch.
    pub fn publish_many_to(&self, partition: usize, recs: Vec<ProducerRecord>) -> Vec<u64> {
        if recs.is_empty() {
            return Vec::new();
        }
        let _s = trace::span("partition.append");
        let offsets = {
            let mut log = self.partitions[partition].lock().unwrap();
            recs.into_iter().map(|rec| log.append(rec)).collect()
        };
        self.stash_publish_ctx();
        self.notify_publish();
        offsets
    }

    /// Append a whole batch, grouping records by partition so each
    /// partition lock is taken **once** per batch instead of once per
    /// record. Acks are returned in submission order. O(records +
    /// partitions): one partitioner pass builds per-partition index
    /// buckets, then each non-empty bucket appends under one lock.
    pub fn publish_many(&self, recs: Vec<ProducerRecord>) -> Vec<(usize, u64)> {
        let _s = trace::span("partition.append");
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); self.partitions.len()];
        for (i, rec) in recs.iter().enumerate() {
            buckets[self.pick_partition(rec)].push(i);
        }
        let mut slots: Vec<Option<ProducerRecord>> = recs.into_iter().map(Some).collect();
        let mut acks: Vec<(usize, u64)> = vec![(0, 0); slots.len()];
        for (p, bucket) in buckets.iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let mut log = self.partitions[p].lock().unwrap();
            for &i in bucket {
                let rec = slots[i].take().expect("record consumed twice");
                acks[i] = (p, log.append(rec));
            }
        }
        if !acks.is_empty() {
            self.stash_publish_ctx();
            // One wakeup per batch — waiters drain everything they can see.
            self.notify_publish();
        }
        acks
    }

    /// Follower-side replica apply: append one leader record preserving
    /// its offset and timestamp (no partitioner, no offset assignment).
    /// The caller batches its own [`Topic::notify_publish`].
    pub fn append_replica(&self, partition: usize, rec: Arc<Record>) {
        self.partitions[partition].lock().unwrap().append_replica(rec);
    }

    /// Replication fencing epoch of one partition.
    pub fn partition_epoch(&self, partition: usize) -> u64 {
        self.partitions[partition].lock().unwrap().epoch()
    }

    /// Adopt a fencing epoch on one partition (forward-only; persisted
    /// for durable partitions).
    pub fn set_partition_epoch(&self, partition: usize, epoch: u64) {
        self.partitions[partition].lock().unwrap().set_epoch(epoch);
    }

    /// Fetch up to `max` records from a partition starting at `from`.
    pub fn fetch(&self, partition: usize, from: u64, max: usize) -> Vec<Arc<Record>> {
        self.partitions[partition].lock().unwrap().fetch(from, max)
    }

    /// Fetch with both a record cap and a payload byte budget (see
    /// [`PartitionLog::fetch_budgeted`]).
    pub fn fetch_budgeted(
        &self,
        partition: usize,
        from: u64,
        max: usize,
        max_bytes: usize,
    ) -> Vec<Arc<Record>> {
        self.partitions[partition].lock().unwrap().fetch_budgeted(from, max, max_bytes)
    }

    /// `(start_offset, high_watermark)` of one partition under a single
    /// lock acquisition (the multi-partition fetch hot path).
    pub fn offsets_of(&self, partition: usize) -> (u64, u64) {
        let log = self.partitions[partition].lock().unwrap();
        (log.start_offset(), log.high_watermark())
    }

    /// High watermark of a partition.
    pub fn high_watermark(&self, partition: usize) -> u64 {
        self.partitions[partition].lock().unwrap().high_watermark()
    }

    /// Earliest retained offset of a partition.
    pub fn start_offset(&self, partition: usize) -> u64 {
        self.partitions[partition].lock().unwrap().start_offset()
    }

    /// Delete records below `up_to` in a partition (exactly-once support).
    pub fn delete_records(&self, partition: usize, up_to: u64) -> usize {
        self.partitions[partition].lock().unwrap().delete_up_to(up_to)
    }

    /// Total records retained across partitions.
    pub fn total_records(&self) -> usize {
        self.partitions.iter().map(|p| p.lock().unwrap().len()).sum()
    }

    /// Total payload bytes retained across partitions.
    pub fn total_bytes(&self) -> usize {
        self.partitions.iter().map(|p| p.lock().unwrap().retained_bytes()).sum()
    }

    // ---- durability introspection --------------------------------------

    /// True when this topic's partitions are disk-backed.
    pub fn is_durable(&self) -> bool {
        self.partitions.first().is_some_and(|p| p.lock().unwrap().is_durable())
    }

    /// Segment-file bytes across all partitions (0 in memory mode).
    pub fn total_bytes_on_disk(&self) -> u64 {
        self.partitions.iter().map(|p| p.lock().unwrap().bytes_on_disk()).sum()
    }

    /// Segment count across all partitions (0 in memory mode).
    pub fn total_segments(&self) -> usize {
        self.partitions.iter().map(|p| p.lock().unwrap().segment_count()).sum()
    }

    /// Records replayed from disk when this topic was opened.
    pub fn total_recovered(&self) -> u64 {
        self.partitions.iter().map(|p| p.lock().unwrap().recovered_records()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::wire::Blob;

    #[test]
    fn round_robin_spreads_keyless_records() {
        let t = Topic::new("t", 3);
        for i in 0..9 {
            t.publish(ProducerRecord::new(vec![i]));
        }
        for p in 0..3 {
            assert_eq!(t.fetch(p, 0, 100).len(), 3, "partition {p}");
        }
    }

    #[test]
    fn keyed_records_stick_to_one_partition() {
        let t = Topic::new("t", 4);
        let mut first = None;
        for i in 0..8 {
            let (p, _) = t.publish(ProducerRecord::with_key(b"same-key".to_vec(), vec![i]));
            match first {
                None => first = Some(p),
                Some(fp) => assert_eq!(p, fp),
            }
        }
    }

    #[test]
    fn distinct_keys_use_multiple_partitions() {
        let t = Topic::new("t", 4);
        let mut seen = std::collections::HashSet::new();
        for i in 0..64u32 {
            let rec = ProducerRecord {
                key: Some(Blob::new(i.to_le_bytes().to_vec())),
                value: Blob::default(),
            };
            seen.insert(t.pick_partition(&rec));
        }
        assert!(seen.len() > 1, "all keys hashed to one partition");
    }

    #[test]
    fn per_partition_offsets_independent() {
        let t = Topic::new("t", 2);
        assert_eq!(t.publish_to(0, ProducerRecord::new(vec![0])), 0);
        assert_eq!(t.publish_to(0, ProducerRecord::new(vec![1])), 1);
        assert_eq!(t.publish_to(1, ProducerRecord::new(vec![2])), 0);
        assert_eq!(t.high_watermark(0), 2);
        assert_eq!(t.high_watermark(1), 1);
    }

    #[test]
    #[should_panic(expected = ">= 1 partition")]
    fn zero_partitions_rejected() {
        Topic::new("t", 0);
    }

    #[test]
    fn publish_many_matches_per_record_semantics() {
        let a = Topic::new("a", 3);
        let b = Topic::new("b", 3);
        let recs: Vec<ProducerRecord> = (0..9).map(|i| ProducerRecord::new(vec![i])).collect();
        let singles: Vec<(usize, u64)> = recs.iter().cloned().map(|r| a.publish(r)).collect();
        let batched = b.publish_many(recs);
        assert_eq!(singles, batched, "grouped append must keep ack order");
        for p in 0..3 {
            assert_eq!(a.fetch(p, 0, 100).len(), b.fetch(p, 0, 100).len());
        }
    }

    #[test]
    fn publish_many_keeps_keyed_records_on_their_partition() {
        let t = Topic::new("t", 4);
        let recs: Vec<ProducerRecord> =
            (0..8).map(|i| ProducerRecord::with_key(b"k".to_vec(), vec![i])).collect();
        let acks = t.publish_many(recs);
        let p0 = acks[0].0;
        assert!(acks.iter().all(|&(p, _)| p == p0), "same key → same partition");
        // Offsets are dense in submission order within the partition.
        assert_eq!(acks.iter().map(|&(_, o)| o).collect::<Vec<_>>(), (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn publish_many_to_appends_densely_with_one_wakeup() {
        let t = Topic::new("t", 2);
        let s0 = t.publish_seq();
        let offs = t.publish_many_to(1, (0..5).map(|i| ProducerRecord::new(vec![i])).collect());
        assert_eq!(offs, (0..5).collect::<Vec<u64>>());
        assert_eq!(t.publish_seq(), s0 + 1, "one wakeup per batch");
        assert_eq!(t.high_watermark(1), 5);
        assert_eq!(t.high_watermark(0), 0);
        assert!(t.publish_many_to(0, Vec::new()).is_empty());
        assert_eq!(t.publish_seq(), s0 + 1, "empty batch must not wake anyone");
    }

    #[test]
    fn key_partition_matches_pick_partition() {
        let t = Topic::new("t", 4);
        for key in [&b"a"[..], b"same-key", b"another", b"\x00\xFF"] {
            let rec = ProducerRecord::with_key(key.to_vec(), vec![1]);
            assert_eq!(t.pick_partition(&rec), key_partition(key, 4), "{key:?}");
        }
    }

    #[test]
    fn offsets_of_snapshots_one_partition() {
        let t = Topic::new("t", 2);
        t.publish_to(1, ProducerRecord::new(vec![0]));
        assert_eq!(t.offsets_of(0), (0, 0));
        assert_eq!(t.offsets_of(1), (0, 1));
    }

    #[test]
    fn disk_topic_reopens_with_records_and_extra_partition_dirs() {
        use crate::broker::storage::{Retention, StorageMode};
        let data_dir =
            std::env::temp_dir().join(format!("hybridws-topic-disk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&data_dir);
        let mode = StorageMode::disk(&data_dir).retention(Retention::default());
        {
            let t = Topic::open("t", 3, &mode).unwrap();
            assert!(t.is_durable());
            for i in 0..9 {
                t.publish(ProducerRecord::new(vec![i]));
            }
            assert_eq!(t.total_records(), 9);
        }
        // Reopen asking for FEWER partitions: the on-disk layout wins.
        let t = Topic::open("t", 1, &mode).unwrap();
        assert_eq!(t.partition_count(), 3);
        assert_eq!(t.total_records(), 9);
        assert_eq!(t.total_recovered(), 9);
        assert!(t.total_bytes_on_disk() > 0);
        assert!(t.total_segments() >= 3);
        // Memory topics report zero durability stats.
        let m = Topic::new("m", 2);
        assert!(!m.is_durable());
        assert_eq!(m.total_bytes_on_disk(), 0);
        assert_eq!(m.total_segments(), 0);
        std::fs::remove_dir_all(&data_dir).unwrap();
    }

    #[test]
    fn publishes_bump_the_notifier_sequence() {
        let t = Topic::new("t", 2);
        let s0 = t.publish_seq();
        t.publish(ProducerRecord::new(vec![0]));
        assert!(t.publish_seq() > s0);
        let s1 = t.publish_seq();
        t.publish_many(vec![ProducerRecord::new(vec![1]), ProducerRecord::new(vec![2])]);
        assert_eq!(t.publish_seq(), s1 + 1, "one wakeup per batch, not per record");
        // An empty batch must not wake anyone.
        t.publish_many(Vec::new());
        assert_eq!(t.publish_seq(), s1 + 1);
    }

    #[test]
    fn wait_publish_wakes_on_publish_and_expires_otherwise() {
        use std::time::{Duration, Instant};
        let t = Arc::new(Topic::new("t", 1));
        // Expiry: nothing published.
        let seen = t.publish_seq();
        let t0 = Instant::now();
        assert!(!t.wait_publish(seen, Duration::from_millis(30)));
        assert!(t0.elapsed() >= Duration::from_millis(30));
        // Wakeup: a publish from another thread releases the wait early.
        let seen = t.publish_seq();
        let t2 = Arc::clone(&t);
        let publisher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            t2.publish(ProducerRecord::new(vec![1]));
        });
        let t0 = Instant::now();
        assert!(t.wait_publish(seen, Duration::from_secs(5)));
        assert!(t0.elapsed() < Duration::from_secs(4), "woke by notify, not timeout");
        publisher.join().unwrap();
        // A stale snapshot returns immediately (lost-wakeup guard).
        assert!(t.wait_publish(seen, Duration::from_secs(5)));
    }
}
