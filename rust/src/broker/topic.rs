//! Topics: named sets of partitions plus the producer-side partitioner.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::partition::PartitionLog;
use super::record::{ProducerRecord, Record};

/// A topic with `n` independently-locked partitions.
#[derive(Debug)]
pub struct Topic {
    pub name: String,
    partitions: Vec<Mutex<PartitionLog>>,
    /// Round-robin cursor for key-less records.
    rr: AtomicU64,
}

impl Topic {
    pub fn new(name: &str, partitions: usize) -> Self {
        assert!(partitions > 0, "topic needs >= 1 partition");
        Self {
            name: name.to_string(),
            partitions: (0..partitions).map(|_| Mutex::new(PartitionLog::new())).collect(),
            rr: AtomicU64::new(0),
        }
    }

    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    /// FNV-1a key hash → partition (stable across processes).
    fn hash_key(key: &[u8]) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &b in key {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h
    }

    /// Partition selection: key hash when present, else round-robin.
    pub fn pick_partition(&self, rec: &ProducerRecord) -> usize {
        match &rec.key {
            Some(k) => (Self::hash_key(&k.0) % self.partitions.len() as u64) as usize,
            None => (self.rr.fetch_add(1, Ordering::Relaxed) % self.partitions.len() as u64) as usize,
        }
    }

    /// Append to the chosen partition; returns (partition, offset).
    pub fn publish(&self, rec: ProducerRecord) -> (usize, u64) {
        let p = self.pick_partition(&rec);
        let offset = self.partitions[p].lock().unwrap().append(rec);
        (p, offset)
    }

    /// Append to an explicit partition; returns the offset.
    pub fn publish_to(&self, partition: usize, rec: ProducerRecord) -> u64 {
        self.partitions[partition].lock().unwrap().append(rec)
    }

    /// Append a whole batch, grouping records by partition so each
    /// partition lock is taken **once** per batch instead of once per
    /// record. Acks are returned in submission order. O(records +
    /// partitions): one partitioner pass builds per-partition index
    /// buckets, then each non-empty bucket appends under one lock.
    pub fn publish_many(&self, recs: Vec<ProducerRecord>) -> Vec<(usize, u64)> {
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); self.partitions.len()];
        for (i, rec) in recs.iter().enumerate() {
            buckets[self.pick_partition(rec)].push(i);
        }
        let mut slots: Vec<Option<ProducerRecord>> = recs.into_iter().map(Some).collect();
        let mut acks: Vec<(usize, u64)> = vec![(0, 0); slots.len()];
        for (p, bucket) in buckets.iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let mut log = self.partitions[p].lock().unwrap();
            for &i in bucket {
                let rec = slots[i].take().expect("record consumed twice");
                acks[i] = (p, log.append(rec));
            }
        }
        acks
    }

    /// Fetch up to `max` records from a partition starting at `from`.
    pub fn fetch(&self, partition: usize, from: u64, max: usize) -> Vec<Arc<Record>> {
        self.partitions[partition].lock().unwrap().fetch(from, max)
    }

    /// Fetch with both a record cap and a payload byte budget (see
    /// [`PartitionLog::fetch_budgeted`]).
    pub fn fetch_budgeted(
        &self,
        partition: usize,
        from: u64,
        max: usize,
        max_bytes: usize,
    ) -> Vec<Arc<Record>> {
        self.partitions[partition].lock().unwrap().fetch_budgeted(from, max, max_bytes)
    }

    /// `(start_offset, high_watermark)` of one partition under a single
    /// lock acquisition (the multi-partition fetch hot path).
    pub fn offsets_of(&self, partition: usize) -> (u64, u64) {
        let log = self.partitions[partition].lock().unwrap();
        (log.start_offset(), log.high_watermark())
    }

    /// High watermark of a partition.
    pub fn high_watermark(&self, partition: usize) -> u64 {
        self.partitions[partition].lock().unwrap().high_watermark()
    }

    /// Earliest retained offset of a partition.
    pub fn start_offset(&self, partition: usize) -> u64 {
        self.partitions[partition].lock().unwrap().start_offset()
    }

    /// Delete records below `up_to` in a partition (exactly-once support).
    pub fn delete_records(&self, partition: usize, up_to: u64) -> usize {
        self.partitions[partition].lock().unwrap().delete_up_to(up_to)
    }

    /// Total records retained across partitions.
    pub fn total_records(&self) -> usize {
        self.partitions.iter().map(|p| p.lock().unwrap().len()).sum()
    }

    /// Total payload bytes retained across partitions.
    pub fn total_bytes(&self) -> usize {
        self.partitions.iter().map(|p| p.lock().unwrap().retained_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::wire::Blob;

    #[test]
    fn round_robin_spreads_keyless_records() {
        let t = Topic::new("t", 3);
        for i in 0..9 {
            t.publish(ProducerRecord::new(vec![i]));
        }
        for p in 0..3 {
            assert_eq!(t.fetch(p, 0, 100).len(), 3, "partition {p}");
        }
    }

    #[test]
    fn keyed_records_stick_to_one_partition() {
        let t = Topic::new("t", 4);
        let mut first = None;
        for i in 0..8 {
            let (p, _) = t.publish(ProducerRecord::with_key(b"same-key".to_vec(), vec![i]));
            match first {
                None => first = Some(p),
                Some(fp) => assert_eq!(p, fp),
            }
        }
    }

    #[test]
    fn distinct_keys_use_multiple_partitions() {
        let t = Topic::new("t", 4);
        let mut seen = std::collections::HashSet::new();
        for i in 0..64u32 {
            let rec = ProducerRecord {
                key: Some(Blob(i.to_le_bytes().to_vec())),
                value: Blob(vec![]),
            };
            seen.insert(t.pick_partition(&rec));
        }
        assert!(seen.len() > 1, "all keys hashed to one partition");
    }

    #[test]
    fn per_partition_offsets_independent() {
        let t = Topic::new("t", 2);
        assert_eq!(t.publish_to(0, ProducerRecord::new(vec![0])), 0);
        assert_eq!(t.publish_to(0, ProducerRecord::new(vec![1])), 1);
        assert_eq!(t.publish_to(1, ProducerRecord::new(vec![2])), 0);
        assert_eq!(t.high_watermark(0), 2);
        assert_eq!(t.high_watermark(1), 1);
    }

    #[test]
    #[should_panic(expected = ">= 1 partition")]
    fn zero_partitions_rejected() {
        Topic::new("t", 0);
    }

    #[test]
    fn publish_many_matches_per_record_semantics() {
        let a = Topic::new("a", 3);
        let b = Topic::new("b", 3);
        let recs: Vec<ProducerRecord> = (0..9).map(|i| ProducerRecord::new(vec![i])).collect();
        let singles: Vec<(usize, u64)> = recs.iter().cloned().map(|r| a.publish(r)).collect();
        let batched = b.publish_many(recs);
        assert_eq!(singles, batched, "grouped append must keep ack order");
        for p in 0..3 {
            assert_eq!(a.fetch(p, 0, 100).len(), b.fetch(p, 0, 100).len());
        }
    }

    #[test]
    fn publish_many_keeps_keyed_records_on_their_partition() {
        let t = Topic::new("t", 4);
        let recs: Vec<ProducerRecord> =
            (0..8).map(|i| ProducerRecord::with_key(b"k".to_vec(), vec![i])).collect();
        let acks = t.publish_many(recs);
        let p0 = acks[0].0;
        assert!(acks.iter().all(|&(p, _)| p == p0), "same key → same partition");
        // Offsets are dense in submission order within the partition.
        assert_eq!(acks.iter().map(|&(_, o)| o).collect::<Vec<_>>(), (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn offsets_of_snapshots_one_partition() {
        let t = Topic::new("t", 2);
        t.publish_to(1, ProducerRecord::new(vec![0]));
        assert_eq!(t.offsets_of(0), (0, 0));
        assert_eq!(t.offsets_of(1), (0, 1));
    }
}
