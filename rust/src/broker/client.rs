//! `BrokerClient`: one API over two transports — embedded (`Arc<BrokerCore>`
//! call-through) or remote (framed TCP). The DistroStream layer only ever
//! sees this type (through [`super::StreamBroker`]), so streams are
//! backend-location agnostic, exactly like the paper's
//! ODSPublisher/ODSConsumer hide Kafka.
//!
//! The remote transport is **self-healing**: a send/recv failure drops the
//! socket and retries with exponential backoff for
//! [`RECONNECT_WINDOW`], so a broker restart mid-workload surfaces as
//! latency, not an error. Reconnect retries make remote requests
//! at-least-once (a request whose response was lost may be re-applied);
//! the broker's operations are either idempotent or append-semantic, so
//! callers see duplicate-publish at worst, never loss. The same re-apply
//! can make a non-idempotent control call report its own success as a
//! conflict — a `create_topic` whose ack was lost in the restart may
//! come back `TopicExists`, a `delete_topic` as `UnknownTopic` — so
//! callers racing a broker restart should treat those as
//! possibly-already-applied. The client also
//! remembers its `join_group` registrations and transparently re-joins
//! when a restarted broker answers `UnknownGroup`/`UnknownMember` — with
//! durable storage (PR 3) the group resumes from its persisted committed
//! offsets.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::embedded::{BrokerCore, BrokerError, MultiFetch, Result, TopicStats};
use super::group::AssignmentMode;
use super::protocol::{error_from_code, ClusterMetaWire, Request, Response};
use super::record::{ProducerRecord, Record};
use crate::util::wire::{recv_msg, send_msg};

enum Transport {
    /// Zero-copy call-through: polls return `Arc`-shared records.
    Embedded(Arc<BrokerCore>),
    /// Mutex: the request/response protocol is strictly serial per
    /// connection; concurrent users each hold their own client. `None`
    /// means the socket broke and the next request reconnects.
    ///
    /// Long-poll fetches travel over a **separate** lazily-opened socket
    /// (`fetch_sock`): a consumer parked server-side must not serialise
    /// against publishes and control calls on the main socket.
    Remote {
        sock: Mutex<Option<TcpStream>>,
        addr: String,
        fetch_sock: Mutex<Option<TcpStream>>,
    },
}

/// Client-side slice of one remote long-poll round trip. Shorter than the
/// server clamp: bounds how long the fetch socket is held per request (two
/// consumers sharing a client alternate at this granularity) while staying
/// ~1000× cheaper than the old 500 µs spin loop.
const REMOTE_WAIT_SLICE_MS: u64 = 250;

/// How long a remote request keeps retrying reconnects before the
/// transport error surfaces — sized to ride out a broker restart.
pub const RECONNECT_WINDOW: Duration = Duration::from_secs(10);

/// First reconnect backoff (doubles up to [`RECONNECT_BACKOFF_CAP`]).
const RECONNECT_BACKOFF_START: Duration = Duration::from_millis(20);
const RECONNECT_BACKOFF_CAP: Duration = Duration::from_millis(1_000);

/// Handle to a broker, embedded or remote.
pub struct BrokerClient {
    transport: Transport,
    /// `(group, topic, member) → mode` for every join issued through this
    /// client — replayed when a restarted broker lost volatile group
    /// membership (cursors are recovered broker-side from the offset
    /// journal).
    joined: Mutex<HashMap<(String, String, String), AssignmentMode>>,
}

impl BrokerClient {
    /// In-process client sharing `core`.
    pub fn embedded(core: Arc<BrokerCore>) -> Self {
        Self { transport: Transport::Embedded(core), joined: Mutex::new(HashMap::new()) }
    }

    /// Connect to a TCP broker server (eagerly — a dead address fails
    /// here, not on first use).
    pub fn connect(addr: &str) -> Result<Self> {
        let sock = Self::open(addr)?;
        Ok(Self {
            transport: Transport::Remote {
                sock: Mutex::new(Some(sock)),
                addr: addr.to_string(),
                fetch_sock: Mutex::new(None),
            },
            joined: Mutex::new(HashMap::new()),
        })
    }

    fn open(addr: &str) -> Result<TcpStream> {
        let sock = TcpStream::connect(addr)
            .map_err(|e| BrokerError::Transport(format!("connect {addr}: {e}")))?;
        sock.set_nodelay(true).ok();
        Ok(sock)
    }

    /// Clone an embedded client (remote clients own a socket; open another).
    pub fn try_clone(&self) -> Option<Self> {
        match &self.transport {
            Transport::Embedded(core) => Some(Self::embedded(Arc::clone(core))),
            Transport::Remote { .. } => None,
        }
    }

    fn roundtrip(sock: &mut TcpStream, req: &Request) -> Result<Response> {
        send_msg(sock, req).map_err(|e| BrokerError::Transport(format!("send: {e}")))?;
        match recv_msg(sock) {
            Ok(Some(resp)) => Ok(resp),
            Ok(None) => Err(BrokerError::Transport("broker closed connection".into())),
            Err(e) => Err(BrokerError::Transport(format!("recv: {e}"))),
        }
    }

    /// One attempt on the (re)connected main socket.
    fn try_main(slot: &Mutex<Option<TcpStream>>, addr: &str, req: &Request) -> Result<Response> {
        let mut slot = slot.lock().unwrap();
        if slot.is_none() {
            *slot = Some(Self::open(addr)?);
        }
        let sock = slot.as_mut().expect("socket just ensured");
        let resp = Self::roundtrip(sock, req);
        if resp.is_err() {
            *slot = None; // broken: the next attempt reconnects
        }
        resp
    }

    fn rpc(&self, req: Request) -> Result<Response> {
        match &self.transport {
            Transport::Embedded(core) => Ok(super::server::dispatch(core, req)),
            Transport::Remote { sock, addr, .. } => {
                // Self-healing: reconnect-and-retry across a broker restart
                // instead of surfacing the first broken-pipe error.
                let deadline = Instant::now() + RECONNECT_WINDOW;
                let mut backoff = RECONNECT_BACKOFF_START;
                loop {
                    match Self::try_main(sock, addr, &req) {
                        Err(BrokerError::Transport(e)) => {
                            if Instant::now() + backoff > deadline {
                                return Err(BrokerError::Transport(e));
                            }
                            std::thread::sleep(backoff);
                            backoff = (backoff * 2).min(RECONNECT_BACKOFF_CAP);
                        }
                        other => return other,
                    }
                }
            }
        }
    }

    /// One request over the dedicated long-poll socket (opened on first
    /// use so clients that never long-poll cost one connection, not two).
    /// Single attempt — the long-poll loop owns the retry policy.
    fn fetch_rpc(&self, req: Request) -> Result<Response> {
        let Transport::Remote { addr, fetch_sock, .. } = &self.transport else {
            unreachable!("fetch_rpc is remote-only");
        };
        let mut slot = fetch_sock.lock().unwrap();
        if slot.is_none() {
            *slot = Some(Self::open(addr)?);
        }
        let sock = slot.as_mut().expect("fetch socket just ensured");
        let resp = Self::roundtrip(sock, &req);
        if resp.is_err() {
            // Drop a broken socket so the next long-poll reconnects.
            *slot = None;
        }
        resp
    }

    /// Replay a remembered join after a broker restart dropped the group.
    /// `true` when this client had joined `(group, topic, member)` and the
    /// re-join landed.
    fn rejoin(&self, group: &str, topic: &str, member: &str) -> bool {
        let key = (group.to_string(), topic.to_string(), member.to_string());
        let Some(mode) = self.joined.lock().unwrap().get(&key).copied() else {
            return false;
        };
        matches!(
            self.rpc(Request::JoinGroup {
                group: group.into(),
                topic: topic.into(),
                member: member.into(),
                mode,
            }),
            Ok(Response::Generation(_))
        )
    }

    fn expect_ok(&self, req: Request) -> Result<()> {
        match self.rpc(req)? {
            Response::Ok => Ok(()),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    // ---- public API (mirrors BrokerCore) --------------------------------

    pub fn ping(&self) -> Result<()> {
        match self.rpc(Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn create_topic(&self, name: &str, partitions: usize) -> Result<()> {
        self.expect_ok(Request::CreateTopic { name: name.into(), partitions })
    }

    pub fn ensure_topic(&self, name: &str, partitions: usize) -> Result<()> {
        self.expect_ok(Request::EnsureTopic { name: name.into(), partitions })
    }

    pub fn delete_topic(&self, name: &str) -> Result<()> {
        self.expect_ok(Request::DeleteTopic { name: name.into() })
    }

    pub fn topic_names(&self) -> Result<Vec<String>> {
        match self.rpc(Request::TopicNames)? {
            Response::Names(ns) => Ok(ns),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn topic_stats(&self, name: &str) -> Result<TopicStats> {
        match self.rpc(Request::TopicStats { name: name.into() })? {
            Response::Stats(s) => Ok(TopicStats {
                partitions: s.partitions,
                records: s.records,
                bytes: s.bytes,
                high_watermarks: s.high_watermarks,
                start_offsets: s.start_offsets,
                bytes_on_disk: s.bytes_on_disk,
                segments: s.segments,
                recovered_records: s.recovered_records,
            }),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn publish(&self, topic: &str, rec: ProducerRecord) -> Result<(usize, u64)> {
        match self.rpc(Request::Publish { topic: topic.into(), rec })? {
            Response::PubAck { partition, offset } => Ok((partition, offset)),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn publish_batch(
        &self,
        topic: &str,
        recs: Vec<ProducerRecord>,
    ) -> Result<Vec<(usize, u64)>> {
        match self.rpc(Request::PublishBatch { topic: topic.into(), recs })? {
            Response::PubBatchAck { acks } => Ok(acks),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn join_group(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        mode: AssignmentMode,
    ) -> Result<u64> {
        match self.rpc(Request::JoinGroup {
            group: group.into(),
            topic: topic.into(),
            member: member.into(),
            mode,
        })? {
            Response::Generation(g) => {
                // Remembered so a broker restart (which drops volatile
                // membership) heals transparently on the next fetch.
                self.joined
                    .lock()
                    .unwrap()
                    .insert((group.into(), topic.into(), member.into()), mode);
                Ok(g)
            }
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn leave_group(&self, group: &str, topic: &str, member: &str) -> Result<bool> {
        self.joined
            .lock()
            .unwrap()
            .remove(&(group.to_string(), topic.to_string(), member.to_string()));
        match self.rpc(Request::LeaveGroup {
            group: group.into(),
            topic: topic.into(),
            member: member.into(),
        })? {
            Response::Bool(b) => Ok(b),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn poll(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
    ) -> Result<Vec<Arc<Record>>> {
        match self.poll_raw(group, topic, member, max) {
            Err(e @ (BrokerError::UnknownGroup(_) | BrokerError::UnknownMember { .. })) => {
                if self.rejoin(group, topic, member) {
                    self.poll_raw(group, topic, member, max)
                } else {
                    Err(e)
                }
            }
            other => other,
        }
    }

    fn poll_raw(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
    ) -> Result<Vec<Arc<Record>>> {
        // Embedded transport: bypass the dispatch layer so records stay
        // Arc-shared (no payload copy).
        if let Transport::Embedded(core) = &self.transport {
            return core.poll(group, topic, member, max);
        }
        match self.rpc(Request::Poll {
            group: group.into(),
            topic: topic.into(),
            member: member.into(),
            max,
        })? {
            Response::Records(rs) => Ok(rs.into_iter().map(Arc::new).collect()),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Multi-partition drain: up to `max` records / `max_bytes` payload
    /// bytes for `member`, plus the group's post-claim cursor positions —
    /// one call (one wire frame, remotely) instead of poll + positions.
    pub fn fetch_many(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
    ) -> Result<MultiFetch> {
        self.fetch_many_wait(group, topic, member, max, max_bytes, 0)
    }

    /// [`BrokerClient::fetch_many`] that **blocks** until data or deadline
    /// (the long-poll plane). Embedded: parks on the topic's publish
    /// `Condvar` — zero round trips while idle. Remote: holds one
    /// outstanding `FetchMany` frame per wait slice; the server parks the
    /// connection, so an idle consumer costs ~4 frames/s instead of the
    /// ~2000 empty fetches/s of a 500 µs spin loop. A broker restart
    /// mid-poll reconnects (and re-joins the group when this client had
    /// joined it) instead of erroring.
    pub fn fetch_many_wait(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
        wait_ms: u64,
    ) -> Result<MultiFetch> {
        match self.fetch_many_wait_raw(group, topic, member, max, max_bytes, wait_ms) {
            Err(e @ (BrokerError::UnknownGroup(_) | BrokerError::UnknownMember { .. })) => {
                if self.rejoin(group, topic, member) {
                    self.fetch_many_wait_raw(group, topic, member, max, max_bytes, wait_ms)
                } else {
                    Err(e)
                }
            }
            other => other,
        }
    }

    fn fetch_many_wait_raw(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
        wait_ms: u64,
    ) -> Result<MultiFetch> {
        // Embedded transport: bypass the dispatch layer so records stay
        // Arc-shared (no payload copy).
        if let Transport::Embedded(core) = &self.transport {
            return core.fetch_many_wait(group, topic, member, max, max_bytes, wait_ms);
        }
        // Clamped like the embedded path: no Instant overflow on "forever".
        let wait_ms = wait_ms.min(super::embedded::MAX_WAIT_HORIZON_MS);
        let deadline = Instant::now() + Duration::from_millis(wait_ms);
        let mut backoff = RECONNECT_BACKOFF_START;
        loop {
            let remaining_ms =
                deadline.saturating_duration_since(Instant::now()).as_millis() as u64;
            let slice = remaining_ms.min(REMOTE_WAIT_SLICE_MS);
            let req = Request::FetchMany {
                group: group.into(),
                topic: topic.into(),
                member: member.into(),
                max,
                max_bytes,
                wait_ms: slice,
            };
            let resp = if slice == 0 { self.rpc(req) } else { self.fetch_rpc(req) };
            match resp {
                Ok(Response::Batches { batches, positions }) => {
                    let mf = MultiFetch {
                        batches: batches
                            .into_iter()
                            .map(|(p, rs)| (p, rs.into_iter().map(Arc::new).collect()))
                            .collect(),
                        positions,
                    };
                    if !mf.batches.is_empty() || remaining_ms <= slice {
                        return Ok(mf);
                    }
                    // Empty slice with time left: park again.
                }
                Ok(Response::Err { code, msg }) => return Err(error_from_code(code, msg)),
                Ok(other) => {
                    return Err(BrokerError::Transport(format!("unexpected response {other:?}")))
                }
                Err(BrokerError::Transport(e)) => {
                    // Mid-poll broker restart: back off and re-poll while
                    // the deadline allows instead of surfacing the break.
                    if remaining_ms == 0 {
                        return Err(BrokerError::Transport(e));
                    }
                    std::thread::sleep(
                        backoff.min(Duration::from_millis(remaining_ms)),
                    );
                    backoff = (backoff * 2).min(RECONNECT_BACKOFF_CAP);
                }
                Err(e) => return Err(e),
            }
        }
    }

    pub fn commit(&self, group: &str, topic: &str, commits: &[(usize, u64)]) -> Result<()> {
        let req = || Request::Commit {
            group: group.into(),
            topic: topic.into(),
            commits: commits.to_vec(),
        };
        match self.expect_ok(req()) {
            // A restarted broker dropped the (volatile) group: re-join and
            // re-commit — the commit point is what makes resume correct.
            Err(BrokerError::UnknownGroup(_)) if self.rejoin_any(group, topic) => {
                self.expect_ok(req())
            }
            other => other,
        }
    }

    /// Replay every remembered join of `(group, topic)` (commit has no
    /// member argument). `true` when at least one re-join landed.
    fn rejoin_any(&self, group: &str, topic: &str) -> bool {
        let members: Vec<String> = self
            .joined
            .lock()
            .unwrap()
            .keys()
            .filter(|(g, t, _)| g == group && t == topic)
            .map(|(_, _, m)| m.clone())
            .collect();
        let mut any = false;
        for m in members {
            any |= self.rejoin(group, topic, &m);
        }
        any
    }

    pub fn delete_records(&self, topic: &str, partition: usize, up_to: u64) -> Result<usize> {
        match self.rpc(Request::DeleteRecords { topic: topic.into(), partition, up_to })? {
            Response::Count(n) => Ok(n),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn offsets(&self, topic: &str) -> Result<Vec<(u64, u64)>> {
        match self.rpc(Request::Offsets { topic: topic.into() })? {
            Response::OffsetList(os) => Ok(os),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// (claim position, committed) per partition for a group.
    pub fn positions(&self, group: &str, topic: &str) -> Result<Vec<(u64, u64)>> {
        match self.rpc(Request::Positions { group: group.into(), topic: topic.into() })? {
            Response::OffsetList(os) => Ok(os),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn crash_member(&self, group: &str, topic: &str, member: &str) -> Result<()> {
        self.expect_ok(Request::CrashMember {
            group: group.into(),
            topic: topic.into(),
            member: member.into(),
        })
    }

    /// Publish a batch to one **explicit** partition (the cluster data
    /// plane — see [`super::cluster::ClusterClient`]); returns the
    /// assigned offsets in order. A cluster member that does not own the
    /// partition answers [`BrokerError::NotOwner`].
    pub fn publish_to(
        &self,
        topic: &str,
        partition: usize,
        recs: Vec<ProducerRecord>,
    ) -> Result<Vec<u64>> {
        if let Transport::Embedded(core) = &self.transport {
            return core.publish_to(topic, partition, recs);
        }
        match self.rpc(Request::PublishTo { topic: topic.into(), partition, recs })? {
            Response::PubBatchAck { acks } => Ok(acks.into_iter().map(|(_, o)| o).collect()),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Cluster membership snapshot (empty member list from a standalone
    /// broker).
    pub fn cluster_meta(&self) -> Result<ClusterMetaWire> {
        match self.rpc(Request::ClusterMeta)? {
            Response::Cluster(meta) => Ok(meta),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }
}

impl super::StreamBroker for BrokerClient {
    fn ping(&self) -> Result<()> {
        BrokerClient::ping(self)
    }
    fn create_topic(&self, name: &str, partitions: usize) -> Result<()> {
        BrokerClient::create_topic(self, name, partitions)
    }
    fn ensure_topic(&self, name: &str, partitions: usize) -> Result<()> {
        BrokerClient::ensure_topic(self, name, partitions)
    }
    fn delete_topic(&self, name: &str) -> Result<()> {
        BrokerClient::delete_topic(self, name)
    }
    fn topic_names(&self) -> Result<Vec<String>> {
        BrokerClient::topic_names(self)
    }
    fn topic_stats(&self, name: &str) -> Result<TopicStats> {
        BrokerClient::topic_stats(self, name)
    }
    fn publish(&self, topic: &str, rec: ProducerRecord) -> Result<(usize, u64)> {
        BrokerClient::publish(self, topic, rec)
    }
    fn publish_batch(&self, topic: &str, recs: Vec<ProducerRecord>) -> Result<Vec<(usize, u64)>> {
        BrokerClient::publish_batch(self, topic, recs)
    }
    fn join_group(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        mode: AssignmentMode,
    ) -> Result<u64> {
        BrokerClient::join_group(self, group, topic, member, mode)
    }
    fn leave_group(&self, group: &str, topic: &str, member: &str) -> Result<bool> {
        BrokerClient::leave_group(self, group, topic, member)
    }
    fn poll(&self, group: &str, topic: &str, member: &str, max: usize) -> Result<Vec<Arc<Record>>> {
        BrokerClient::poll(self, group, topic, member, max)
    }
    fn fetch_many_wait(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
        wait_ms: u64,
    ) -> Result<MultiFetch> {
        BrokerClient::fetch_many_wait(self, group, topic, member, max, max_bytes, wait_ms)
    }
    fn commit(&self, group: &str, topic: &str, commits: &[(usize, u64)]) -> Result<()> {
        BrokerClient::commit(self, group, topic, commits)
    }
    fn delete_records(&self, topic: &str, partition: usize, up_to: u64) -> Result<usize> {
        BrokerClient::delete_records(self, topic, partition, up_to)
    }
    fn offsets(&self, topic: &str) -> Result<Vec<(u64, u64)>> {
        BrokerClient::offsets(self, topic)
    }
    fn positions(&self, group: &str, topic: &str) -> Result<Vec<(u64, u64)>> {
        BrokerClient::positions(self, group, topic)
    }
    fn crash_member(&self, group: &str, topic: &str, member: &str) -> Result<()> {
        BrokerClient::crash_member(self, group, topic, member)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::server::BrokerServer;

    fn exercise(client: &BrokerClient) {
        client.create_topic("t", 2).unwrap();
        assert!(client.create_topic("t", 2).is_err());
        client.publish("t", ProducerRecord::new(vec![1])).unwrap();
        client
            .publish_batch("t", vec![ProducerRecord::new(vec![2]), ProducerRecord::new(vec![3])])
            .unwrap();
        client.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let recs = client.poll("g", "t", "m", usize::MAX).unwrap();
        assert_eq!(recs.len(), 3);
        // Batched drain: publish another batch, take it in one fetch_many.
        client
            .publish_batch("t", vec![ProducerRecord::new(vec![4]), ProducerRecord::new(vec![5])])
            .unwrap();
        let mf = client.fetch_many("g", "t", "m", usize::MAX, usize::MAX).unwrap();
        assert_eq!(mf.record_count(), 2);
        assert_eq!(mf.positions.len(), 2);
        client.commit("g", "t", &[(0, 2)]).unwrap();
        let stats = client.topic_stats("t").unwrap();
        assert_eq!(stats.partitions, 2);
        assert_eq!(stats.records, 5);
        for (p, (_s, hw)) in client.offsets("t").unwrap().into_iter().enumerate() {
            client.delete_records("t", p, hw).unwrap();
        }
        assert_eq!(client.topic_stats("t").unwrap().records, 0);
        assert!(client.leave_group("g", "t", "m").unwrap());
        client.delete_topic("t").unwrap();
    }

    #[test]
    fn embedded_end_to_end() {
        let client = BrokerClient::embedded(BrokerCore::new());
        exercise(&client);
    }

    #[test]
    fn remote_end_to_end() {
        let server = BrokerServer::start(BrokerCore::new(), "127.0.0.1:0").unwrap();
        let client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.ping().unwrap();
        exercise(&client);
        server.shutdown();
    }

    #[test]
    fn remote_fetch_many_respects_budgets() {
        let server = BrokerServer::start(BrokerCore::new(), "127.0.0.1:0").unwrap();
        let client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.create_topic("t", 2).unwrap();
        for _ in 0..8 {
            client.publish("t", ProducerRecord::new(vec![0; 10])).unwrap();
        }
        client.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let mf = client.fetch_many("g", "t", "m", usize::MAX, 45).unwrap();
        assert_eq!(mf.record_count(), 4, "45-byte budget → 4 × 10-byte records");
        let rest = client.fetch_many("g", "t", "m", usize::MAX, usize::MAX).unwrap();
        assert_eq!(rest.record_count(), 4, "budget cut must not lose records");
        server.shutdown();
    }

    #[test]
    fn remote_fetch_many_wait_parks_and_wakes() {
        use std::time::{Duration, Instant};
        let server = BrokerServer::start(BrokerCore::new(), "127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let producer = BrokerClient::connect(&addr).unwrap();
        producer.create_topic("t", 1).unwrap();
        let consumer = BrokerClient::connect(&addr).unwrap();
        consumer.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        // Expiry on an empty topic: no data, no error, full wait.
        let t0 = Instant::now();
        let mf = consumer.fetch_many_wait("g", "t", "m", usize::MAX, usize::MAX, 40).unwrap();
        assert_eq!(mf.record_count(), 0);
        assert!(t0.elapsed() >= Duration::from_millis(40));
        // Wakeup: a publish from the other client releases the parked wait.
        let waiter = std::thread::spawn(move || {
            let t0 = Instant::now();
            let mf = consumer
                .fetch_many_wait("g", "t", "m", usize::MAX, usize::MAX, 10_000)
                .unwrap();
            (mf.record_count(), t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(30));
        producer.publish("t", ProducerRecord::new(vec![5])).unwrap();
        let (count, waited) = waiter.join().unwrap();
        assert_eq!(count, 1);
        assert!(waited < Duration::from_secs(5), "server must wake the parked fetch");
        server.shutdown();
    }

    #[test]
    fn two_remote_clients_share_state() {
        let server = BrokerServer::start(BrokerCore::new(), "127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let producer = BrokerClient::connect(&addr).unwrap();
        let consumer = BrokerClient::connect(&addr).unwrap();
        producer.create_topic("t", 1).unwrap();
        producer.publish("t", ProducerRecord::new(vec![42])).unwrap();
        consumer.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let recs = consumer.poll("g", "t", "m", usize::MAX).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].value.as_slice(), &[42]);
        server.shutdown();
    }
}
