//! `BrokerClient`: one API over two transports — embedded (`Arc<BrokerCore>`
//! call-through) or remote (pipelined mux TCP, see [`crate::util::mux`]).
//! The DistroStream layer only ever sees this type (through
//! [`super::StreamBroker`]), so streams are backend-location agnostic,
//! exactly like the paper's ODSPublisher/ODSConsumer hide Kafka.
//!
//! The remote transport multiplexes every request over **one socket**:
//! concurrent callers (publishers, parked long-polls, control calls) each
//! hold an outstanding correlation id instead of serialising on a socket
//! mutex, and [`BrokerClient::pipeline`] keeps a bounded window of publish
//! frames in flight so throughput scales past `1/RTT`.
//!
//! The remote transport is **self-healing**: a send/recv failure drops the
//! socket and retries with exponential backoff for
//! [`RECONNECT_WINDOW`], so a broker restart mid-workload surfaces as
//! latency, not an error. Reconnect retries make remote requests
//! at-least-once (a request whose response was lost may be re-applied);
//! the broker's operations are either idempotent or append-semantic, so
//! callers see duplicate-publish at worst, never loss. The same re-apply
//! can make a non-idempotent control call report its own success as a
//! conflict — a `create_topic` whose ack was lost in the restart may
//! come back `TopicExists`, a `delete_topic` as `UnknownTopic` — so
//! callers racing a broker restart should treat those as
//! possibly-already-applied. The client also
//! remembers its `join_group` registrations and transparently re-joins
//! when a restarted broker answers `UnknownGroup`/`UnknownMember` — with
//! durable storage (PR 3) the group resumes from its persisted committed
//! offsets.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::embedded::{BrokerCore, BrokerError, MultiFetch, Result, TopicStats};
use super::group::AssignmentMode;
use super::protocol::{error_from_code, ClusterMetaWire, Request, Response};
use super::record::{ProducerRecord, Record};
use super::storage::OffsetEntry;
use crate::util::mux::{MuxConn, MuxSlot, PendingReply};
use crate::util::trace;

enum Transport {
    /// Zero-copy call-through: polls return `Arc`-shared records.
    Embedded(Arc<BrokerCore>),
    /// One pipelined mux connection (PR 5) in a reconnectable slot: any
    /// number of threads issue requests concurrently over the single
    /// socket — each call is just an outstanding correlation id, so a
    /// consumer parked in a server-side long-poll no longer serialises
    /// against publishes and control calls (the old dedicated fetch socket
    /// folded into the mux). A broken connection is dropped from the slot
    /// and the next request reconnects.
    Remote(MuxSlot),
}

/// Client-side slice of one remote long-poll round trip. Shorter than the
/// server clamp: bounds how long one park outlives its caller's deadline
/// while staying ~1000× cheaper than the old 500 µs spin loop. On the mux
/// a parked slice is just an outstanding id — it holds no socket, so other
/// consumers and publishers proceed concurrently.
const REMOTE_WAIT_SLICE_MS: u64 = 250;

/// How long a remote request keeps retrying reconnects before the
/// transport error surfaces — sized to ride out a broker restart.
pub const RECONNECT_WINDOW: Duration = Duration::from_secs(10);

/// First reconnect backoff (doubles up to [`RECONNECT_BACKOFF_CAP`]).
const RECONNECT_BACKOFF_START: Duration = Duration::from_millis(20);
const RECONNECT_BACKOFF_CAP: Duration = Duration::from_millis(1_000);

/// Handle to a broker, embedded or remote.
pub struct BrokerClient {
    transport: Transport,
    /// `(group, topic, member) → mode` for every join issued through this
    /// client — replayed when a restarted broker lost volatile group
    /// membership (cursors are recovered broker-side from the offset
    /// journal).
    joined: Mutex<HashMap<(String, String, String), AssignmentMode>>,
}

impl BrokerClient {
    /// In-process client sharing `core`.
    pub fn embedded(core: Arc<BrokerCore>) -> Self {
        Self { transport: Transport::Embedded(core), joined: Mutex::new(HashMap::new()) }
    }

    /// Connect to a TCP broker server (eagerly — a dead or legacy-only
    /// address fails here, at the mux handshake, not on first use).
    pub fn connect(addr: &str) -> Result<Self> {
        let conn = MuxConn::connect(addr)
            .map(Arc::new)
            .map_err(|e| BrokerError::Transport(format!("connect {addr}: {e}")))?;
        Ok(Self {
            transport: Transport::Remote(MuxSlot::connected(addr, conn)),
            joined: Mutex::new(HashMap::new()),
        })
    }

    /// Clone an embedded client (remote clients own a connection; open
    /// another).
    pub fn try_clone(&self) -> Option<Self> {
        match &self.transport {
            Transport::Embedded(core) => Some(Self::embedded(Arc::clone(core))),
            Transport::Remote(_) => None,
        }
    }

    /// The live mux connection, (re)established on demand (see
    /// [`MuxSlot::get`] — concurrent callers all fly on the same `Arc`).
    fn conn(&self) -> Result<Arc<MuxConn>> {
        let Transport::Remote(slot) = &self.transport else {
            unreachable!("conn() is remote-only");
        };
        slot.get()
            .map_err(|e| BrokerError::Transport(format!("connect {}: {e}", slot.addr())))
    }

    /// Forget `failed` so the next request reconnects (unless a concurrent
    /// caller already replaced it).
    fn invalidate(&self, failed: &Arc<MuxConn>) {
        if let Transport::Remote(slot) = &self.transport {
            slot.invalidate(failed);
        }
    }

    /// One attempt, any transport: embedded dispatch, or a single remote
    /// round trip with **no** reconnect window. The replication and
    /// failover planes use this — a replicator probing a dead follower
    /// (or a client probing a dead leader) must learn about the death in
    /// one connect timeout, not after the full 10 s reconnect window.
    pub(crate) fn rpc_once(&self, req: Request) -> Result<Response> {
        match &self.transport {
            Transport::Embedded(core) => Ok(super::server::dispatch(core, req)),
            Transport::Remote(_) => self.try_once(&req),
        }
    }

    /// One attempt over the (re)connected mux: single round trip, no
    /// retry — the callers own their retry policies.
    fn try_once(&self, req: &Request) -> Result<Response> {
        let conn = self.conn()?;
        match conn.call::<Request, Response>(req) {
            Ok(resp) => Ok(resp),
            Err(e) => {
                self.invalidate(&conn);
                Err(BrokerError::Transport(format!("rpc: {e}")))
            }
        }
    }

    fn rpc(&self, req: Request) -> Result<Response> {
        match &self.transport {
            Transport::Embedded(core) => Ok(super::server::dispatch(core, req)),
            Transport::Remote(_) => {
                // Self-healing: reconnect-and-retry across a broker restart
                // instead of surfacing the first broken-pipe error.
                let deadline = Instant::now() + RECONNECT_WINDOW;
                let mut backoff = RECONNECT_BACKOFF_START;
                loop {
                    match self.try_once(&req) {
                        Err(BrokerError::Transport(e)) => {
                            if Instant::now() + backoff > deadline {
                                return Err(BrokerError::Transport(e));
                            }
                            std::thread::sleep(backoff);
                            backoff = (backoff * 2).min(RECONNECT_BACKOFF_CAP);
                        }
                        other => return other,
                    }
                }
            }
        }
    }

    /// Replay a remembered join after a broker restart dropped the group.
    /// `true` when this client had joined `(group, topic, member)` and the
    /// re-join landed.
    fn rejoin(&self, group: &str, topic: &str, member: &str) -> bool {
        let key = (group.to_string(), topic.to_string(), member.to_string());
        let Some(mode) = self.joined.lock().unwrap().get(&key).copied() else {
            return false;
        };
        matches!(
            self.rpc(Request::JoinGroup {
                group: group.into(),
                topic: topic.into(),
                member: member.into(),
                mode,
            }),
            Ok(Response::Generation(_))
        )
    }

    fn expect_ok(&self, req: Request) -> Result<()> {
        match self.rpc(req)? {
            Response::Ok => Ok(()),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    // ---- public API (mirrors BrokerCore) --------------------------------

    pub fn ping(&self) -> Result<()> {
        match self.rpc(Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn create_topic(&self, name: &str, partitions: usize) -> Result<()> {
        self.expect_ok(Request::CreateTopic { name: name.into(), partitions })
    }

    pub fn ensure_topic(&self, name: &str, partitions: usize) -> Result<()> {
        self.expect_ok(Request::EnsureTopic { name: name.into(), partitions })
    }

    pub fn delete_topic(&self, name: &str) -> Result<()> {
        self.expect_ok(Request::DeleteTopic { name: name.into() })
    }

    pub fn topic_names(&self) -> Result<Vec<String>> {
        match self.rpc(Request::TopicNames)? {
            Response::Names(ns) => Ok(ns),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn topic_stats(&self, name: &str) -> Result<TopicStats> {
        match self.rpc(Request::TopicStats { name: name.into() })? {
            Response::Stats(s) => Ok(TopicStats {
                partitions: s.partitions,
                records: s.records,
                bytes: s.bytes,
                high_watermarks: s.high_watermarks,
                start_offsets: s.start_offsets,
                bytes_on_disk: s.bytes_on_disk,
                segments: s.segments,
                recovered_records: s.recovered_records,
            }),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn publish(&self, topic: &str, rec: ProducerRecord) -> Result<(usize, u64)> {
        let _root = trace::span_root("client.publish");
        match self.rpc(Request::Publish { topic: topic.into(), rec })? {
            Response::PubAck { partition, offset } => Ok((partition, offset)),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn publish_batch(
        &self,
        topic: &str,
        recs: Vec<ProducerRecord>,
    ) -> Result<Vec<(usize, u64)>> {
        let _root = trace::span_root("client.publish");
        match self.rpc(Request::PublishBatch { topic: topic.into(), recs })? {
            Response::PubBatchAck { acks } => Ok(acks),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn join_group(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        mode: AssignmentMode,
    ) -> Result<u64> {
        match self.rpc(Request::JoinGroup {
            group: group.into(),
            topic: topic.into(),
            member: member.into(),
            mode,
        })? {
            Response::Generation(g) => {
                // Remembered so a broker restart (which drops volatile
                // membership) heals transparently on the next fetch.
                self.joined
                    .lock()
                    .unwrap()
                    .insert((group.into(), topic.into(), member.into()), mode);
                Ok(g)
            }
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn leave_group(&self, group: &str, topic: &str, member: &str) -> Result<bool> {
        self.joined
            .lock()
            .unwrap()
            .remove(&(group.to_string(), topic.to_string(), member.to_string()));
        match self.rpc(Request::LeaveGroup {
            group: group.into(),
            topic: topic.into(),
            member: member.into(),
        })? {
            Response::Bool(b) => Ok(b),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn poll(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
    ) -> Result<Vec<Arc<Record>>> {
        let res = match self.poll_raw(group, topic, member, max) {
            Err(e @ (BrokerError::UnknownGroup(_) | BrokerError::UnknownMember { .. })) => {
                if self.rejoin(group, topic, member) {
                    self.poll_raw(group, topic, member, max)
                } else {
                    Err(e)
                }
            }
            other => other,
        };
        // Close the publish → consume loop: the response carried the
        // publish's trace ctx (set by the fetch wakeup), so the delivery
        // shows up as a leaf of the publish's span tree.
        let rctx = trace::take_reply();
        if rctx.sampled() && matches!(&res, Ok(rs) if !rs.is_empty()) {
            trace::record_at(rctx, "consumer.poll", trace::now_us(), 0);
        }
        res
    }

    fn poll_raw(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
    ) -> Result<Vec<Arc<Record>>> {
        // Embedded transport: bypass the dispatch layer so records stay
        // Arc-shared (no payload copy).
        if let Transport::Embedded(core) = &self.transport {
            return core.poll(group, topic, member, max);
        }
        match self.rpc(Request::Poll {
            group: group.into(),
            topic: topic.into(),
            member: member.into(),
            max,
        })? {
            Response::Records(rs) => Ok(rs.into_iter().map(Arc::new).collect()),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Multi-partition drain: up to `max` records / `max_bytes` payload
    /// bytes for `member`, plus the group's post-claim cursor positions —
    /// one call (one wire frame, remotely) instead of poll + positions.
    pub fn fetch_many(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
    ) -> Result<MultiFetch> {
        self.fetch_many_wait(group, topic, member, max, max_bytes, 0)
    }

    /// [`BrokerClient::fetch_many`] that **blocks** until data or deadline
    /// (the long-poll plane). Embedded: parks on the topic's publish
    /// `Condvar` — zero round trips while idle. Remote: holds one
    /// outstanding `FetchMany` id on the mux per wait slice; the server
    /// parks it on its own thread, so an idle consumer costs ~4 frames/s
    /// instead of the ~2000 empty fetches/s of a 500 µs spin loop — and
    /// publishes/control calls keep flowing on the same socket while it
    /// parks. A broker restart mid-poll reconnects (and re-joins the group
    /// when this client had joined it) instead of erroring.
    pub fn fetch_many_wait(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
        wait_ms: u64,
    ) -> Result<MultiFetch> {
        let res = match self.fetch_many_wait_raw(group, topic, member, max, max_bytes, wait_ms) {
            Err(e @ (BrokerError::UnknownGroup(_) | BrokerError::UnknownMember { .. })) => {
                if self.rejoin(group, topic, member) {
                    self.fetch_many_wait_raw(group, topic, member, max, max_bytes, wait_ms)
                } else {
                    Err(e)
                }
            }
            other => other,
        };
        // See `poll`: stitch the delivery into the publish's trace.
        let rctx = trace::take_reply();
        if rctx.sampled() && matches!(&res, Ok(mf) if !mf.batches.is_empty()) {
            trace::record_at(rctx, "consumer.poll", trace::now_us(), 0);
        }
        res
    }

    fn fetch_many_wait_raw(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
        wait_ms: u64,
    ) -> Result<MultiFetch> {
        // Embedded transport: bypass the dispatch layer so records stay
        // Arc-shared (no payload copy).
        if let Transport::Embedded(core) = &self.transport {
            return core.fetch_many_wait(group, topic, member, max, max_bytes, wait_ms);
        }
        // Clamped like the embedded path: no Instant overflow on "forever".
        let wait_ms = wait_ms.min(super::embedded::MAX_WAIT_HORIZON_MS);
        let deadline = Instant::now() + Duration::from_millis(wait_ms);
        let mut backoff = RECONNECT_BACKOFF_START;
        loop {
            let remaining_ms =
                deadline.saturating_duration_since(Instant::now()).as_millis() as u64;
            let slice = remaining_ms.min(REMOTE_WAIT_SLICE_MS);
            let req = Request::FetchMany {
                group: group.into(),
                topic: topic.into(),
                member: member.into(),
                max,
                max_bytes,
                wait_ms: slice,
            };
            // Waiting slices are single attempts (this loop owns the retry
            // policy); a zero-wait sweep keeps the full reconnect window.
            let resp = if slice == 0 { self.rpc(req) } else { self.try_once(&req) };
            match resp {
                Ok(Response::Batches { batches, positions }) => {
                    let mf = MultiFetch {
                        batches: batches
                            .into_iter()
                            .map(|(p, rs)| (p, rs.into_iter().map(Arc::new).collect()))
                            .collect(),
                        positions,
                    };
                    if !mf.batches.is_empty() || remaining_ms <= slice {
                        return Ok(mf);
                    }
                    // Empty slice with time left: park again.
                }
                Ok(Response::Err { code, msg }) => return Err(error_from_code(code, msg)),
                Ok(other) => {
                    return Err(BrokerError::Transport(format!("unexpected response {other:?}")))
                }
                Err(BrokerError::Transport(e)) => {
                    // Mid-poll broker restart: back off and re-poll while
                    // the deadline allows instead of surfacing the break.
                    if remaining_ms == 0 {
                        return Err(BrokerError::Transport(e));
                    }
                    std::thread::sleep(
                        backoff.min(Duration::from_millis(remaining_ms)),
                    );
                    backoff = (backoff * 2).min(RECONNECT_BACKOFF_CAP);
                }
                Err(e) => return Err(e),
            }
        }
    }

    pub fn commit(&self, group: &str, topic: &str, commits: &[(usize, u64)]) -> Result<()> {
        let req = || Request::Commit {
            group: group.into(),
            topic: topic.into(),
            commits: commits.to_vec(),
        };
        match self.expect_ok(req()) {
            // A restarted broker dropped the (volatile) group: re-join and
            // re-commit — the commit point is what makes resume correct.
            Err(BrokerError::UnknownGroup(_)) if self.rejoin_any(group, topic) => {
                self.expect_ok(req())
            }
            other => other,
        }
    }

    /// Replay every remembered join of `(group, topic)` (commit has no
    /// member argument). `true` when at least one re-join landed.
    fn rejoin_any(&self, group: &str, topic: &str) -> bool {
        let members: Vec<String> = self
            .joined
            .lock()
            .unwrap()
            .keys()
            .filter(|(g, t, _)| g == group && t == topic)
            .map(|(_, _, m)| m.clone())
            .collect();
        let mut any = false;
        for m in members {
            any |= self.rejoin(group, topic, &m);
        }
        any
    }

    pub fn delete_records(&self, topic: &str, partition: usize, up_to: u64) -> Result<usize> {
        match self.rpc(Request::DeleteRecords { topic: topic.into(), partition, up_to })? {
            Response::Count(n) => Ok(n),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn offsets(&self, topic: &str) -> Result<Vec<(u64, u64)>> {
        match self.rpc(Request::Offsets { topic: topic.into() })? {
            Response::OffsetList(os) => Ok(os),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// (claim position, committed) per partition for a group.
    pub fn positions(&self, group: &str, topic: &str) -> Result<Vec<(u64, u64)>> {
        match self.rpc(Request::Positions { group: group.into(), topic: topic.into() })? {
            Response::OffsetList(os) => Ok(os),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn crash_member(&self, group: &str, topic: &str, member: &str) -> Result<()> {
        self.expect_ok(Request::CrashMember {
            group: group.into(),
            topic: topic.into(),
            member: member.into(),
        })
    }

    /// Publish a batch to one **explicit** partition (the cluster data
    /// plane — see [`super::cluster::ClusterClient`]); returns the
    /// assigned offsets in order. A cluster member that does not lead the
    /// partition answers [`BrokerError::NotOwner`]. `acks` is
    /// [`super::protocol::ACKS_LEADER`] or
    /// [`super::protocol::ACKS_QUORUM`]: quorum
    /// publishes return only after the leader's in-sync followers have
    /// confirmed the records (standalone brokers ack immediately either
    /// way — there is nobody to wait for).
    pub fn publish_to(
        &self,
        topic: &str,
        partition: usize,
        recs: Vec<ProducerRecord>,
        acks: u8,
    ) -> Result<Vec<u64>> {
        let _root = trace::span_root("client.publish");
        if let Transport::Embedded(core) = &self.transport {
            return core.publish_to(topic, partition, recs);
        }
        match self.rpc(Request::PublishTo { topic: topic.into(), partition, recs, acks })? {
            Response::PubBatchAck { acks } => Ok(acks.into_iter().map(|(_, o)| o).collect()),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    // ---- replication plane (PR 7) ---------------------------------------

    /// Ship one replication frame to a follower: `recs` start at `base`
    /// under leadership `epoch`. Returns the follower's high watermark
    /// after the apply (`< base + recs.len()` = backfill request). Single
    /// attempt — the replicator owns liveness policy.
    pub(crate) fn replicate(
        &self,
        topic: &str,
        partitions: usize,
        partition: usize,
        epoch: u64,
        base: u64,
        recs: Vec<Record>,
    ) -> Result<u64> {
        let req = Request::Replicate {
            topic: topic.into(),
            partitions,
            partition,
            epoch,
            base,
            recs,
        };
        match self.rpc_once(req)? {
            Response::RepAck { hw } => Ok(hw),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Ship consumer-group cursors to a follower (single attempt).
    pub(crate) fn sync_offsets(&self, topic: &str, entries: Vec<OffsetEntry>) -> Result<()> {
        match self.rpc_once(Request::OffsetSync { topic: topic.into(), entries })? {
            Response::Ok => Ok(()),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Ask this broker to take leadership of `(topic, partition)` (client
    /// failover). Returns the new fencing epoch. Single attempt — the
    /// caller is probing candidates and must fail fast.
    pub fn promote(&self, topic: &str, partition: usize, partitions: usize) -> Result<u64> {
        match self.rpc_once(Request::Promote { topic: topic.into(), partitions, partition })? {
            Response::Epoch(e) => Ok(e),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Cluster membership snapshot (empty member list from a standalone
    /// broker).
    pub fn cluster_meta(&self) -> Result<ClusterMetaWire> {
        match self.rpc(Request::ClusterMeta)? {
            Response::Cluster(meta) => Ok(meta),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Scrape the broker's observability snapshot (PR 8): every counter,
    /// gauge and histogram its process has registered. Embedded transports
    /// read the shared in-process registry directly.
    pub fn metrics(&self) -> Result<crate::util::obs::Snapshot> {
        if matches!(self.transport, Transport::Embedded(_)) {
            return Ok(crate::util::obs::snapshot());
        }
        match self.rpc(Request::Metrics)? {
            Response::Metrics(snap) => Ok(snap),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Drain the broker's span flight recorder (PR 9): every finished
    /// span still in its bounded ring, oldest first. `trace_id = 0`
    /// returns all traces; non-zero filters to one. Embedded transports
    /// read the shared in-process ring directly.
    pub fn spans(&self, trace_id: u64) -> Result<Vec<trace::Span>> {
        if matches!(self.transport, Transport::Embedded(_)) {
            return Ok(trace::snapshot_wire(trace_id));
        }
        match self.rpc(Request::Spans { trace_id })? {
            Response::Spans(spans) => Ok(spans),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    // ---- membership plane (PR 10) ---------------------------------------

    /// Ask a cluster member (the seed) for an epoch-bumped spec that
    /// includes `member`. The seed derives it without installing it — the
    /// joiner installs and gossips once its partition pulls finished.
    pub fn join_cluster(&self, member: &str) -> Result<ClusterMetaWire> {
        match self.rpc(Request::JoinCluster { member: member.into() })? {
            Response::Cluster(meta) => Ok(meta),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Push an epoch-bumped spec to a peer (membership gossip). Returns
    /// whatever spec the peer holds afterwards — newer news than ours
    /// comes back on the same round trip. Single attempt: gossip is
    /// best-effort by design.
    pub fn spec_sync(&self, meta: ClusterMetaWire) -> Result<ClusterMetaWire> {
        match self.rpc_once(Request::SpecSync { meta })? {
            Response::Cluster(meta) => Ok(meta),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Migration catch-up read: `(source hw, source epoch, records)` of
    /// `(topic, partition)` from offset `from`. Single attempt — the
    /// migration state machine owns retry policy.
    pub(crate) fn fetch_log(
        &self,
        topic: &str,
        partition: usize,
        from: u64,
        max: usize,
    ) -> Result<(u64, u64, Vec<Record>)> {
        let req = Request::FetchLog { topic: topic.into(), partition, from, max };
        match self.rpc_once(req)? {
            Response::LogChunk { hw, epoch, recs } => Ok((hw, epoch, recs)),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Migration offset-journal read: every consumer group's cursors for
    /// `topic` on this broker (single attempt).
    pub(crate) fn fetch_offsets(&self, topic: &str) -> Result<Vec<OffsetEntry>> {
        match self.rpc_once(Request::FetchOffsets { topic: topic.into() })? {
            Response::OffsetDump(entries) => Ok(entries),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Fence `(topic, partition)` on this broker: it stops accepting
    /// writes and redirects producers to `by`. Returns the fence epoch
    /// (single attempt — a fence that cannot be delivered must surface,
    /// not silently retry into a double handoff).
    pub(crate) fn fence(
        &self,
        topic: &str,
        partitions: usize,
        partition: usize,
        by: &str,
    ) -> Result<u64> {
        let req =
            Request::Fence { topic: topic.into(), partitions, partition, by: by.into() };
        match self.rpc_once(req)? {
            Response::Epoch(e) => Ok(e),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Tell this broker to pull `(topic, partition)` from `from` and take
    /// ownership (the drain path's per-partition handoff). Blocks until
    /// the transfer promoted; returns the new owner's fencing epoch.
    pub(crate) fn migrate_partition(
        &self,
        topic: &str,
        partitions: usize,
        partition: usize,
        from: &str,
    ) -> Result<u64> {
        let req = Request::MigratePartition {
            topic: topic.into(),
            partitions,
            partition,
            from: from.into(),
        };
        match self.rpc_once(req)? {
            Response::Epoch(e) => Ok(e),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Decommission a cluster member: it hands every owned partition to
    /// the next rendezvous owner and gossips the spec without itself.
    /// Empty `member` means "drain yourself". Returns the number of
    /// partitions moved. Single attempt on purpose: retrying a drain that
    /// timed out mid-handoff could race its own first run.
    pub fn drain_member(&self, member: &str) -> Result<usize> {
        match self.rpc_once(Request::DrainMember { member: member.into() })? {
            Response::Count(moved) => Ok(moved),
            Response::Err { code, msg } => Err(error_from_code(code, msg)),
            other => Err(BrokerError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    // ---- pipelined publishing (PR 5) ------------------------------------

    /// A bounded-window pipelined publisher over this client: up to
    /// `window` publish frames stay in flight on the mux at once, so
    /// remote throughput is no longer capped at `1/RTT`. Acks resolve
    /// asynchronously; errors surface in submission order. Call
    /// [`PublishPipeline::flush`] before dropping it — unflushed acks are
    /// abandoned. Embedded transports complete each publish inline.
    ///
    /// Unlike the plain [`BrokerClient::publish_batch`], a pipelined
    /// publish whose connection breaks is **not** retried (re-submitting a
    /// window could reorder records); [`PublishPipeline::acked`] reports
    /// progress so callers can resume.
    pub fn pipeline(&self, window: usize) -> PublishPipeline<'_> {
        PublishPipeline { client: self, window: window.max(1), inflight: VecDeque::new(), acked: 0 }
    }

    /// Submit a partition-targeted publish without waiting for its ack
    /// (remote: one in-flight mux frame; embedded: completes inline) —
    /// the primitive under [`super::cluster::ClusterClient`]'s pipelined
    /// per-owner batch shipping.
    pub fn publish_to_submit(
        &self,
        topic: &str,
        partition: usize,
        recs: Vec<ProducerRecord>,
        acks: u8,
    ) -> PendingPublish {
        let _root = trace::span_root("client.publish");
        let inner = match &self.transport {
            Transport::Embedded(core) => {
                PendingKind::Ready(core.publish_to(topic, partition, recs))
            }
            Transport::Remote(_) => {
                let req = Request::PublishTo { topic: topic.into(), partition, recs, acks };
                match self.conn() {
                    Ok(conn) => match conn.submit(&req) {
                        Ok(reply) => PendingKind::Wire(reply),
                        Err(e) => {
                            self.invalidate(&conn);
                            PendingKind::Ready(Err(BrokerError::Transport(format!("submit: {e}"))))
                        }
                    },
                    Err(e) => PendingKind::Ready(Err(e)),
                }
            }
        };
        PendingPublish { inner }
    }
}

/// An in-flight partition-targeted publish (see
/// [`BrokerClient::publish_to_submit`]).
pub struct PendingPublish {
    inner: PendingKind,
}

enum PendingKind {
    /// Completed inline (embedded transport, or a submit-time failure).
    Ready(Result<Vec<u64>>),
    /// Outstanding mux frame; resolved by correlation id.
    Wire(PendingReply),
}

impl PendingPublish {
    /// Block until the ack arrives; returns the assigned offsets in order.
    pub fn wait(self) -> Result<Vec<u64>> {
        match self.inner {
            PendingKind::Ready(res) => res,
            PendingKind::Wire(reply) => match reply.wait_msg::<Response>() {
                Ok(Response::PubBatchAck { acks }) => {
                    Ok(acks.into_iter().map(|(_, o)| o).collect())
                }
                Ok(Response::Err { code, msg }) => Err(error_from_code(code, msg)),
                Ok(other) => {
                    Err(BrokerError::Transport(format!("unexpected response {other:?}")))
                }
                Err(e) => Err(BrokerError::Transport(format!("ack: {e}"))),
            },
        }
    }
}

/// Bounded-window pipelined publisher (see [`BrokerClient::pipeline`]).
pub struct PublishPipeline<'a> {
    client: &'a BrokerClient,
    window: usize,
    inflight: VecDeque<PendingAck>,
    acked: u64,
}

enum PendingAck {
    Ready(Result<Vec<(usize, u64)>>),
    Wire(PendingReply),
}

impl PublishPipeline<'_> {
    /// Publish one record through the window.
    pub fn publish(&mut self, topic: &str, rec: ProducerRecord) -> Result<()> {
        self.publish_batch(topic, vec![rec])
    }

    /// Publish a batch through the window: blocks only while the window is
    /// full (waiting the **oldest** outstanding ack, so errors surface in
    /// submission order), then ships the frame without waiting for its own
    /// ack.
    pub fn publish_batch(&mut self, topic: &str, recs: Vec<ProducerRecord>) -> Result<()> {
        while self.inflight.len() >= self.window {
            self.complete_oldest()?;
        }
        match &self.client.transport {
            Transport::Embedded(core) => {
                let res = core.publish_batch(topic, recs);
                self.inflight.push_back(PendingAck::Ready(res));
            }
            Transport::Remote(_) => {
                let conn = self.client.conn()?;
                let req = Request::PublishBatch { topic: topic.into(), recs };
                match conn.submit(&req) {
                    Ok(reply) => self.inflight.push_back(PendingAck::Wire(reply)),
                    Err(e) => {
                        self.client.invalidate(&conn);
                        return Err(BrokerError::Transport(format!("submit: {e}")));
                    }
                }
            }
        }
        Ok(())
    }

    fn complete_oldest(&mut self) -> Result<()> {
        let Some(pending) = self.inflight.pop_front() else {
            return Ok(());
        };
        let acks = match pending {
            PendingAck::Ready(res) => res?,
            PendingAck::Wire(reply) => match reply.wait_msg::<Response>() {
                Ok(Response::PubBatchAck { acks }) => acks,
                Ok(Response::Err { code, msg }) => return Err(error_from_code(code, msg)),
                Ok(other) => {
                    return Err(BrokerError::Transport(format!("unexpected response {other:?}")))
                }
                Err(e) => return Err(BrokerError::Transport(format!("ack: {e}"))),
            },
        };
        self.acked += acks.len() as u64;
        Ok(())
    }

    /// Wait out every outstanding ack (first error, in submission order,
    /// wins) and return the total records acked through this pipeline.
    pub fn flush(&mut self) -> Result<u64> {
        while !self.inflight.is_empty() {
            self.complete_oldest()?;
        }
        Ok(self.acked)
    }

    /// Records acked so far (grows as the window turns over).
    pub fn acked(&self) -> u64 {
        self.acked
    }
}

impl super::StreamBroker for BrokerClient {
    fn ping(&self) -> Result<()> {
        BrokerClient::ping(self)
    }
    fn create_topic(&self, name: &str, partitions: usize) -> Result<()> {
        BrokerClient::create_topic(self, name, partitions)
    }
    fn ensure_topic(&self, name: &str, partitions: usize) -> Result<()> {
        BrokerClient::ensure_topic(self, name, partitions)
    }
    fn delete_topic(&self, name: &str) -> Result<()> {
        BrokerClient::delete_topic(self, name)
    }
    fn topic_names(&self) -> Result<Vec<String>> {
        BrokerClient::topic_names(self)
    }
    fn topic_stats(&self, name: &str) -> Result<TopicStats> {
        BrokerClient::topic_stats(self, name)
    }
    fn publish(&self, topic: &str, rec: ProducerRecord) -> Result<(usize, u64)> {
        BrokerClient::publish(self, topic, rec)
    }
    fn publish_batch(&self, topic: &str, recs: Vec<ProducerRecord>) -> Result<Vec<(usize, u64)>> {
        BrokerClient::publish_batch(self, topic, recs)
    }
    fn join_group(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        mode: AssignmentMode,
    ) -> Result<u64> {
        BrokerClient::join_group(self, group, topic, member, mode)
    }
    fn leave_group(&self, group: &str, topic: &str, member: &str) -> Result<bool> {
        BrokerClient::leave_group(self, group, topic, member)
    }
    fn poll(&self, group: &str, topic: &str, member: &str, max: usize) -> Result<Vec<Arc<Record>>> {
        BrokerClient::poll(self, group, topic, member, max)
    }
    fn fetch_many_wait(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
        wait_ms: u64,
    ) -> Result<MultiFetch> {
        BrokerClient::fetch_many_wait(self, group, topic, member, max, max_bytes, wait_ms)
    }
    fn commit(&self, group: &str, topic: &str, commits: &[(usize, u64)]) -> Result<()> {
        BrokerClient::commit(self, group, topic, commits)
    }
    fn delete_records(&self, topic: &str, partition: usize, up_to: u64) -> Result<usize> {
        BrokerClient::delete_records(self, topic, partition, up_to)
    }
    fn offsets(&self, topic: &str) -> Result<Vec<(u64, u64)>> {
        BrokerClient::offsets(self, topic)
    }
    fn positions(&self, group: &str, topic: &str) -> Result<Vec<(u64, u64)>> {
        BrokerClient::positions(self, group, topic)
    }
    fn crash_member(&self, group: &str, topic: &str, member: &str) -> Result<()> {
        BrokerClient::crash_member(self, group, topic, member)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::protocol::ACKS_LEADER;
    use crate::broker::server::BrokerServer;

    fn exercise(client: &BrokerClient) {
        client.create_topic("t", 2).unwrap();
        assert!(client.create_topic("t", 2).is_err());
        client.publish("t", ProducerRecord::new(vec![1])).unwrap();
        client
            .publish_batch("t", vec![ProducerRecord::new(vec![2]), ProducerRecord::new(vec![3])])
            .unwrap();
        client.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let recs = client.poll("g", "t", "m", usize::MAX).unwrap();
        assert_eq!(recs.len(), 3);
        // Batched drain: publish another batch, take it in one fetch_many.
        client
            .publish_batch("t", vec![ProducerRecord::new(vec![4]), ProducerRecord::new(vec![5])])
            .unwrap();
        let mf = client.fetch_many("g", "t", "m", usize::MAX, usize::MAX).unwrap();
        assert_eq!(mf.record_count(), 2);
        assert_eq!(mf.positions.len(), 2);
        client.commit("g", "t", &[(0, 2)]).unwrap();
        let stats = client.topic_stats("t").unwrap();
        assert_eq!(stats.partitions, 2);
        assert_eq!(stats.records, 5);
        for (p, (_s, hw)) in client.offsets("t").unwrap().into_iter().enumerate() {
            client.delete_records("t", p, hw).unwrap();
        }
        assert_eq!(client.topic_stats("t").unwrap().records, 0);
        assert!(client.leave_group("g", "t", "m").unwrap());
        client.delete_topic("t").unwrap();
    }

    #[test]
    fn embedded_end_to_end() {
        let client = BrokerClient::embedded(BrokerCore::new());
        exercise(&client);
    }

    #[test]
    fn remote_end_to_end() {
        let server = BrokerServer::start(BrokerCore::new(), "127.0.0.1:0").unwrap();
        let client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.ping().unwrap();
        exercise(&client);
        server.shutdown();
    }

    #[test]
    fn remote_fetch_many_respects_budgets() {
        let server = BrokerServer::start(BrokerCore::new(), "127.0.0.1:0").unwrap();
        let client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.create_topic("t", 2).unwrap();
        for _ in 0..8 {
            client.publish("t", ProducerRecord::new(vec![0; 10])).unwrap();
        }
        client.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let mf = client.fetch_many("g", "t", "m", usize::MAX, 45).unwrap();
        assert_eq!(mf.record_count(), 4, "45-byte budget → 4 × 10-byte records");
        let rest = client.fetch_many("g", "t", "m", usize::MAX, usize::MAX).unwrap();
        assert_eq!(rest.record_count(), 4, "budget cut must not lose records");
        server.shutdown();
    }

    #[test]
    fn remote_fetch_many_wait_parks_and_wakes() {
        use std::time::{Duration, Instant};
        let server = BrokerServer::start(BrokerCore::new(), "127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let producer = BrokerClient::connect(&addr).unwrap();
        producer.create_topic("t", 1).unwrap();
        let consumer = BrokerClient::connect(&addr).unwrap();
        consumer.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        // Expiry on an empty topic: no data, no error, full wait.
        let t0 = Instant::now();
        let mf = consumer.fetch_many_wait("g", "t", "m", usize::MAX, usize::MAX, 40).unwrap();
        assert_eq!(mf.record_count(), 0);
        assert!(t0.elapsed() >= Duration::from_millis(40));
        // Wakeup: a publish from the other client releases the parked wait.
        let waiter = std::thread::spawn(move || {
            let t0 = Instant::now();
            let mf = consumer
                .fetch_many_wait("g", "t", "m", usize::MAX, usize::MAX, 10_000)
                .unwrap();
            (mf.record_count(), t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(30));
        producer.publish("t", ProducerRecord::new(vec![5])).unwrap();
        let (count, waited) = waiter.join().unwrap();
        assert_eq!(count, 1);
        assert!(waited < Duration::from_secs(5), "server must wake the parked fetch");
        server.shutdown();
    }

    #[test]
    fn pipelined_publish_window_flushes_every_ack() {
        let server = BrokerServer::start(BrokerCore::new(), "127.0.0.1:0").unwrap();
        let client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.create_topic("t", 4).unwrap();
        let mut pipe = client.pipeline(8);
        for i in 0..100u8 {
            pipe.publish("t", ProducerRecord::new(vec![i])).unwrap();
        }
        assert_eq!(pipe.flush().unwrap(), 100, "every submitted record must be acked");
        assert_eq!(client.topic_stats("t").unwrap().records, 100);
        // Submission-order errors: publishing to a missing topic surfaces
        // the broker error through the pipeline, not a hang.
        let mut bad = client.pipeline(4);
        bad.publish("nope", ProducerRecord::new(vec![1])).unwrap();
        assert!(matches!(bad.flush(), Err(BrokerError::UnknownTopic(_))));
        server.shutdown();
    }

    #[test]
    fn publish_to_submit_resolves_out_of_band() {
        let server = BrokerServer::start(BrokerCore::new(), "127.0.0.1:0").unwrap();
        let client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.create_topic("t", 2).unwrap();
        // Two partition-targeted publishes in flight at once; both ack.
        let a = client.publish_to_submit("t", 0, vec![ProducerRecord::new(vec![1])], ACKS_LEADER);
        let b = client.publish_to_submit("t", 1, vec![ProducerRecord::new(vec![2])], ACKS_LEADER);
        assert_eq!(b.wait().unwrap(), vec![0]);
        assert_eq!(a.wait().unwrap(), vec![0]);
        server.shutdown();
    }

    #[test]
    fn parked_long_poll_does_not_block_the_mux() {
        use std::time::{Duration, Instant};
        let server = BrokerServer::start(BrokerCore::new(), "127.0.0.1:0").unwrap();
        let client = Arc::new(BrokerClient::connect(&server.addr.to_string()).unwrap());
        client.create_topic("t", 1).unwrap();
        client.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        // Park a long fetch on the shared connection...
        let consumer = Arc::clone(&client);
        let waiter = std::thread::spawn(move || {
            consumer.fetch_many_wait("g", "t", "m", usize::MAX, usize::MAX, 10_000)
        });
        std::thread::sleep(Duration::from_millis(50));
        // ...and prove later requests on the SAME client still flow (the
        // lock-step transport would queue them behind the park).
        let t0 = Instant::now();
        client.ping().unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "ping must not wait for the parked fetch"
        );
        client.publish("t", ProducerRecord::new(vec![9])).unwrap();
        let mf = waiter.join().unwrap().unwrap();
        assert_eq!(mf.record_count(), 1, "the publish must wake the parked fetch");
        server.shutdown();
    }

    #[test]
    fn two_remote_clients_share_state() {
        let server = BrokerServer::start(BrokerCore::new(), "127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let producer = BrokerClient::connect(&addr).unwrap();
        let consumer = BrokerClient::connect(&addr).unwrap();
        producer.create_topic("t", 1).unwrap();
        producer.publish("t", ProducerRecord::new(vec![42])).unwrap();
        consumer.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let recs = consumer.poll("g", "t", "m", usize::MAX).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].value.as_slice(), &[42]);
        server.shutdown();
    }
}
