//! Consumer groups: shared consumption of a topic's records.
//!
//! Kafka ensures each record published to a topic is delivered to at least
//! one member of every subscribing group (§3.2). Two disciplines:
//!
//! - [`AssignmentMode::Shared`]: one cursor per (group, partition); a poll
//!   atomically claims everything available past the cursor (optionally
//!   capped). This matches the behaviour the paper measures — "elements are
//!   assigned to the first process that requests them" (§6.4) — and
//!   reproduces the Fig 20 imbalance. A finite `max_poll_records` is the
//!   paper's proposed balanced-poll policy (future work).
//! - [`AssignmentMode::Partitioned`]: classic Kafka — partitions are
//!   range-assigned to members; a rebalance redistributes on join/leave.

use std::collections::BTreeMap;

/// How a group's members share partitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssignmentMode {
    /// Greedy shared cursors (paper behaviour).
    Shared,
    /// Kafka-style partition-per-member assignment.
    Partitioned,
}

/// Per-(topic, partition) consumption cursor.
#[derive(Debug, Default, Clone)]
pub struct Cursor {
    /// Next offset this group will claim.
    pub position: u64,
    /// Highest offset + 1 acknowledged as *processed* (commit point).
    pub committed: u64,
}

/// Consumer-group state for one topic.
#[derive(Debug)]
pub struct GroupState {
    pub mode: AssignmentMode,
    /// Sorted member ids (deterministic assignment).
    members: Vec<String>,
    /// partition -> cursor.
    cursors: BTreeMap<usize, Cursor>,
    /// Bumped on every membership change (detects stale members).
    pub generation: u64,
}

impl GroupState {
    pub fn new(mode: AssignmentMode) -> Self {
        Self { mode, members: Vec::new(), cursors: BTreeMap::new(), generation: 0 }
    }

    pub fn members(&self) -> &[String] {
        &self.members
    }

    /// Add a member (idempotent); returns the new generation.
    pub fn join(&mut self, member: &str) -> u64 {
        if !self.members.iter().any(|m| m == member) {
            self.members.push(member.to_string());
            self.members.sort();
            self.generation += 1;
        }
        self.generation
    }

    /// Remove a member; returns true if it was present.
    pub fn leave(&mut self, member: &str) -> bool {
        let before = self.members.len();
        self.members.retain(|m| m != member);
        if self.members.len() != before {
            self.generation += 1;
            true
        } else {
            false
        }
    }

    /// Partitions assigned to `member` under `Partitioned` mode
    /// (range assignment over sorted members). Under `Shared` mode every
    /// member may claim from every partition.
    pub fn assignment(&self, member: &str, partitions: usize) -> Vec<usize> {
        match self.mode {
            AssignmentMode::Shared => (0..partitions).collect(),
            AssignmentMode::Partitioned => {
                let Some(rank) = self.members.iter().position(|m| m == member) else {
                    return Vec::new();
                };
                (0..partitions).filter(|p| p % self.members.len().max(1) == rank).collect()
            }
        }
    }

    /// Cursor for a partition (created on first touch).
    pub fn cursor_mut(&mut self, partition: usize) -> &mut Cursor {
        self.cursors.entry(partition).or_default()
    }

    pub fn cursor(&self, partition: usize) -> Cursor {
        self.cursors.get(&partition).cloned().unwrap_or_default()
    }

    /// Claim up to `max` records past the cursor given the partition's
    /// `high_watermark` and `start_offset`; advances the position and
    /// returns the claimed half-open range `[from, to)`.
    pub fn claim(
        &mut self,
        partition: usize,
        start_offset: u64,
        high_watermark: u64,
        max: usize,
    ) -> (u64, u64) {
        let cur = self.cursors.entry(partition).or_default();
        // Never re-claim deleted records.
        let from = cur.position.max(start_offset);
        let available = high_watermark.saturating_sub(from);
        let take = available.min(max as u64);
        let to = from + take;
        cur.position = to;
        (from, to)
    }

    /// Mark records below `up_to` as processed.
    pub fn commit(&mut self, partition: usize, up_to: u64) {
        let cur = self.cursors.entry(partition).or_default();
        cur.committed = cur.committed.max(up_to);
    }

    /// Rewind the claim position to the commit point (redelivery after a
    /// member crash — at-least-once).
    pub fn rewind_to_committed(&mut self, partition: usize) {
        let cur = self.cursors.entry(partition).or_default();
        cur.position = cur.committed;
    }

    /// Smallest committed offset across partitions (safe deletion bound
    /// helpers for admins).
    pub fn committed(&self, partition: usize) -> u64 {
        self.cursors.get(&partition).map(|c| c.committed).unwrap_or(0)
    }

    /// Current claim position of a partition (next offset to be claimed).
    pub fn position(&self, partition: usize) -> u64 {
        self.cursors.get(&partition).map(|c| c.position).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_is_idempotent_and_sorted() {
        let mut g = GroupState::new(AssignmentMode::Partitioned);
        g.join("b");
        g.join("a");
        g.join("b");
        assert_eq!(g.members(), &["a".to_string(), "b".to_string()]);
        assert_eq!(g.generation, 2);
    }

    #[test]
    fn partitioned_assignment_covers_all_disjointly() {
        let mut g = GroupState::new(AssignmentMode::Partitioned);
        for m in ["m1", "m2", "m3"] {
            g.join(m);
        }
        let parts = 8;
        let mut seen = vec![0u32; parts];
        for m in ["m1", "m2", "m3"] {
            for p in g.assignment(m, parts) {
                seen[p] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "partitions not covered exactly once: {seen:?}");
    }

    #[test]
    fn rebalance_on_leave() {
        let mut g = GroupState::new(AssignmentMode::Partitioned);
        g.join("m1");
        g.join("m2");
        let before = g.assignment("m1", 4);
        assert_eq!(before.len(), 2);
        g.leave("m2");
        assert_eq!(g.assignment("m1", 4).len(), 4);
        assert!(g.assignment("m2", 4).is_empty());
    }

    #[test]
    fn shared_claim_is_greedy_and_non_overlapping() {
        let mut g = GroupState::new(AssignmentMode::Shared);
        g.join("r1");
        g.join("r2");
        // 10 records available in partition 0.
        let (a0, a1) = g.claim(0, 0, 10, usize::MAX);
        assert_eq!((a0, a1), (0, 10)); // first poller takes everything
        let (b0, b1) = g.claim(0, 0, 10, usize::MAX);
        assert_eq!((b0, b1), (10, 10)); // second gets nothing
    }

    #[test]
    fn capped_claim_limits_take() {
        let mut g = GroupState::new(AssignmentMode::Shared);
        let (f, t) = g.claim(0, 0, 100, 10);
        assert_eq!((f, t), (0, 10));
        let (f2, t2) = g.claim(0, 0, 100, 10);
        assert_eq!((f2, t2), (10, 20));
    }

    #[test]
    fn claim_skips_deleted_prefix() {
        let mut g = GroupState::new(AssignmentMode::Shared);
        // Records below offset 5 were deleted.
        let (f, t) = g.claim(0, 5, 8, usize::MAX);
        assert_eq!((f, t), (5, 8));
    }

    #[test]
    fn commit_and_rewind_for_redelivery() {
        let mut g = GroupState::new(AssignmentMode::Shared);
        g.claim(0, 0, 10, usize::MAX);
        g.commit(0, 4);
        g.rewind_to_committed(0);
        let (f, t) = g.claim(0, 0, 10, usize::MAX);
        assert_eq!((f, t), (4, 10)); // offsets 4..10 redelivered
    }
}
