//! Records: the key-value payloads stored in partition logs.

use crate::util::wire::Blob;
use crate::wire_struct;

/// A record as stored in (and fetched from) a partition log.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Record {
    /// Dense per-partition sequence number, assigned at append time.
    pub offset: u64,
    /// Publication time (ms since epoch), assigned at append time.
    pub timestamp_ms: u64,
    /// Optional partitioning key.
    pub key: Option<Blob>,
    /// Application payload.
    pub value: Blob,
}

wire_struct!(Record {
    offset: u64,
    timestamp_ms: u64,
    key: Option<Blob>,
    value: Blob,
});

impl Record {
    /// Total payload footprint in bytes (for metrics/backpressure).
    pub fn payload_len(&self) -> usize {
        self.value.0.len() + self.key.as_ref().map_or(0, |k| k.0.len())
    }
}

/// A record as submitted by a producer (no offset/timestamp yet).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProducerRecord {
    pub key: Option<Blob>,
    pub value: Blob,
}

wire_struct!(ProducerRecord { key: Option<Blob>, value: Blob });

impl ProducerRecord {
    /// Wrap a payload without copying it (`Blob` is `Arc`-backed, so the
    /// producer's buffer is the same allocation every consumer reads).
    pub fn new(value: impl Into<Blob>) -> Self {
        Self { key: None, value: value.into() }
    }

    pub fn with_key(key: impl Into<Blob>, value: impl Into<Blob>) -> Self {
        Self { key: Some(key.into()), value: value.into() }
    }

    /// Total payload footprint in bytes (key + value) — the same unit the
    /// stored [`Record::payload_len`] and the broker byte budgets use.
    pub fn payload_len(&self) -> usize {
        self.value.0.len() + self.key.as_ref().map_or(0, |k| k.0.len())
    }
}

/// Wall-clock ms since the UNIX epoch (record timestamps).
pub fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::wire::Wire;

    #[test]
    fn record_roundtrip() {
        let r = Record {
            offset: 9,
            timestamp_ms: 123,
            key: Some(Blob::new(vec![1])),
            value: Blob::new(vec![2, 3]),
        };
        assert_eq!(Record::decode_exact(&r.encode_vec()).unwrap(), r);
    }

    #[test]
    fn payload_len_counts_key_and_value() {
        let r = Record {
            offset: 0,
            timestamp_ms: 0,
            key: Some(Blob::new(vec![0; 3])),
            value: Blob::new(vec![0; 5]),
        };
        assert_eq!(r.payload_len(), 8);
        let r2 = Record { key: None, ..r };
        assert_eq!(r2.payload_len(), 5);
    }

    #[test]
    fn producer_record_constructors() {
        assert!(ProducerRecord::new(vec![1]).key.is_none());
        assert!(ProducerRecord::with_key(vec![0], vec![1]).key.is_some());
    }
}
