//! Wire protocol between [`super::client::BrokerClient`] and the TCP server.
//!
//! One request frame → one response frame. Tag bytes keep the codec
//! hand-rolled but explicit; unknown tags surface as `DecodeError::BadTag`.
//!
//! Since PR 5 these encodings normally travel inside **mux frames**
//! (`[len][corr][body]`, see [`crate::util::mux`]): one connection carries
//! many in-flight request/response pairs, matched by correlation id, and
//! responses may return out of submission order (parked long-polls). The
//! bare one-shot framing survives as the legacy lock-step mode, still
//! served for old peers and raw-socket tools.

use crate::util::bytes::{ByteReader, ByteWriter, DecodeError};
use crate::util::obs;
use crate::util::trace;
use crate::util::wire::Wire;

use super::embedded::{BrokerError, TopicStats};
use super::group::AssignmentMode;
use super::record::{ProducerRecord, Record};
use super::storage::OffsetEntry;

/// `acks` level of a [`Request::PublishTo`]: the broker acks after its own
/// append (leader) — the pre-PR 7 behaviour — or only once every in-sync
/// follower has applied the batch (quorum).
pub const ACKS_LEADER: u8 = 0;
pub const ACKS_QUORUM: u8 = 1;

impl Wire for AssignmentMode {
    fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            AssignmentMode::Shared => 0,
            AssignmentMode::Partitioned => 1,
        });
    }
    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        let at = r.position();
        match r.get_u8()? {
            0 => Ok(AssignmentMode::Shared),
            1 => Ok(AssignmentMode::Partitioned),
            tag => Err(DecodeError::BadTag { at, tag: tag as u32, ty: "AssignmentMode" }),
        }
    }
}

/// Client → broker.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    CreateTopic { name: String, partitions: usize },
    EnsureTopic { name: String, partitions: usize },
    DeleteTopic { name: String },
    TopicNames,
    TopicStats { name: String },
    Publish { topic: String, rec: ProducerRecord },
    PublishBatch { topic: String, recs: Vec<ProducerRecord> },
    JoinGroup { group: String, topic: String, member: String, mode: AssignmentMode },
    LeaveGroup { group: String, topic: String, member: String },
    Poll { group: String, topic: String, member: String, max: usize },
    /// One-frame multi-partition drain with record + byte budgets
    /// (the batched data plane; replies with [`Response::Batches`]).
    /// `wait_ms > 0` long-polls: the server parks the connection until
    /// data arrives or the deadline passes (clamped server-side to
    /// [`super::server::MAX_SERVER_WAIT_MS`]) instead of the client
    /// spinning empty fetches.
    FetchMany {
        group: String,
        topic: String,
        member: String,
        max: usize,
        max_bytes: usize,
        wait_ms: u64,
    },
    Commit { group: String, topic: String, commits: Vec<(usize, u64)> },
    DeleteRecords { topic: String, partition: usize, up_to: u64 },
    Offsets { topic: String },
    Positions { group: String, topic: String },
    CrashMember { group: String, topic: String, member: String },
    Shutdown,
    /// Partition-targeted batch publish (the cluster data plane): the
    /// client computed the partition from the shared placement function; a
    /// broker that does not lead it answers `NotOwner { owner_addr }` (wire
    /// code 8) so stale clients self-correct. `acks` picks the durability
    /// level ([`ACKS_LEADER`] or [`ACKS_QUORUM`]). Replies with
    /// [`Response::PubBatchAck`].
    PublishTo { topic: String, partition: usize, recs: Vec<ProducerRecord>, acks: u8 },
    /// Cluster membership snapshot; replies with [`Response::Cluster`]
    /// (empty member list when the broker is not part of a cluster).
    ClusterMeta,
    /// Leader → follower log shipping (PR 7): apply `recs` — whose bodies
    /// are byte-identical to the CRC-framed disk format — to the replica
    /// of `(topic, partition)` starting at offset `base`. `epoch` fences
    /// stale leaders: a follower that has adopted a higher fencing epoch
    /// answers `Err` code 9 (`Fenced`) instead of applying. Replies with
    /// [`Response::RepAck`] carrying the follower's high watermark (a
    /// watermark below `base` asks the leader to back-fill).
    Replicate {
        topic: String,
        partitions: usize,
        partition: usize,
        epoch: u64,
        base: u64,
        recs: Vec<Record>,
    },
    /// Leader → follower consumer-offset shipping: the commit journal
    /// entries ride alongside the segment stream so a promoted follower
    /// resumes every group from its committed offsets. Replies `Ok`.
    OffsetSync { topic: String, entries: Vec<OffsetEntry> },
    /// Client → follower promotion request after a leader death: the
    /// follower bumps the partition's fencing epoch past anything the dead
    /// leader could have issued and starts accepting writes. Replies with
    /// [`Response::Epoch`] (the new fencing epoch).
    Promote { topic: String, partitions: usize, partition: usize },
    /// Scrape this broker's full observability snapshot (PR 8): every
    /// counter/gauge/histogram the process has registered — broker,
    /// storage, mux, replication, scheduler, fault planes. Replies with
    /// [`Response::Metrics`].
    Metrics,
    /// Scrape this broker's span flight recorder (PR 9): every finished
    /// span still in the ring, optionally filtered to one trace
    /// (`trace_id == 0` exports everything). Replies with
    /// [`Response::Spans`].
    Spans { trace_id: u64 },
    /// Membership plane (PR 10), joiner → seed: `member` wants in. The
    /// seed derives the epoch-bumped spec with the newcomer and replies
    /// with [`Response::Cluster`] carrying it — the joiner then pulls its
    /// rendezvous share of partitions from their current owners *before*
    /// installing the new spec anywhere (see `cluster::migrate`).
    JoinCluster { member: String },
    /// Membership gossip: push an epoch-bumped spec to a peer. The peer
    /// adopts it iff the epoch is higher than its own and always replies
    /// with [`Response::Cluster`] carrying whatever spec it now holds, so
    /// a push to a peer that already heard newer news returns the newer
    /// spec to the pusher.
    SpecSync { meta: ClusterMetaWire },
    /// Migration catch-up read (new owner → old owner): records of
    /// `(topic, partition)` from offset `from`, at most `max`. Replies
    /// with [`Response::LogChunk`] carrying the partition's high
    /// watermark + fencing epoch alongside the records, so one frame
    /// tells the puller both what it got and how far behind it still is.
    FetchLog { topic: String, partition: usize, from: u64, max: usize },
    /// Migration offset-journal read (new owner → old owner): every
    /// consumer group's `(position, committed)` cursors for `topic`.
    /// Replies with [`Response::OffsetDump`].
    FetchOffsets { topic: String },
    /// Migration fence (new owner → old owner): stop accepting writes for
    /// `(topic, partition)` and answer `NotOwner { by }` from now on. The
    /// old owner bumps its fencing epoch past everything it ever issued
    /// and records the deposal, freezing the log so the final catch-up
    /// read is exact. Replies with [`Response::Epoch`] (the fence epoch).
    Fence { topic: String, partitions: usize, partition: usize, by: String },
    /// Drain-driven handoff (draining broker → new owner): "pull
    /// `(topic, partition)` from `from`, fence it, and take ownership".
    /// The receiver runs the same pull/fence/adopt state machine a joiner
    /// runs for its own share. Replies with [`Response::Epoch`] (the
    /// receiver's post-adoption fencing epoch).
    MigratePartition { topic: String, partitions: usize, partition: usize, from: String },
    /// Decommission request (CLI → draining broker): hand every owned
    /// partition to its next rendezvous owner, gossip the epoch-bumped
    /// spec without this member, and reply [`Response::Count`] with the
    /// number of partitions moved. An empty `member` means "drain
    /// yourself" — the receiver substitutes its own advertised address.
    DrainMember { member: String },
}

impl Request {
    /// Server-side park horizon of this request in ms: `> 0` marks a
    /// long-poll, which a mux server must dispatch off its reader thread
    /// so the requests pipelined behind it are not blocked while it parks
    /// (its response then completes out of order, routed by id).
    pub fn park_wait_ms(&self) -> u64 {
        match self {
            Request::FetchMany { wait_ms, .. } => *wait_ms,
            _ => 0,
        }
    }
}

impl Wire for Request {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            Request::Ping => w.put_u8(0),
            Request::CreateTopic { name, partitions } => {
                w.put_u8(1);
                name.encode(w);
                partitions.encode(w);
            }
            Request::EnsureTopic { name, partitions } => {
                w.put_u8(2);
                name.encode(w);
                partitions.encode(w);
            }
            Request::DeleteTopic { name } => {
                w.put_u8(3);
                name.encode(w);
            }
            Request::TopicNames => w.put_u8(4),
            Request::TopicStats { name } => {
                w.put_u8(5);
                name.encode(w);
            }
            Request::Publish { topic, rec } => {
                w.put_u8(6);
                topic.encode(w);
                rec.encode(w);
            }
            Request::PublishBatch { topic, recs } => {
                w.put_u8(7);
                topic.encode(w);
                recs.encode(w);
            }
            Request::JoinGroup { group, topic, member, mode } => {
                w.put_u8(8);
                group.encode(w);
                topic.encode(w);
                member.encode(w);
                mode.encode(w);
            }
            Request::LeaveGroup { group, topic, member } => {
                w.put_u8(9);
                group.encode(w);
                topic.encode(w);
                member.encode(w);
            }
            Request::Poll { group, topic, member, max } => {
                w.put_u8(10);
                group.encode(w);
                topic.encode(w);
                member.encode(w);
                max.encode(w);
            }
            Request::Commit { group, topic, commits } => {
                w.put_u8(11);
                group.encode(w);
                topic.encode(w);
                commits.encode(w);
            }
            Request::DeleteRecords { topic, partition, up_to } => {
                w.put_u8(12);
                topic.encode(w);
                partition.encode(w);
                up_to.encode(w);
            }
            Request::Offsets { topic } => {
                w.put_u8(13);
                topic.encode(w);
            }
            Request::Positions { group, topic } => {
                w.put_u8(16);
                group.encode(w);
                topic.encode(w);
            }
            Request::CrashMember { group, topic, member } => {
                w.put_u8(14);
                group.encode(w);
                topic.encode(w);
                member.encode(w);
            }
            Request::Shutdown => w.put_u8(15),
            Request::FetchMany { group, topic, member, max, max_bytes, wait_ms } => {
                w.put_u8(17);
                group.encode(w);
                topic.encode(w);
                member.encode(w);
                max.encode(w);
                max_bytes.encode(w);
                wait_ms.encode(w);
            }
            Request::PublishTo { topic, partition, recs, acks } => {
                w.put_u8(18);
                topic.encode(w);
                partition.encode(w);
                recs.encode(w);
                w.put_u8(*acks);
            }
            Request::ClusterMeta => w.put_u8(19),
            Request::Replicate { topic, partitions, partition, epoch, base, recs } => {
                w.put_u8(20);
                topic.encode(w);
                partitions.encode(w);
                partition.encode(w);
                epoch.encode(w);
                base.encode(w);
                recs.encode(w);
            }
            Request::OffsetSync { topic, entries } => {
                w.put_u8(21);
                topic.encode(w);
                entries.encode(w);
            }
            Request::Promote { topic, partitions, partition } => {
                w.put_u8(22);
                topic.encode(w);
                partitions.encode(w);
                partition.encode(w);
            }
            Request::Metrics => w.put_u8(23),
            Request::Spans { trace_id } => {
                w.put_u8(24);
                trace_id.encode(w);
            }
            Request::JoinCluster { member } => {
                w.put_u8(25);
                member.encode(w);
            }
            Request::SpecSync { meta } => {
                w.put_u8(26);
                meta.encode(w);
            }
            Request::FetchLog { topic, partition, from, max } => {
                w.put_u8(27);
                topic.encode(w);
                partition.encode(w);
                from.encode(w);
                max.encode(w);
            }
            Request::FetchOffsets { topic } => {
                w.put_u8(28);
                topic.encode(w);
            }
            Request::Fence { topic, partitions, partition, by } => {
                w.put_u8(29);
                topic.encode(w);
                partitions.encode(w);
                partition.encode(w);
                by.encode(w);
            }
            Request::MigratePartition { topic, partitions, partition, from } => {
                w.put_u8(30);
                topic.encode(w);
                partitions.encode(w);
                partition.encode(w);
                from.encode(w);
            }
            Request::DrainMember { member } => {
                w.put_u8(31);
                member.encode(w);
            }
        }
    }

    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        let at = r.position();
        Ok(match r.get_u8()? {
            0 => Request::Ping,
            1 => Request::CreateTopic { name: Wire::decode(r)?, partitions: Wire::decode(r)? },
            2 => Request::EnsureTopic { name: Wire::decode(r)?, partitions: Wire::decode(r)? },
            3 => Request::DeleteTopic { name: Wire::decode(r)? },
            4 => Request::TopicNames,
            5 => Request::TopicStats { name: Wire::decode(r)? },
            6 => Request::Publish { topic: Wire::decode(r)?, rec: Wire::decode(r)? },
            7 => Request::PublishBatch { topic: Wire::decode(r)?, recs: Wire::decode(r)? },
            8 => Request::JoinGroup {
                group: Wire::decode(r)?,
                topic: Wire::decode(r)?,
                member: Wire::decode(r)?,
                mode: Wire::decode(r)?,
            },
            9 => Request::LeaveGroup {
                group: Wire::decode(r)?,
                topic: Wire::decode(r)?,
                member: Wire::decode(r)?,
            },
            10 => Request::Poll {
                group: Wire::decode(r)?,
                topic: Wire::decode(r)?,
                member: Wire::decode(r)?,
                max: Wire::decode(r)?,
            },
            11 => Request::Commit {
                group: Wire::decode(r)?,
                topic: Wire::decode(r)?,
                commits: Wire::decode(r)?,
            },
            12 => Request::DeleteRecords {
                topic: Wire::decode(r)?,
                partition: Wire::decode(r)?,
                up_to: Wire::decode(r)?,
            },
            13 => Request::Offsets { topic: Wire::decode(r)? },
            14 => Request::CrashMember {
                group: Wire::decode(r)?,
                topic: Wire::decode(r)?,
                member: Wire::decode(r)?,
            },
            15 => Request::Shutdown,
            16 => Request::Positions { group: Wire::decode(r)?, topic: Wire::decode(r)? },
            17 => Request::FetchMany {
                group: Wire::decode(r)?,
                topic: Wire::decode(r)?,
                member: Wire::decode(r)?,
                max: Wire::decode(r)?,
                max_bytes: Wire::decode(r)?,
                wait_ms: Wire::decode(r)?,
            },
            18 => Request::PublishTo {
                topic: Wire::decode(r)?,
                partition: Wire::decode(r)?,
                recs: Wire::decode(r)?,
                acks: r.get_u8()?,
            },
            19 => Request::ClusterMeta,
            20 => Request::Replicate {
                topic: Wire::decode(r)?,
                partitions: Wire::decode(r)?,
                partition: Wire::decode(r)?,
                epoch: Wire::decode(r)?,
                base: Wire::decode(r)?,
                recs: Wire::decode(r)?,
            },
            21 => Request::OffsetSync { topic: Wire::decode(r)?, entries: Wire::decode(r)? },
            22 => Request::Promote {
                topic: Wire::decode(r)?,
                partitions: Wire::decode(r)?,
                partition: Wire::decode(r)?,
            },
            23 => Request::Metrics,
            24 => Request::Spans { trace_id: Wire::decode(r)? },
            25 => Request::JoinCluster { member: Wire::decode(r)? },
            26 => Request::SpecSync { meta: Wire::decode(r)? },
            27 => Request::FetchLog {
                topic: Wire::decode(r)?,
                partition: Wire::decode(r)?,
                from: Wire::decode(r)?,
                max: Wire::decode(r)?,
            },
            28 => Request::FetchOffsets { topic: Wire::decode(r)? },
            29 => Request::Fence {
                topic: Wire::decode(r)?,
                partitions: Wire::decode(r)?,
                partition: Wire::decode(r)?,
                by: Wire::decode(r)?,
            },
            30 => Request::MigratePartition {
                topic: Wire::decode(r)?,
                partitions: Wire::decode(r)?,
                partition: Wire::decode(r)?,
                from: Wire::decode(r)?,
            },
            31 => Request::DrainMember { member: Wire::decode(r)? },
            tag => return Err(DecodeError::BadTag { at, tag: tag as u32, ty: "Request" }),
        })
    }
}

/// Broker → client.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok,
    Pong,
    PubAck { partition: usize, offset: u64 },
    PubBatchAck { acks: Vec<(usize, u64)> },
    Generation(u64),
    Records(Vec<Record>),
    OffsetList(Vec<(u64, u64)>),
    Stats(TopicStatsWire),
    Names(Vec<String>),
    Bool(bool),
    Count(usize),
    /// Multi-partition fetch reply: per-partition record batches plus the
    /// group's post-claim `(position, committed)` cursors (one frame
    /// carries everything a batched poll needs).
    Batches { batches: Vec<(usize, Vec<Record>)>, positions: Vec<(u64, u64)> },
    /// Cluster membership snapshot (reply to [`Request::ClusterMeta`]).
    Cluster(ClusterMetaWire),
    /// Follower's high watermark after applying (or refusing) a
    /// [`Request::Replicate`] batch.
    RepAck { hw: u64 },
    /// A fencing epoch (reply to [`Request::Promote`]).
    Epoch(u64),
    /// The broker process's observability snapshot (reply to
    /// [`Request::Metrics`]).
    Metrics(obs::Snapshot),
    /// The broker process's span flight recorder (reply to
    /// [`Request::Spans`]).
    Spans(Vec<trace::Span>),
    /// Migration catch-up chunk (reply to [`Request::FetchLog`]): the
    /// partition's records from the requested offset plus the source's
    /// high watermark and fencing epoch — `recs` empty and `hw` equal to
    /// the puller's own watermark means it has caught up.
    LogChunk { hw: u64, epoch: u64, recs: Vec<Record> },
    /// Migration offset-journal dump (reply to [`Request::FetchOffsets`]).
    OffsetDump(Vec<OffsetEntry>),
    Err { code: u8, msg: String },
}

/// Wire form of the cluster description: epoch + member list + placement
/// version + replicas-per-partition. An empty member list means "not a
/// cluster member"; `replication: 0` (a pre-PR 7 peer) reads as 1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterMetaWire {
    pub epoch: u64,
    pub version: u32,
    pub members: Vec<String>,
    pub replication: u32,
}

crate::wire_struct!(ClusterMetaWire {
    epoch: u64,
    version: u32,
    members: Vec<String>,
    replication: u32,
});

/// `TopicStats` mirror with Wire support.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopicStatsWire {
    pub partitions: usize,
    pub records: usize,
    pub bytes: usize,
    pub high_watermarks: Vec<u64>,
    pub start_offsets: Vec<u64>,
    pub bytes_on_disk: u64,
    pub segments: usize,
    pub recovered_records: u64,
}

crate::wire_struct!(TopicStatsWire {
    partitions: usize,
    records: usize,
    bytes: usize,
    high_watermarks: Vec<u64>,
    start_offsets: Vec<u64>,
    bytes_on_disk: u64,
    segments: usize,
    recovered_records: u64,
});

impl From<TopicStats> for TopicStatsWire {
    fn from(s: TopicStats) -> Self {
        Self {
            partitions: s.partitions,
            records: s.records,
            bytes: s.bytes,
            high_watermarks: s.high_watermarks,
            start_offsets: s.start_offsets,
            bytes_on_disk: s.bytes_on_disk,
            segments: s.segments,
            recovered_records: s.recovered_records,
        }
    }
}

impl Wire for Response {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            Response::Ok => w.put_u8(0),
            Response::Pong => w.put_u8(1),
            Response::PubAck { partition, offset } => {
                w.put_u8(2);
                partition.encode(w);
                offset.encode(w);
            }
            Response::PubBatchAck { acks } => {
                w.put_u8(3);
                acks.encode(w);
            }
            Response::Generation(g) => {
                w.put_u8(4);
                g.encode(w);
            }
            Response::Records(rs) => {
                w.put_u8(5);
                rs.encode(w);
            }
            Response::OffsetList(os) => {
                w.put_u8(6);
                os.encode(w);
            }
            Response::Stats(s) => {
                w.put_u8(7);
                s.encode(w);
            }
            Response::Names(ns) => {
                w.put_u8(8);
                ns.encode(w);
            }
            Response::Bool(b) => {
                w.put_u8(9);
                b.encode(w);
            }
            Response::Count(c) => {
                w.put_u8(10);
                c.encode(w);
            }
            Response::Batches { batches, positions } => {
                w.put_u8(11);
                batches.encode(w);
                positions.encode(w);
            }
            Response::Cluster(meta) => {
                w.put_u8(12);
                meta.encode(w);
            }
            Response::RepAck { hw } => {
                w.put_u8(13);
                hw.encode(w);
            }
            Response::Epoch(e) => {
                w.put_u8(14);
                e.encode(w);
            }
            Response::Metrics(s) => {
                w.put_u8(15);
                s.encode(w);
            }
            Response::Spans(ss) => {
                w.put_u8(16);
                ss.encode(w);
            }
            Response::LogChunk { hw, epoch, recs } => {
                w.put_u8(17);
                hw.encode(w);
                epoch.encode(w);
                recs.encode(w);
            }
            Response::OffsetDump(entries) => {
                w.put_u8(18);
                entries.encode(w);
            }
            Response::Err { code, msg } => {
                w.put_u8(255);
                w.put_u8(*code);
                msg.encode(w);
            }
        }
    }

    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        let at = r.position();
        Ok(match r.get_u8()? {
            0 => Response::Ok,
            1 => Response::Pong,
            2 => Response::PubAck { partition: Wire::decode(r)?, offset: Wire::decode(r)? },
            3 => Response::PubBatchAck { acks: Wire::decode(r)? },
            4 => Response::Generation(Wire::decode(r)?),
            5 => Response::Records(Wire::decode(r)?),
            6 => Response::OffsetList(Wire::decode(r)?),
            7 => Response::Stats(Wire::decode(r)?),
            8 => Response::Names(Wire::decode(r)?),
            9 => Response::Bool(Wire::decode(r)?),
            10 => Response::Count(Wire::decode(r)?),
            11 => Response::Batches { batches: Wire::decode(r)?, positions: Wire::decode(r)? },
            12 => Response::Cluster(Wire::decode(r)?),
            13 => Response::RepAck { hw: Wire::decode(r)? },
            14 => Response::Epoch(Wire::decode(r)?),
            15 => Response::Metrics(Wire::decode(r)?),
            16 => Response::Spans(Wire::decode(r)?),
            17 => Response::LogChunk {
                hw: Wire::decode(r)?,
                epoch: Wire::decode(r)?,
                recs: Wire::decode(r)?,
            },
            18 => Response::OffsetDump(Wire::decode(r)?),
            255 => Response::Err { code: r.get_u8()?, msg: Wire::decode(r)? },
            tag => return Err(DecodeError::BadTag { at, tag: tag as u32, ty: "Response" }),
        })
    }
}

/// Stable error codes for the wire (superset-safe mapping of `BrokerError`).
pub fn error_code(e: &BrokerError) -> u8 {
    match e {
        BrokerError::UnknownTopic(_) => 1,
        BrokerError::TopicExists(_) => 2,
        BrokerError::BadPartition { .. } => 3,
        BrokerError::UnknownGroup(_) => 4,
        BrokerError::UnknownMember { .. } => 5,
        BrokerError::Transport(_) => 6,
        BrokerError::Storage(_) => 7,
        BrokerError::NotOwner { .. } => 8,
        BrokerError::Fenced { .. } => 9,
    }
}

/// `(code, msg)` for the wire. `NotOwner` ships **only** the owner address
/// as its message so the receiving client can rehydrate the redirect
/// target without parsing prose; `Fenced` ships `epoch@fencer_addr` the
/// same way.
pub fn error_payload(e: &BrokerError) -> (u8, String) {
    let msg = match e {
        BrokerError::NotOwner { owner } => owner.clone(),
        BrokerError::Fenced { epoch, by } => format!("{epoch}@{by}"),
        other => other.to_string(),
    };
    (error_code(e), msg)
}

/// Rehydrate a `BrokerError` from a wire code + message.
pub fn error_from_code(code: u8, msg: String) -> BrokerError {
    match code {
        1 => BrokerError::UnknownTopic(msg),
        2 => BrokerError::TopicExists(msg),
        4 => BrokerError::UnknownGroup(msg),
        5 => BrokerError::UnknownMember { group: msg, member: String::new() },
        3 => BrokerError::BadPartition { topic: msg, partition: 0, count: 0 },
        7 => BrokerError::Storage(msg),
        8 => BrokerError::NotOwner { owner: msg },
        9 => {
            let (epoch, by) = msg.split_once('@').unwrap_or(("0", msg.as_str()));
            BrokerError::Fenced { epoch: epoch.parse().unwrap_or(0), by: by.to_string() }
        }
        _ => BrokerError::Transport(msg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::wire::Blob;

    #[test]
    fn request_roundtrip_all_variants() {
        let reqs = vec![
            Request::Ping,
            Request::CreateTopic { name: "t".into(), partitions: 3 },
            Request::EnsureTopic { name: "t".into(), partitions: 1 },
            Request::DeleteTopic { name: "t".into() },
            Request::TopicNames,
            Request::TopicStats { name: "t".into() },
            Request::Publish {
                topic: "t".into(),
                rec: ProducerRecord::with_key(vec![1], vec![2, 3]),
            },
            Request::PublishBatch {
                topic: "t".into(),
                recs: vec![ProducerRecord::new(vec![1]), ProducerRecord::new(vec![2])],
            },
            Request::JoinGroup {
                group: "g".into(),
                topic: "t".into(),
                member: "m".into(),
                mode: AssignmentMode::Partitioned,
            },
            Request::LeaveGroup { group: "g".into(), topic: "t".into(), member: "m".into() },
            Request::Poll { group: "g".into(), topic: "t".into(), member: "m".into(), max: 7 },
            Request::FetchMany {
                group: "g".into(),
                topic: "t".into(),
                member: "m".into(),
                max: 7,
                max_bytes: 1 << 20,
                wait_ms: 250,
            },
            Request::Commit { group: "g".into(), topic: "t".into(), commits: vec![(0, 5)] },
            Request::DeleteRecords { topic: "t".into(), partition: 1, up_to: 9 },
            Request::Offsets { topic: "t".into() },
            Request::Positions { group: "g".into(), topic: "t".into() },
            Request::CrashMember { group: "g".into(), topic: "t".into(), member: "m".into() },
            Request::Shutdown,
            Request::PublishTo {
                topic: "t".into(),
                partition: 3,
                recs: vec![ProducerRecord::new(vec![9])],
                acks: ACKS_QUORUM,
            },
            Request::ClusterMeta,
            Request::Replicate {
                topic: "t".into(),
                partitions: 16,
                partition: 3,
                epoch: 2,
                base: 7,
                recs: vec![Record {
                    offset: 7,
                    timestamp_ms: 99,
                    key: None,
                    value: Blob::new(vec![1, 2, 3]),
                }],
            },
            Request::OffsetSync {
                topic: "t".into(),
                entries: vec![OffsetEntry {
                    group: "g".into(),
                    mode: AssignmentMode::Shared,
                    partition: 3,
                    position: 9,
                    committed: 7,
                }],
            },
            Request::Promote { topic: "t".into(), partitions: 16, partition: 3 },
            Request::Metrics,
            Request::Spans { trace_id: 0xfeed_beef },
            Request::JoinCluster { member: "127.0.0.1:9095".into() },
            Request::SpecSync {
                meta: ClusterMetaWire {
                    epoch: 3,
                    version: 1,
                    members: vec!["127.0.0.1:9092".into(), "127.0.0.1:9095".into()],
                    replication: 2,
                },
            },
            Request::FetchLog { topic: "t".into(), partition: 3, from: 42, max: 512 },
            Request::FetchOffsets { topic: "t".into() },
            Request::Fence {
                topic: "t".into(),
                partitions: 16,
                partition: 3,
                by: "127.0.0.1:9095".into(),
            },
            Request::MigratePartition {
                topic: "t".into(),
                partitions: 16,
                partition: 3,
                from: "127.0.0.1:9092".into(),
            },
            Request::DrainMember { member: "127.0.0.1:9093".into() },
        ];
        for req in reqs {
            let back = Request::decode_exact(&req.encode_vec()).unwrap();
            assert_eq!(back, req);
        }
    }

    #[test]
    fn response_roundtrip_all_variants() {
        let resps = vec![
            Response::Ok,
            Response::Pong,
            Response::PubAck { partition: 1, offset: 2 },
            Response::PubBatchAck { acks: vec![(0, 1), (1, 0)] },
            Response::Generation(3),
            Response::Records(vec![Record {
                offset: 0,
                timestamp_ms: 1,
                key: None,
                value: Blob::new(vec![1, 2]),
            }]),
            Response::OffsetList(vec![(0, 5)]),
            Response::Stats(TopicStatsWire {
                partitions: 2,
                records: 3,
                bytes: 4,
                high_watermarks: vec![2, 1],
                start_offsets: vec![0, 0],
                bytes_on_disk: 512,
                segments: 2,
                recovered_records: 3,
            }),
            Response::Names(vec!["a".into()]),
            Response::Bool(true),
            Response::Count(9),
            Response::Batches {
                batches: vec![(
                    1,
                    vec![Record {
                        offset: 3,
                        timestamp_ms: 4,
                        key: None,
                        value: Blob::new(vec![9]),
                    }],
                )],
                positions: vec![(4, 2), (0, 0)],
            },
            Response::Cluster(ClusterMetaWire {
                epoch: 2,
                version: 1,
                members: vec!["127.0.0.1:9092".into(), "127.0.0.1:9093".into()],
                replication: 2,
            }),
            Response::RepAck { hw: 42 },
            Response::Epoch(3),
            Response::Metrics(obs::Snapshot {
                counters: vec![("broker.partition.append_records".into(), 7)],
                gauges: vec![("mux.inflight".into(), -1), ("sched.queue_depth".into(), 3)],
                hists: vec![obs::HistSnapshot {
                    name: "broker.latency.publish_to_fetch_us".into(),
                    count: 2,
                    sum_us: 300,
                    buckets: vec![0, 1, 1],
                }],
            }),
            Response::Spans(vec![trace::Span {
                node: "127.0.0.1:9092".into(),
                name: "partition.append".into(),
                trace_id: 0xfeed_beef,
                span_id: 2,
                parent_id: 1,
                start_us: 1_000,
                dur_us: 42,
            }]),
            Response::LogChunk {
                hw: 43,
                epoch: 2,
                recs: vec![Record {
                    offset: 42,
                    timestamp_ms: 7,
                    key: None,
                    value: Blob::new(vec![4, 5]),
                }],
            },
            Response::OffsetDump(vec![OffsetEntry {
                group: "g".into(),
                mode: AssignmentMode::Partitioned,
                partition: 1,
                position: 5,
                committed: 4,
            }]),
            Response::Err { code: 1, msg: "t".into() },
        ];
        for resp in resps {
            let back = Response::decode_exact(&resp.encode_vec()).unwrap();
            assert_eq!(back, resp);
        }
    }

    #[test]
    fn error_codes_roundtrip_variant_kind() {
        let e = BrokerError::UnknownTopic("x".into());
        let back = error_from_code(error_code(&e), "x".into());
        assert!(matches!(back, BrokerError::UnknownTopic(_)));
    }

    #[test]
    fn not_owner_ships_the_owner_address() {
        let e = BrokerError::NotOwner { owner: "10.0.0.2:9092".into() };
        let (code, msg) = error_payload(&e);
        assert_eq!(code, 8);
        assert_eq!(msg, "10.0.0.2:9092", "message must be the bare redirect target");
        match error_from_code(code, msg) {
            BrokerError::NotOwner { owner } => assert_eq!(owner, "10.0.0.2:9092"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn fenced_ships_epoch_and_fencer() {
        let e = BrokerError::Fenced { epoch: 5, by: "10.0.0.3:9092".into() };
        let (code, msg) = error_payload(&e);
        assert_eq!(code, 9);
        assert_eq!(msg, "5@10.0.0.3:9092");
        match error_from_code(code, msg) {
            BrokerError::Fenced { epoch, by } => {
                assert_eq!(epoch, 5);
                assert_eq!(by, "10.0.0.3:9092");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(Request::decode_exact(&[200]).is_err());
        assert!(Response::decode_exact(&[123]).is_err());
    }
}
