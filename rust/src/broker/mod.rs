//! A partitioned-log message broker — the repo's Kafka substitute.
//!
//! The paper's `ObjectDistroStream` is backed by Apache Kafka (§3.2, §4.2.1).
//! This module rebuilds the slice of Kafka the paper relies on, so the ODS
//! code path is exercised with identical semantics:
//!
//! - **Topics** split into **partitions**: immutable, publication-time
//!   ordered records, each with a dense per-partition **offset**.
//! - **Producers** publish records (key-hash or round-robin partitioning).
//! - **Consumer groups** share the records of a topic: each record is
//!   delivered to at least one member of every subscribing group.
//! - **Record deletion** (`AdminClient.deleteRecords` in the paper): the
//!   ODS consumer deletes processed records to get exactly-once.
//!
//! Two consumption disciplines are provided (see [`group`]):
//! [`group::AssignmentMode::Shared`] reproduces the paper's observed
//! greedy "first poller takes everything available" behaviour (the Fig 20
//! load imbalance), while [`group::AssignmentMode::Partitioned`] is the
//! classic Kafka partition-per-member assignment. A per-poll cap
//! (`max_poll_records`) implements the balanced-poll policy the paper
//! proposes as future work (§6.4) — benchmarked in `benches/ablations.rs`.
//!
//! The broker runs [`embedded`] (in-process, lock-per-topic) or remote over
//! TCP ([`server`]/[`client`]) with the same [`client::BrokerClient`] API.
//!
//! Durability ([`storage`]): topics configured [`storage::StorageMode::Disk`]
//! keep a segmented CRC-framed log per partition and a consumer-offset
//! journal per topic, so acked records and committed group offsets survive
//! broker restarts (`BrokerCore::with_config` recovers them at boot).
//!
//! Scale-out ([`cluster`]): topics shard across N broker processes with
//! deterministic client-side routing (rendezvous placement, owner-routed
//! frames, `NotOwner` self-correction). [`ClusterClient`] presents the
//! same surface as [`BrokerClient`] — both implement [`StreamBroker`], the
//! object-safe face the DistroStream layer programs against, so a stream
//! is backend-count agnostic exactly like the paper's homogeneous stream
//! representation (§4.2).
//!
//! High availability ([`cluster::replicate`]): with a replication factor
//! above 1 every partition gets an ordered replica list (leader +
//! followers) from the same rendezvous ranking, the leader streams each
//! append to its followers (byte-identical record frames, CRC-checked on
//! apply), publishes choose [`protocol::ACKS_LEADER`] or
//! [`protocol::ACKS_QUORUM`], and on leader death clients promote the
//! most-caught-up follower — fenced against stale leaders by a
//! monotonically increasing per-partition epoch.

pub mod client;
pub mod cluster;
pub mod embedded;
pub mod group;
pub mod partition;
pub mod protocol;
pub mod record;
pub mod server;
pub mod storage;
pub mod topic;

use std::sync::Arc;

pub use client::{BrokerClient, PendingPublish, PublishPipeline};
pub use cluster::{ClusterClient, ClusterSpec, ClusterView, HaState, Replicator};
pub use embedded::{BrokerCore, MultiFetch};
pub use group::AssignmentMode;
pub use protocol::{ACKS_LEADER, ACKS_QUORUM};
pub use record::Record;
pub use server::BrokerServer;
pub use storage::{BrokerConfig, Retention, StorageMode};

use embedded::{Result, TopicStats};
use record::ProducerRecord;

/// The broker surface the DistroStream layer programs against — one
/// embedded or TCP broker ([`BrokerClient`]) or a whole sharded cluster
/// ([`ClusterClient`]) behind a single object-safe trait. Streams stay
/// backend-count agnostic: a `DistroStreamHub` holds an
/// `Arc<dyn StreamBroker>` and never learns how many processes serve it.
pub trait StreamBroker: Send + Sync {
    fn ping(&self) -> Result<()>;
    fn create_topic(&self, name: &str, partitions: usize) -> Result<()>;
    fn ensure_topic(&self, name: &str, partitions: usize) -> Result<()>;
    fn delete_topic(&self, name: &str) -> Result<()>;
    fn topic_names(&self) -> Result<Vec<String>>;
    fn topic_stats(&self, name: &str) -> Result<TopicStats>;
    fn publish(&self, topic: &str, rec: ProducerRecord) -> Result<(usize, u64)>;
    fn publish_batch(&self, topic: &str, recs: Vec<ProducerRecord>) -> Result<Vec<(usize, u64)>>;
    fn join_group(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        mode: AssignmentMode,
    ) -> Result<u64>;
    fn leave_group(&self, group: &str, topic: &str, member: &str) -> Result<bool>;
    fn poll(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
    ) -> Result<Vec<Arc<Record>>>;
    fn fetch_many_wait(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
        wait_ms: u64,
    ) -> Result<MultiFetch>;
    fn commit(&self, group: &str, topic: &str, commits: &[(usize, u64)]) -> Result<()>;
    fn delete_records(&self, topic: &str, partition: usize, up_to: u64) -> Result<usize>;
    fn offsets(&self, topic: &str) -> Result<Vec<(u64, u64)>>;
    fn positions(&self, group: &str, topic: &str) -> Result<Vec<(u64, u64)>>;
    fn crash_member(&self, group: &str, topic: &str, member: &str) -> Result<()>;

    /// Non-blocking multi-partition drain (default: a zero-wait
    /// [`StreamBroker::fetch_many_wait`]).
    fn fetch_many(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
    ) -> Result<MultiFetch> {
        self.fetch_many_wait(group, topic, member, max, max_bytes, 0)
    }
}
