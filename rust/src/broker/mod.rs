//! A partitioned-log message broker — the repo's Kafka substitute.
//!
//! The paper's `ObjectDistroStream` is backed by Apache Kafka (§3.2, §4.2.1).
//! This module rebuilds the slice of Kafka the paper relies on, so the ODS
//! code path is exercised with identical semantics:
//!
//! - **Topics** split into **partitions**: immutable, publication-time
//!   ordered records, each with a dense per-partition **offset**.
//! - **Producers** publish records (key-hash or round-robin partitioning).
//! - **Consumer groups** share the records of a topic: each record is
//!   delivered to at least one member of every subscribing group.
//! - **Record deletion** (`AdminClient.deleteRecords` in the paper): the
//!   ODS consumer deletes processed records to get exactly-once.
//!
//! Two consumption disciplines are provided (see [`group`]):
//! [`group::AssignmentMode::Shared`] reproduces the paper's observed
//! greedy "first poller takes everything available" behaviour (the Fig 20
//! load imbalance), while [`group::AssignmentMode::Partitioned`] is the
//! classic Kafka partition-per-member assignment. A per-poll cap
//! (`max_poll_records`) implements the balanced-poll policy the paper
//! proposes as future work (§6.4) — benchmarked in `benches/ablations.rs`.
//!
//! The broker runs [`embedded`] (in-process, lock-per-topic) or remote over
//! TCP ([`server`]/[`client`]) with the same [`client::BrokerClient`] API.
//!
//! Durability ([`storage`]): topics configured [`storage::StorageMode::Disk`]
//! keep a segmented CRC-framed log per partition and a consumer-offset
//! journal per topic, so acked records and committed group offsets survive
//! broker restarts (`BrokerCore::with_config` recovers them at boot).

pub mod client;
pub mod embedded;
pub mod group;
pub mod partition;
pub mod protocol;
pub mod record;
pub mod server;
pub mod storage;
pub mod topic;

pub use client::BrokerClient;
pub use embedded::{BrokerCore, MultiFetch};
pub use group::AssignmentMode;
pub use record::Record;
pub use server::BrokerServer;
pub use storage::{BrokerConfig, Retention, StorageMode};
