//! A single partition: an append-only log with front truncation.
//!
//! Offsets are dense and never reused; deleting processed records
//! (exactly-once support) advances `start_offset` without renumbering.
//!
//! A partition is either memory-only (the default — the zero-copy hot
//! path, unchanged) or durable: opened with [`PartitionLog::open_disk`] it
//! keeps a write-through [`DiskLog`] twin. Memory stays the serving side
//! in both modes — fetches always hand out the same `Arc` records — while
//! the disk side makes acked records survive a process restart: `open_disk`
//! replays every valid on-disk record back into the in-memory deque.

use std::collections::VecDeque;
use std::path::Path;
use std::sync::Arc;

use crate::util::wire::Blob;

use super::record::{now_ms, ProducerRecord, Record};
use super::storage::{DiskLog, Retention};

/// Append-only record log with O(1) front truncation. Records are stored
/// behind `Arc` so fetches are O(1) per record regardless of payload size
/// (consumers share the payload; no copy on the embedded hot path).
#[derive(Debug, Default)]
pub struct PartitionLog {
    records: VecDeque<Arc<Record>>,
    /// Offset of the first retained record.
    start: u64,
    /// Next offset to assign (== high watermark).
    next: u64,
    /// Total bytes retained (metrics/backpressure).
    bytes: usize,
    /// Replication fencing epoch (memory-mode storage; in disk mode the
    /// `DiskLog` persists it and this field mirrors it).
    epoch: u64,
    /// Durable write-through twin (`None` = memory-only).
    disk: Option<DiskLog>,
}

impl PartitionLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Open a durable partition under `dir`, replaying every live on-disk
    /// record into memory (crash recovery). The in-memory invariants are
    /// re-derived from the recovered log: `records[0].offset == start` and
    /// `start + records.len() == next`.
    pub fn open_disk(
        dir: &Path,
        segment_bytes: u64,
        retention: Retention,
    ) -> std::io::Result<Self> {
        let (disk, recovered) = DiskLog::open(dir, segment_bytes, retention)?;
        let next = disk.next_offset();
        let start = next - recovered.len() as u64;
        debug_assert!(recovered.first().map_or(true, |r| r.offset == start));
        let bytes = recovered.iter().map(|r| r.payload_len()).sum();
        let epoch = disk.epoch();
        Ok(Self { records: recovered.into(), start, next, bytes, epoch, disk: Some(disk) })
    }

    /// Offset that the next appended record will get.
    pub fn high_watermark(&self) -> u64 {
        self.next
    }

    /// Offset of the earliest retained record.
    pub fn start_offset(&self) -> u64 {
        self.start
    }

    /// Number of retained records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Retained payload bytes.
    pub fn retained_bytes(&self) -> usize {
        self.bytes
    }

    /// Append one producer record; returns its assigned offset. In disk
    /// mode the record is written through to the segmented log (same `Arc`
    /// bytes) before the in-memory append; retention triggered by a
    /// segment roll trims the memory mirror to the new disk start.
    pub fn append(&mut self, rec: ProducerRecord) -> u64 {
        let offset = self.next;
        self.next += 1;
        let stored =
            Arc::new(Record { offset, timestamp_ms: now_ms(), key: rec.key, value: rec.value });
        if let Some(disk) = &mut self.disk {
            if let Some(new_start) = disk.append(&stored) {
                self.trim_to(new_start);
            }
        }
        crate::obs_counter!("broker.partition.append_records").inc();
        crate::obs_counter!("broker.partition.append_bytes").add(stored.payload_len() as u64);
        self.bytes += stored.payload_len();
        self.records.push_back(stored);
        offset
    }

    /// Append a record replicated from the partition leader, preserving
    /// its offset and timestamp verbatim (the HA plane's follower apply —
    /// the wire `Record` is byte-identical to what the leader framed, so
    /// the write-through keeps leader and follower segments identical).
    /// The caller guarantees density (`rec.offset == high_watermark`).
    pub fn append_replica(&mut self, rec: Arc<Record>) {
        debug_assert_eq!(rec.offset, self.next, "replica apply must stay dense");
        self.next = rec.offset + 1;
        if let Some(disk) = &mut self.disk {
            if let Some(new_start) = disk.append(&rec) {
                self.trim_to(new_start);
            }
        }
        // End-to-end replication latency: the leader stamped this record
        // at its original append; "now" is the follower's apply.
        crate::obs_hist!("broker.latency.publish_to_replica_us")
            .observe_ms_span(rec.timestamp_ms, now_ms());
        crate::obs_counter!("broker.partition.replica_records").inc();
        self.bytes += rec.payload_len();
        self.records.push_back(rec);
    }

    /// Replication fencing epoch last adopted by this partition.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Adopt a fencing epoch (forward-only; persisted in disk mode).
    pub fn set_epoch(&mut self, epoch: u64) {
        if epoch <= self.epoch {
            return;
        }
        self.epoch = epoch;
        if let Some(disk) = &mut self.disk {
            disk.set_epoch(epoch);
        }
    }

    /// Fetch up to `max` records with offset >= `from` (Arc clones — O(1)
    /// per record; the log is shared by many consumer groups).
    pub fn fetch(&self, from: u64, max: usize) -> Vec<Arc<Record>> {
        self.fetch_budgeted(from, max, usize::MAX)
    }

    /// Fetch up to `max` records totalling at most `max_bytes` of payload,
    /// starting at offset `from`. The budget is strict: the batch stops
    /// *before* any record that would overflow it, so the result may be
    /// empty even when records are available (a caller draining several
    /// partitions under one shared budget must be able to rely on that —
    /// [`super::embedded::BrokerCore::fetch_many`] layers the one-record
    /// progress guarantee on top).
    pub fn fetch_budgeted(&self, from: u64, max: usize, max_bytes: usize) -> Vec<Arc<Record>> {
        if self.records.is_empty() || max == 0 {
            return Vec::new();
        }
        let from = from.max(self.start);
        if from >= self.next {
            return Vec::new();
        }
        let idx = (from - self.start) as usize;
        // Pre-size to the exact worst case (`max` capped by what is
        // retained past `from`) so large drains never reallocate mid-copy.
        // Under a finite byte budget the record count is unknowable up
        // front, so cap the guess — a tiny budget over a huge backlog must
        // not allocate pointer space for the whole backlog per fetch.
        let avail = max.min(self.records.len() - idx);
        let cap = if max_bytes == usize::MAX { avail } else { avail.min(64) };
        let mut out = Vec::with_capacity(cap);
        let mut bytes = 0usize;
        for rec in self.records.iter().skip(idx).take(max) {
            let len = rec.payload_len();
            if bytes.saturating_add(len) > max_bytes {
                break;
            }
            bytes += len;
            out.push(Arc::clone(rec));
        }
        out
    }

    /// Drop records with offset < `up_to`. Returns how many were deleted.
    /// In disk mode the advanced start is persisted and sealed segments
    /// fully below it are reclaimed.
    pub fn delete_up_to(&mut self, up_to: u64) -> usize {
        let deleted = self.trim_to(up_to);
        if let Some(disk) = &mut self.disk {
            disk.set_start(up_to);
        }
        deleted
    }

    /// Memory-side front truncation (shared by deletion and retention).
    fn trim_to(&mut self, up_to: u64) -> usize {
        let mut deleted = 0;
        while let Some(front) = self.records.front() {
            if front.offset >= up_to {
                break;
            }
            self.bytes -= front.payload_len();
            self.records.pop_front();
            deleted += 1;
        }
        self.start = self.start.max(up_to.min(self.next));
        deleted
    }

    /// First record payload (tests/debugging).
    pub fn front_value(&self) -> Option<&Blob> {
        self.records.front().map(|r| &r.value)
    }

    // ---- durability introspection --------------------------------------

    /// True when this partition has a disk backing.
    pub fn is_durable(&self) -> bool {
        self.disk.is_some()
    }

    /// Bytes in this partition's segment files (0 in memory mode).
    pub fn bytes_on_disk(&self) -> u64 {
        self.disk.as_ref().map_or(0, DiskLog::bytes_on_disk)
    }

    /// Segment count (0 in memory mode).
    pub fn segment_count(&self) -> usize {
        self.disk.as_ref().map_or(0, DiskLog::segment_count)
    }

    /// Records replayed from disk when this partition was opened.
    pub fn recovered_records(&self) -> u64 {
        self.disk.as_ref().map_or(0, DiskLog::recovered)
    }

    /// Durable twin (tests / recovery verification).
    pub fn disk(&self) -> Option<&DiskLog> {
        self.disk.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::{check, ensure};
    use crate::util::rng::Rng;

    fn rec(v: u8) -> ProducerRecord {
        ProducerRecord::new(vec![v])
    }

    #[test]
    fn offsets_are_dense_from_zero() {
        let mut log = PartitionLog::new();
        assert_eq!(log.append(rec(0)), 0);
        assert_eq!(log.append(rec(1)), 1);
        assert_eq!(log.high_watermark(), 2);
        assert_eq!(log.start_offset(), 0);
    }

    #[test]
    fn fetch_respects_from_and_max() {
        let mut log = PartitionLog::new();
        for i in 0..10 {
            log.append(rec(i));
        }
        let got = log.fetch(3, 4);
        assert_eq!(got.iter().map(|r| r.offset).collect::<Vec<_>>(), vec![3, 4, 5, 6]);
        assert!(log.fetch(10, 5).is_empty());
        assert!(log.fetch(0, 0).is_empty());
    }

    #[test]
    fn delete_advances_start_without_renumbering() {
        let mut log = PartitionLog::new();
        for i in 0..5 {
            log.append(rec(i));
        }
        assert_eq!(log.delete_up_to(3), 3);
        assert_eq!(log.start_offset(), 3);
        assert_eq!(log.len(), 2);
        // New appends continue the sequence.
        assert_eq!(log.append(rec(9)), 5);
        // Fetching below start clamps to start.
        let got = log.fetch(0, 10);
        assert_eq!(got.first().unwrap().offset, 3);
    }

    #[test]
    fn delete_beyond_watermark_clamps() {
        let mut log = PartitionLog::new();
        log.append(rec(0));
        assert_eq!(log.delete_up_to(100), 1);
        assert_eq!(log.start_offset(), 1);
        assert_eq!(log.append(rec(1)), 1);
    }

    #[test]
    fn byte_accounting_tracks_retained() {
        let mut log = PartitionLog::new();
        log.append(ProducerRecord::new(vec![0; 10]));
        log.append(ProducerRecord::new(vec![0; 20]));
        assert_eq!(log.retained_bytes(), 30);
        log.delete_up_to(1);
        assert_eq!(log.retained_bytes(), 20);
    }

    #[test]
    fn byte_budget_truncates_fetch() {
        let mut log = PartitionLog::new();
        for _ in 0..5 {
            log.append(ProducerRecord::new(vec![0; 10]));
        }
        // 25 bytes of budget → 2 whole records (the 3rd would overflow).
        let got = log.fetch_budgeted(0, usize::MAX, 25);
        assert_eq!(got.len(), 2);
        // Exact fit takes exactly 3.
        assert_eq!(log.fetch_budgeted(0, usize::MAX, 30).len(), 3);
        // Record cap still applies under a generous byte budget.
        assert_eq!(log.fetch_budgeted(0, 1, usize::MAX).len(), 1);
    }

    #[test]
    fn oversized_first_record_yields_empty_batch() {
        // Strict budget: the progress guarantee lives in fetch_many, not
        // here, so shared cross-partition budgets stay exact.
        let mut log = PartitionLog::new();
        log.append(ProducerRecord::new(vec![0; 100]));
        log.append(ProducerRecord::new(vec![0; 100]));
        assert!(log.fetch_budgeted(0, usize::MAX, 10).is_empty());
        assert_eq!(log.fetch_budgeted(0, usize::MAX, 100).len(), 1);
    }

    #[test]
    fn budget_counts_keys_too() {
        let mut log = PartitionLog::new();
        log.append(ProducerRecord::with_key(vec![0; 8], vec![0; 8]));
        log.append(ProducerRecord::with_key(vec![0; 8], vec![0; 8]));
        // Each record is 16 payload bytes (key + value).
        assert_eq!(log.fetch_budgeted(0, usize::MAX, 16).len(), 1);
        assert_eq!(log.fetch_budgeted(0, usize::MAX, 32).len(), 2);
    }

    #[test]
    fn fetch_presizes_without_overallocating() {
        let mut log = PartitionLog::new();
        for i in 0..8 {
            log.append(rec(i));
        }
        log.delete_up_to(2);
        // `max` far beyond what is retained must cap the allocation.
        // (`with_capacity` guarantees *at least* the request, so assert an
        // upper bound rather than exact equality.)
        let got = log.fetch_budgeted(0, usize::MAX, usize::MAX);
        assert_eq!(got.len(), 6);
        assert!(got.capacity() <= 8, "capacity ≈ min(max, retained past from)");
        let got = log.fetch_budgeted(4, 100, usize::MAX);
        assert!(got.capacity() <= 8, "got {}", got.capacity());
        // A tiny byte budget over a large backlog must not pre-allocate
        // pointer space for the whole backlog.
        let got = log.fetch_budgeted(0, usize::MAX, 1);
        assert!(got.capacity() <= 64, "byte-budgeted fetch over-allocated: {}", got.capacity());
    }

    #[test]
    fn fetch_shares_payload_allocations() {
        let mut log = PartitionLog::new();
        let payload = crate::util::wire::Blob::new(vec![7u8; 1 << 16]);
        log.append(ProducerRecord { key: None, value: payload.clone() });
        let a = log.fetch(0, 1);
        let b = log.fetch(0, 1);
        assert!(a[0].value.ptr_eq(&payload), "append must not copy the payload");
        assert!(a[0].value.ptr_eq(&b[0].value), "every fetch shares one allocation");
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("hybridws-part-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn replica_append_preserves_offset_and_timestamp() {
        let mut leader = PartitionLog::new();
        for i in 0..4 {
            leader.append(rec(i));
        }
        let mut follower = PartitionLog::new();
        for r in leader.fetch(0, usize::MAX) {
            follower.append_replica(r);
        }
        assert_eq!(follower.high_watermark(), 4);
        let a = leader.fetch(0, usize::MAX);
        let b = follower.fetch(0, usize::MAX);
        for (l, f) in a.iter().zip(&b) {
            assert_eq!(l.offset, f.offset);
            assert_eq!(l.timestamp_ms, f.timestamp_ms, "timestamps replicate verbatim");
            assert!(l.value.ptr_eq(&f.value), "in-process replication shares the allocation");
        }
        // Epochs adopt forward-only.
        follower.set_epoch(2);
        follower.set_epoch(1);
        assert_eq!(follower.epoch(), 2);
    }

    #[test]
    fn disk_partition_recovers_records_and_watermarks() {
        let dir = tmp_dir("recover");
        {
            let mut log = PartitionLog::open_disk(&dir, 1 << 20, Retention::default()).unwrap();
            assert!(log.is_durable());
            assert_eq!(log.recovered_records(), 0);
            for i in 0..8 {
                assert_eq!(log.append(rec(i)), i as u64);
            }
            assert_eq!(log.delete_up_to(3), 3);
        }
        let log = PartitionLog::open_disk(&dir, 1 << 20, Retention::default()).unwrap();
        assert_eq!(log.recovered_records(), 5);
        assert_eq!(log.start_offset(), 3);
        assert_eq!(log.high_watermark(), 8);
        let got = log.fetch(0, usize::MAX);
        assert_eq!(got.iter().map(|r| r.offset).collect::<Vec<_>>(), vec![3, 4, 5, 6, 7]);
        assert_eq!(got.iter().map(|r| r.value.0[0]).collect::<Vec<_>>(), vec![3, 4, 5, 6, 7]);
        assert!(log.bytes_on_disk() > 0);
        assert!(log.segment_count() >= 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn disk_partition_serves_the_published_allocation() {
        // Durability must not break the memory-path zero-copy contract:
        // within one process lifetime, fetches still share the producer's
        // own allocation.
        let dir = tmp_dir("zerocopy");
        let mut log = PartitionLog::open_disk(&dir, 1 << 20, Retention::default()).unwrap();
        let payload = crate::util::wire::Blob::new(vec![7u8; 1 << 16]);
        log.append(ProducerRecord { key: None, value: payload.clone() });
        let got = log.fetch(0, 1);
        assert!(got[0].value.ptr_eq(&payload), "disk-mode append must not copy the payload");
        // And the same bytes are durably framed on disk.
        let on_disk = log.disk().unwrap().read(0).unwrap().unwrap();
        assert_eq!(on_disk.value, payload);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn disk_partition_retention_trims_memory_too() {
        let dir = tmp_dir("ret");
        let retention = Retention::default().max_bytes(400);
        let mut log = PartitionLog::open_disk(&dir, 128, retention).unwrap();
        for _ in 0..80 {
            log.append(ProducerRecord::new(vec![0u8; 24]));
        }
        assert!(log.start_offset() > 0, "retention must advance the start");
        assert_eq!(
            log.fetch(0, usize::MAX).first().unwrap().offset,
            log.start_offset(),
            "memory mirror trimmed to the disk start"
        );
        assert_eq!(log.len() as u64, log.high_watermark() - log.start_offset());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prop_fetch_after_random_ops_is_ordered_and_dense() {
        check("partition log invariants", |r: &mut Rng| {
            // Ops: 0..n appends interleaved with deletes.
            let n = r.range(1, 40);
            (0..n).map(|_| r.below(3)).collect::<Vec<u64>>()
        }, |ops| {
            let mut log = PartitionLog::new();
            for (i, op) in ops.iter().enumerate() {
                match op {
                    0 | 1 => {
                        log.append(ProducerRecord::new(vec![i as u8]));
                    }
                    _ => {
                        let mid = (log.start_offset() + log.high_watermark()) / 2;
                        log.delete_up_to(mid);
                    }
                }
            }
            let recs = log.fetch(0, usize::MAX);
            // Offsets strictly increasing by one, starting at start_offset.
            for (i, r) in recs.iter().enumerate() {
                ensure(r.offset == log.start_offset() + i as u64, "offset not dense")?;
            }
            ensure(
                log.start_offset() + recs.len() as u64 == log.high_watermark(),
                "watermark mismatch",
            )
        });
    }
}
