//! TCP front-end for [`BrokerCore`]: one thread per connection, framed
//! request/response (see [`super::protocol`]).
//!
//! The first frame of a connection picks its protocol (PR 5): a mux hello
//! ([`crate::util::mux`]) upgrades to the **pipelined multiplexed plane**
//! — many in-flight requests per socket, matched by correlation id, with
//! long-polls parked on their own threads so their responses complete out
//! of order behind later requests. Anything else is served in the legacy
//! lock-step mode, one request/response pair at a time, with a reused
//! per-connection encode buffer.
//!
//! Long-poll fetches ([`Request::FetchMany`] with `wait_ms > 0`) park
//! inside [`BrokerCore::fetch_many_wait`] — the client holds one
//! outstanding request instead of spinning empty fetches. Connection
//! threads honour [`BrokerServer::shutdown`] through a socket read
//! timeout: between frames they poll the stop flag, so shutdown no longer
//! leaks live threads waiting on peers that never close.
//!
//! A server started with [`BrokerServer::start_cluster`] carries a
//! [`ClusterView`]: it answers [`Request::ClusterMeta`], serves
//! partition-targeted publishes only for partitions it owns (stale clients
//! get `NotOwner { owner_addr }`, wire code 8), and routes legacy
//! partition-less publishes onto its own shard.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use log::{debug, warn};

use crate::util::fault;
use crate::util::mux::{serve_legacy_conn, serve_mux_conn, sniff_first_frame, ServeAction, Sniff};
use crate::util::trace;
use crate::util::wire::{read_frame_patient, Wire};

use super::cluster::{migrate, ClusterSpec, ClusterView, Replicator, PLACEMENT_VERSION};
use super::embedded::{BrokerCore, BrokerError};
use super::protocol::{error_payload, ClusterMetaWire, Request, Response, ACKS_QUORUM};
use super::record::ProducerRecord;
use super::topic::key_partition;

/// Server-side clamp on one long-poll park. Remote clients with longer
/// timeouts simply re-issue the fetch; the clamp bounds how long a parked
/// connection can delay server shutdown.
pub const MAX_SERVER_WAIT_MS: u64 = 5_000;

/// Read timeout on connection sockets — the granularity at which idle
/// connection threads notice the stop flag.
pub const CONN_READ_TIMEOUT: Duration = Duration::from_millis(100);

/// Handle to a running broker server.
pub struct BrokerServer {
    pub addr: SocketAddr,
    core: Arc<BrokerCore>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// The cluster view (if any) — kept so shutdown can stop the
    /// replication worker it started.
    cluster: Arc<Option<ClusterView>>,
}

impl BrokerServer {
    /// Bind `addr` (use `127.0.0.1:0` for an ephemeral port) and serve.
    pub fn start(core: Arc<BrokerCore>, addr: &str) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Self::start_on(core, listener, None)
    }

    /// Serve on a pre-bound listener as a **cluster member**: the view
    /// makes this broker answer `ClusterMeta`, enforce partition ownership
    /// (`NotOwner` redirects) and keep legacy publishes on its own shard.
    /// The listener is pre-bound because the cluster spec needs every
    /// member's final address before any member starts.
    pub fn start_cluster(
        core: Arc<BrokerCore>,
        listener: TcpListener,
        view: ClusterView,
    ) -> std::io::Result<Self> {
        Self::start_on(core, listener, Some(view))
    }

    fn start_on(
        core: Arc<BrokerCore>,
        listener: TcpListener,
        view: Option<ClusterView>,
    ) -> std::io::Result<Self> {
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let cluster: Arc<Option<ClusterView>> = Arc::new(view);
        // Replicating members (PR 7) run a segment-shipping worker that
        // streams every leader-side append to the partition's followers.
        if let Some(v) = cluster.as_ref() {
            let spec = v.spec();
            if spec.replication() > 1 {
                let rep = Replicator::start(Arc::clone(&core), spec, v.self_addr.clone(), v.ha());
                v.set_replicator(rep);
            }
        }
        let accept_core = Arc::clone(&core);
        let accept_stop = Arc::clone(&stop);
        let held_cluster = Arc::clone(&cluster);
        let accept_thread = std::thread::Builder::new()
            .name("broker-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if accept_stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(sock) => {
                            let core = Arc::clone(&accept_core);
                            let stop = Arc::clone(&accept_stop);
                            let cluster = Arc::clone(&cluster);
                            std::thread::Builder::new()
                                .name("broker-conn".into())
                                .spawn(move || handle_conn(core, cluster, stop, sock))
                                .expect("spawn conn thread");
                        }
                        Err(e) => {
                            warn!("broker accept error: {e}");
                            break;
                        }
                    }
                }
            })?;
        Ok(Self {
            addr: local,
            core,
            stop,
            accept_thread: Some(accept_thread),
            cluster: held_cluster,
        })
    }

    /// Stop the replication worker, if this member started one. Idempotent.
    fn stop_replication(&self) {
        if let Some(rep) = self.cluster.as_ref().as_ref().and_then(|v| v.replicator()) {
            rep.stop();
        }
    }

    /// The served core (embedded-side inspection in tests).
    pub fn core(&self) -> Arc<BrokerCore> {
        Arc::clone(&self.core)
    }

    /// The cluster view, when this server was started as a member — the
    /// join CLI drives [`migrate::join`] against it after the listener is
    /// already serving (the joiner must answer redirects mid-pull).
    pub fn cluster_view(&self) -> Option<&ClusterView> {
        self.cluster.as_ref().as_ref()
    }

    /// Stop accepting and join the accept thread. Existing connection
    /// threads exit when their peers close.
    pub fn shutdown(mut self) {
        self.stop_replication();
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept with a no-op connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for BrokerServer {
    fn drop(&mut self) {
        self.stop_replication();
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    core: Arc<BrokerCore>,
    cluster: Arc<Option<ClusterView>>,
    stop: Arc<AtomicBool>,
    mut sock: TcpStream,
) {
    let peer = sock.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    debug!("broker conn from {peer}");
    // Fault seam: sever a scripted server-side connection before any frame
    // is served (the client sees an abrupt close and must reconnect). The
    // context is this broker's own address so scenarios can target one
    // member of a cluster.
    if fault::active() {
        let local = sock.local_addr().map(|a| a.to_string()).unwrap_or_default();
        if fault::check(fault::site::BROKER_CONN, &local).is_some() {
            debug!("broker conn {peer}: injected drop");
            return;
        }
    }
    // Small lock-step replies must not sit out a Nagle delay (clients
    // always set nodelay; the server-accepted half never did before PR 5).
    let _ = sock.set_nodelay(true);
    // The read timeout lets the loops poll the stop flag between frames;
    // the patient readers keep partial frames intact across timeout ticks.
    let _ = sock.set_read_timeout(Some(CONN_READ_TIMEOUT));
    // The first frame picks the protocol: a mux hello upgrades the
    // connection, anything else is a legacy lock-step request.
    let first = match read_frame_patient(&mut sock, || !stop.load(Ordering::SeqCst)) {
        Ok(Some(buf)) => buf,
        Ok(None) => return,
        Err(e) => {
            debug!("broker conn {peer} read error: {e}");
            return;
        }
    };
    match sniff_first_frame(&mut sock, &first, &peer) {
        Sniff::Mux { trace } => serve_mux(core, cluster, stop, sock, peer, trace),
        Sniff::Reject => {}
        Sniff::Legacy => match Request::decode_exact(&first) {
            Ok(req) => serve_legacy(core, cluster, stop, sock, peer, req),
            Err(e) => debug!("broker conn {peer} bad first frame: {e}"),
        },
    }
}

/// The pre-PR 5 lock-step mode, on the shared loop ([`serve_legacy_conn`]):
/// one request, one response, strictly serial. Kept for old peers and
/// raw-socket tools.
fn serve_legacy(
    core: Arc<BrokerCore>,
    cluster: Arc<Option<ClusterView>>,
    stop: Arc<AtomicBool>,
    sock: TcpStream,
    peer: String,
    first: Request,
) {
    let keep_going = {
        let stop = Arc::clone(&stop);
        move || !stop.load(Ordering::SeqCst)
    };
    let classify = move |req: &Request| {
        if matches!(req, Request::Shutdown) {
            stop.store(true, Ordering::SeqCst);
            ServeAction::Terminal
        } else {
            ServeAction::Inline
        }
    };
    let dispatch = Arc::new(move |req: Request| dispatch_at(&core, (*cluster).as_ref(), req));
    serve_legacy_conn(sock, &peer, keep_going, classify, dispatch, first);
}

/// The pipelined mux mode (PR 5), on the shared serve loop
/// ([`serve_mux_conn`]): non-blocking requests dispatch inline (publish
/// acks keep submission order); long-polls park on their own threads and
/// answer out of order by correlation id. `Shutdown` sets the stop flag
/// from the classifier before its ack goes out.
fn serve_mux(
    core: Arc<BrokerCore>,
    cluster: Arc<Option<ClusterView>>,
    stop: Arc<AtomicBool>,
    sock: TcpStream,
    peer: String,
    trace: bool,
) {
    debug!("broker conn {peer}: mux mode");
    let keep_going = {
        let stop = Arc::clone(&stop);
        move || !stop.load(Ordering::SeqCst)
    };
    let classify = move |req: &Request| {
        if matches!(req, Request::Shutdown) {
            stop.store(true, Ordering::SeqCst);
            ServeAction::Terminal
        } else if req.park_wait_ms() > 0 {
            ServeAction::Park
        } else {
            ServeAction::Inline
        }
    };
    let dispatch = Arc::new(move |req: Request| dispatch_at(&core, (*cluster).as_ref(), req));
    serve_mux_conn(sock, &peer, "broker-park", trace, keep_going, classify, dispatch);
}

/// Map one request onto the core (standalone broker: no cluster view).
pub fn dispatch(core: &BrokerCore, req: Request) -> Response {
    dispatch_at(core, None, req)
}

/// Route legacy partition-less publishes onto this member's own shard:
/// keyed records must match the cluster-wide key hash (a key owned
/// elsewhere redirects with `NotOwner`); key-less records rotate over the
/// partitions this broker owns.
fn cluster_publish(
    core: &BrokerCore,
    view: &ClusterView,
    topic: &str,
    recs: Vec<ProducerRecord>,
) -> Result<Vec<(usize, u64)>, BrokerError> {
    let parts = core.partition_count(topic)?;
    // One spec snapshot for the whole batch: a membership flip mid-loop
    // must not route half the records under each placement.
    let spec = view.spec();
    let owned = view.owned_partitions(topic, parts);
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); parts];
    for (i, rec) in recs.iter().enumerate() {
        let p = match &rec.key {
            Some(k) => {
                let p = key_partition(&k.0, parts);
                if !view.owns(topic, p) {
                    return Err(BrokerError::NotOwner {
                        owner: spec.owner(topic, p).to_string(),
                    });
                }
                p
            }
            None => view.next_owned(&owned).ok_or_else(|| BrokerError::NotOwner {
                owner: spec.owner(topic, 0).to_string(),
            })?,
        };
        buckets[p].push(i);
    }
    let mut slots: Vec<Option<ProducerRecord>> = recs.into_iter().map(Some).collect();
    let mut acks = vec![(0usize, 0u64); slots.len()];
    for (p, bucket) in buckets.iter().enumerate() {
        if bucket.is_empty() {
            continue;
        }
        let batch: Vec<ProducerRecord> = bucket
            .iter()
            .map(|&i| slots[i].take().expect("record consumed twice"))
            .collect();
        let offsets = core.publish_to(topic, p, batch)?;
        // Legacy frames carry no acks level: the broker's own default
        // (`--acks`) decides whether the ack waits for the quorum.
        if let (Some(rep), Some(&base)) = (view.replicator(), offsets.first()) {
            let count = offsets.len() as u64;
            rep.enqueue(topic, parts, p, base, count, trace::current());
            if view.default_acks() == ACKS_QUORUM {
                rep.wait_quorum(topic, p, base + count)?;
            }
        }
        for (&i, off) in bucket.iter().zip(offsets) {
            acks[i] = (p, off);
        }
    }
    Ok(acks)
}

/// Map one request onto the core, enforcing cluster ownership when a
/// [`ClusterView`] is present.
pub fn dispatch_at(core: &BrokerCore, cluster: Option<&ClusterView>, req: Request) -> Response {
    use Request as Q;
    use Response as A;
    // The broker-side span of a sampled request: a child of whatever
    // context the frame carried (ambient on this thread since the serve
    // loop set it). Inert outside the hot verbs or when unsampled.
    let _span = match &req {
        Q::PublishTo { .. } => Some(trace::span("broker.publish_to")),
        Q::Publish { .. } | Q::PublishBatch { .. } => Some(trace::span("broker.publish")),
        Q::FetchMany { .. } => Some(trace::span("broker.fetch")),
        Q::Poll { .. } => Some(trace::span("broker.poll")),
        Q::Replicate { .. } => Some(trace::span("replica.apply")),
        Q::FetchLog { .. } => Some(trace::span("migrate.serve_log")),
        _ => None,
    };
    let to_err = |e: &BrokerError| {
        let (code, msg) = error_payload(e);
        A::Err { code, msg }
    };
    match req {
        Q::Ping => A::Pong,
        Q::Shutdown => A::Ok,
        // The scrape face of the PR 8 observability plane: one frame
        // returns every metric the process has registered.
        Q::Metrics => A::Metrics(crate::util::obs::snapshot()),
        // The scrape face of the PR 9 tracing plane: this process's span
        // flight recorder, optionally filtered to one trace.
        Q::Spans { trace_id } => A::Spans(trace::snapshot_wire(trace_id)),
        Q::ClusterMeta => A::Cluster(match cluster {
            Some(v) => v.spec().to_wire(),
            None => ClusterMetaWire {
                epoch: 0,
                version: PLACEMENT_VERSION,
                members: Vec::new(),
                replication: 1,
            },
        }),
        Q::PublishTo { topic, partition, recs, acks } => {
            if let Some(v) = cluster {
                // The existence check must come first: ownership of an
                // unknown topic is still computable, but the client needs
                // UnknownTopic to trigger its re-ensure self-heal.
                match core.partition_count(&topic) {
                    Ok(_) => {}
                    Err(e) => return to_err(&e),
                }
                // Leadership, not static ownership: a promotion makes this
                // broker serve out-of-placement partitions; a deposal makes
                // it redirect to the broker that fenced it.
                if !v.leads(&topic, partition) {
                    return to_err(&BrokerError::NotOwner {
                        owner: v.leader_of(&topic, partition),
                    });
                }
            }
            let count = recs.len() as u64;
            match core.publish_to(&topic, partition, recs) {
                Ok(offsets) => {
                    if let Some(rep) = cluster.and_then(|v| v.replicator()) {
                        if let Some(&base) = offsets.first() {
                            let parts = core.partition_count(&topic).unwrap_or(partition + 1);
                            rep.enqueue(&topic, parts, partition, base, count, trace::current());
                            if acks == ACKS_QUORUM {
                                // Hold the ack until every in-sync follower
                                // confirms the batch (laggards get benched
                                // at the deadline; a fencing loses the
                                // leadership and fails the publish).
                                if let Err(e) = rep.wait_quorum(&topic, partition, base + count) {
                                    return to_err(&e);
                                }
                            }
                        }
                    }
                    A::PubBatchAck {
                        acks: offsets.into_iter().map(|o| (partition, o)).collect(),
                    }
                }
                Err(e) => to_err(&e),
            }
        }
        Q::Replicate { topic, partitions, partition, epoch, base, recs } => {
            // Follower-side apply. Works without a view too (standalone
            // receivers in tests); the fencer address in a refusal is this
            // broker's advertised address when it has one.
            match core.replica_append(&topic, partitions, partition, epoch, base, recs) {
                Ok(hw) => A::RepAck { hw },
                Err(BrokerError::Fenced { epoch, by }) => {
                    let by = if by.is_empty() {
                        cluster.map(|v| v.self_addr.clone()).unwrap_or_default()
                    } else {
                        by
                    };
                    to_err(&BrokerError::Fenced { epoch, by })
                }
                Err(e) => to_err(&e),
            }
        }
        Q::OffsetSync { topic, entries } => match core.sync_offsets(&topic, entries) {
            Ok(()) => A::Ok,
            Err(e) => to_err(&e),
        },
        Q::Promote { topic, partitions, partition } => match cluster {
            None => to_err(&BrokerError::Transport(
                "promote on a standalone broker".into(),
            )),
            Some(v) => {
                let spec = v.spec();
                if !spec.is_replica(&v.self_addr, &topic, partition) {
                    return to_err(&BrokerError::NotOwner {
                        owner: spec.owner(&topic, partition).to_string(),
                    });
                }
                match v.promote(core, &topic, partitions, partition) {
                    Ok(e) => A::Epoch(e),
                    Err(e) => to_err(&e),
                }
            }
        },
        Q::CreateTopic { name, partitions } => match core.create_topic(&name, partitions) {
            Ok(()) => A::Ok,
            Err(e) => to_err(&e),
        },
        Q::EnsureTopic { name, partitions } => match core.ensure_topic(&name, partitions) {
            Ok(()) => A::Ok,
            Err(e) => to_err(&e),
        },
        Q::DeleteTopic { name } => match core.delete_topic(&name) {
            Ok(()) => A::Ok,
            Err(e) => to_err(&e),
        },
        Q::TopicNames => A::Names(core.topic_names()),
        Q::TopicStats { name } => match core.topic_stats(&name) {
            Ok(s) => A::Stats(s.into()),
            Err(e) => to_err(&e),
        },
        Q::Publish { topic, rec } => match cluster {
            None => match core.publish(&topic, rec) {
                Ok((partition, offset)) => A::PubAck { partition, offset },
                Err(e) => to_err(&e),
            },
            Some(v) => match cluster_publish(core, v, &topic, vec![rec]) {
                Ok(acks) => {
                    let (partition, offset) = acks[0];
                    A::PubAck { partition, offset }
                }
                Err(e) => to_err(&e),
            },
        },
        Q::PublishBatch { topic, recs } => {
            let res = match cluster {
                None => core.publish_batch(&topic, recs),
                Some(v) => cluster_publish(core, v, &topic, recs),
            };
            match res {
                Ok(acks) => A::PubBatchAck { acks },
                Err(e) => to_err(&e),
            }
        }
        Q::JoinGroup { group, topic, member, mode } => {
            match core.join_group(&group, &topic, &member, mode) {
                Ok(g) => A::Generation(g),
                Err(e) => to_err(&e),
            }
        }
        Q::LeaveGroup { group, topic, member } => {
            match core.leave_group(&group, &topic, &member) {
                Ok(b) => A::Bool(b),
                Err(e) => to_err(&e),
            }
        }
        Q::Poll { group, topic, member, max } => match core.poll(&group, &topic, &member, max) {
            // Wire responses must own their payloads (one copy at the TCP
            // boundary; the embedded path stays zero-copy).
            Ok(rs) => A::Records(rs.iter().map(|r| (**r).clone()).collect()),
            Err(e) => to_err(&e),
        },
        Q::FetchMany { group, topic, member, max, max_bytes, wait_ms } => {
            // Long-poll: park this connection (its thread — dispatch is
            // also the embedded call path, where blocking is equally
            // correct) until data or deadline. Clamped so a parked fetch
            // cannot delay shutdown indefinitely; clients loop as needed.
            let wait = wait_ms.min(MAX_SERVER_WAIT_MS);
            match core.fetch_many_wait(&group, &topic, &member, max, max_bytes, wait) {
                Ok(mf) => A::Batches {
                    batches: mf
                        .batches
                        .into_iter()
                        .map(|(p, rs)| (p, rs.iter().map(|r| (**r).clone()).collect()))
                        .collect(),
                    positions: mf.positions,
                },
                Err(e) => to_err(&e),
            }
        }
        Q::Commit { group, topic, commits } => match core.commit(&group, &topic, &commits) {
            Ok(()) => {
                // Replicate the group's cursors so consumers resume from
                // their committed offsets on a promoted follower.
                if let Some(rep) = cluster.and_then(|v| v.replicator()) {
                    if let Ok(parts) = core.partition_count(&topic) {
                        rep.enqueue_offsets(&topic, parts);
                    }
                }
                A::Ok
            }
            Err(e) => to_err(&e),
        },
        Q::DeleteRecords { topic, partition, up_to } => {
            match core.delete_records(&topic, partition, up_to) {
                Ok(n) => A::Count(n),
                Err(e) => to_err(&e),
            }
        }
        Q::Offsets { topic } => match core.offsets(&topic) {
            Ok(os) => A::OffsetList(os),
            Err(e) => to_err(&e),
        },
        Q::Positions { group, topic } => match core.positions(&group, &topic) {
            Ok(os) => A::OffsetList(os),
            Err(e) => to_err(&e),
        },
        Q::CrashMember { group, topic, member } => {
            match core.crash_member(&group, &topic, &member) {
                Ok(()) => A::Ok,
                Err(e) => to_err(&e),
            }
        }
        // ---- membership plane (PR 10) --------------------------------
        Q::JoinCluster { member } => match cluster {
            None => to_err(&BrokerError::Transport("join on a standalone broker".into())),
            // Derive and answer — do NOT install. Installing here would
            // route traffic to a joiner whose logs are still empty; the
            // joiner installs (and gossips) only after every pull
            // promoted. See `migrate::join`.
            Some(v) => A::Cluster(v.spec().joined(&member).to_wire()),
        },
        Q::SpecSync { meta } => match cluster {
            None => to_err(&BrokerError::Transport("spec sync on a standalone broker".into())),
            Some(v) => {
                v.install_spec(ClusterSpec::from_wire(&meta));
                // Always answer the spec we now hold: a pusher behind
                // newer news learns it from its own gossip round.
                A::Cluster(v.spec().to_wire())
            }
        },
        Q::FetchLog { topic, partition, from, max } => {
            // Served regardless of ownership (like `Replicate`): the
            // puller reads from a source that may already be fenced —
            // that frozen tail is exactly what the final drain wants.
            match core.partition_count(&topic) {
                Ok(count) if partition < count => {}
                Ok(count) => {
                    return to_err(&BrokerError::BadPartition { topic, partition, count })
                }
                Err(e) => return to_err(&e),
            }
            let hw = match core.high_watermark(&topic, partition) {
                Ok(hw) => hw,
                Err(e) => return to_err(&e),
            };
            let epoch = core.partition_epoch(&topic, partition).unwrap_or(0);
            match core.read_records(&topic, partition, from, max) {
                Ok(rs) => A::LogChunk {
                    hw,
                    epoch,
                    recs: rs.iter().map(|r| (**r).clone()).collect(),
                },
                Err(e) => to_err(&e),
            }
        }
        Q::FetchOffsets { topic } => A::OffsetDump(core.group_offset_entries(&topic)),
        Q::Fence { topic, partitions, partition, by } => match cluster {
            None => to_err(&BrokerError::Transport("fence on a standalone broker".into())),
            Some(v) => {
                // Freeze the partition: bump the epoch past everything
                // this broker ever issued and record the deposal, so
                // `leads` flips false and producers get `NotOwner { by }`.
                if let Err(e) = core.ensure_topic(&topic, partitions.max(1)) {
                    return to_err(&e);
                }
                let epoch = match core.partition_epoch(&topic, partition) {
                    Ok(e) => e + 1,
                    Err(e) => return to_err(&e),
                };
                if let Err(e) = core.set_partition_epoch(&topic, partition, epoch) {
                    return to_err(&e);
                }
                v.ha().depose(&topic, partition, epoch, &by);
                A::Epoch(epoch)
            }
        },
        Q::MigratePartition { topic, partitions, partition, from } => match cluster {
            None => to_err(&BrokerError::Transport("migrate on a standalone broker".into())),
            Some(v) => match migrate::pull_partition(core, v, &topic, partitions, partition, &from)
            {
                Ok(epoch) => A::Epoch(epoch),
                Err(e) => to_err(&e),
            },
        },
        Q::DrainMember { member } => match cluster {
            None => to_err(&BrokerError::Transport("drain on a standalone broker".into())),
            Some(v) => {
                // An empty member means "drain yourself"; a mismatched one
                // is a mis-routed CLI call, refused before any handoff.
                if !member.is_empty() && member != v.self_addr {
                    return to_err(&BrokerError::Transport(format!(
                        "drain addressed to {member} but this broker is {}",
                        v.self_addr
                    )));
                }
                match migrate::drain(core, v) {
                    Ok(moved) => A::Count(moved),
                    Err(e) => to_err(&e),
                }
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::group::AssignmentMode;
    use crate::broker::record::ProducerRecord;
    use crate::util::wire::{recv_msg, send_msg};

    #[test]
    fn dispatch_covers_success_and_error() {
        let core = BrokerCore::new();
        assert_eq!(
            dispatch(&core, Request::CreateTopic { name: "t".into(), partitions: 1 }),
            Response::Ok
        );
        assert!(matches!(
            dispatch(&core, Request::CreateTopic { name: "t".into(), partitions: 1 }),
            Response::Err { code: 2, .. }
        ));
        assert!(matches!(
            dispatch(
                &core,
                Request::Publish { topic: "t".into(), rec: ProducerRecord::new(vec![1]) }
            ),
            Response::PubAck { .. }
        ));
        assert!(matches!(
            dispatch(
                &core,
                Request::JoinGroup {
                    group: "g".into(),
                    topic: "t".into(),
                    member: "m".into(),
                    mode: AssignmentMode::Shared,
                }
            ),
            Response::Generation(_)
        ));
        match dispatch(
            &core,
            Request::Poll { group: "g".into(), topic: "t".into(), member: "m".into(), max: 10 },
        ) {
            Response::Records(rs) => assert_eq!(rs.len(), 1),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn dispatch_fetch_many_returns_batches_and_positions() {
        let core = BrokerCore::new();
        dispatch(&core, Request::CreateTopic { name: "t".into(), partitions: 2 });
        for i in 0..6u8 {
            dispatch(
                &core,
                Request::Publish { topic: "t".into(), rec: ProducerRecord::new(vec![i]) },
            );
        }
        dispatch(
            &core,
            Request::JoinGroup {
                group: "g".into(),
                topic: "t".into(),
                member: "m".into(),
                mode: AssignmentMode::Shared,
            },
        );
        match dispatch(
            &core,
            Request::FetchMany {
                group: "g".into(),
                topic: "t".into(),
                member: "m".into(),
                max: usize::MAX,
                max_bytes: usize::MAX,
                wait_ms: 0,
            },
        ) {
            Response::Batches { batches, positions } => {
                assert_eq!(batches.iter().map(|(_, rs)| rs.len()).sum::<usize>(), 6);
                assert_eq!(positions.len(), 2);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn cluster_dispatch_enforces_ownership() {
        use crate::broker::cluster::{ClusterSpec, ClusterView};
        let spec = ClusterSpec::new(["10.0.0.1:9092", "10.0.0.2:9092"]);
        let me = spec.members()[0].clone();
        let other = spec.members()[1].clone();
        let view = ClusterView::new(spec.clone(), me.clone());
        let core = BrokerCore::new();
        core.create_topic("t", 8).unwrap();
        let owned = view.owned_partitions("t", 8);
        let foreign: Vec<usize> = (0..8).filter(|p| !owned.contains(p)).collect();
        assert!(!owned.is_empty() && !foreign.is_empty(), "degenerate placement");
        // Owned partition: the publish lands.
        match dispatch_at(
            &core,
            Some(&view),
            Request::PublishTo {
                topic: "t".into(),
                partition: owned[0],
                recs: vec![ProducerRecord::new(vec![1])],
                acks: crate::broker::protocol::ACKS_LEADER,
            },
        ) {
            Response::PubBatchAck { acks } => assert_eq!(acks, vec![(owned[0], 0)]),
            otherwise => panic!("unexpected {otherwise:?}"),
        }
        // Foreign partition: NotOwner carrying the bare owner address.
        match dispatch_at(
            &core,
            Some(&view),
            Request::PublishTo {
                topic: "t".into(),
                partition: foreign[0],
                recs: vec![ProducerRecord::new(vec![2])],
                acks: crate::broker::protocol::ACKS_LEADER,
            },
        ) {
            Response::Err { code: 8, msg } => assert_eq!(msg, other),
            otherwise => panic!("unexpected {otherwise:?}"),
        }
        // ClusterMeta answers the member list; standalone brokers answer
        // an empty one.
        match dispatch_at(&core, Some(&view), Request::ClusterMeta) {
            Response::Cluster(meta) => assert_eq!(meta.members, spec.members()),
            otherwise => panic!("unexpected {otherwise:?}"),
        }
        match dispatch_at(&core, None, Request::ClusterMeta) {
            Response::Cluster(meta) => assert!(meta.members.is_empty()),
            otherwise => panic!("unexpected {otherwise:?}"),
        }
    }

    #[test]
    fn cluster_dispatch_keeps_legacy_publishes_on_own_shard() {
        use crate::broker::cluster::{ClusterSpec, ClusterView};
        let spec = ClusterSpec::new(["10.0.0.1:9092", "10.0.0.2:9092"]);
        let me = spec.members()[0].clone();
        let view = ClusterView::new(spec, me);
        let core = BrokerCore::new();
        core.create_topic("t", 8).unwrap();
        let owned = view.owned_partitions("t", 8);
        for i in 0..12u8 {
            match dispatch_at(
                &core,
                Some(&view),
                Request::Publish { topic: "t".into(), rec: ProducerRecord::new(vec![i]) },
            ) {
                Response::PubAck { partition, .. } => {
                    assert!(owned.contains(&partition), "landed on foreign partition {partition}");
                }
                otherwise => panic!("unexpected {otherwise:?}"),
            }
        }
        // A keyed record whose hash lands on a foreign partition redirects.
        let key: Vec<u8> = (0u8..64)
            .map(|i| vec![i])
            .find(|k| {
                !owned.contains(&crate::broker::topic::key_partition(k, 8))
            })
            .expect("some key must hash to a foreign partition");
        match dispatch_at(
            &core,
            Some(&view),
            Request::Publish {
                topic: "t".into(),
                rec: ProducerRecord::with_key(key, vec![0]),
            },
        ) {
            Response::Err { code: 8, .. } => {}
            otherwise => panic!("unexpected {otherwise:?}"),
        }
    }

    #[test]
    fn server_starts_and_shuts_down() {
        let core = BrokerCore::new();
        let server = BrokerServer::start(core, "127.0.0.1:0").unwrap();
        let addr = server.addr;
        // Raw socket request.
        let mut sock = TcpStream::connect(addr).unwrap();
        send_msg(&mut sock, &Request::Ping).unwrap();
        let resp: Option<Response> = recv_msg(&mut sock).unwrap();
        assert_eq!(resp, Some(Response::Pong));
        drop(sock);
        server.shutdown();
    }

    #[test]
    fn shutdown_terminates_connection_threads() {
        // Regression: `handle_conn` used to block in `recv_msg` until the
        // peer closed, leaking one live thread per still-open client after
        // shutdown. Connection threads hold an `Arc<BrokerCore>`, so the
        // strong count observes their exit.
        let core = BrokerCore::new();
        let server = BrokerServer::start(Arc::clone(&core), "127.0.0.1:0").unwrap();
        let mut sock = TcpStream::connect(server.addr).unwrap();
        send_msg(&mut sock, &Request::Ping).unwrap();
        let resp: Option<Response> = recv_msg(&mut sock).unwrap();
        assert_eq!(resp, Some(Response::Pong));
        // Keep `sock` open across shutdown: the old code would hang here.
        server.shutdown();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while Arc::strong_count(&core) > 1 {
            assert!(
                std::time::Instant::now() < deadline,
                "connection thread still alive {} refs after shutdown",
                Arc::strong_count(&core)
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        drop(sock);
    }
}
