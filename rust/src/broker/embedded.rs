//! `BrokerCore`: the broker's state machine, shared by the embedded client
//! and the TCP server (which is just `BrokerCore` behind sockets).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use thiserror::Error;

use super::group::{AssignmentMode, GroupState};
use super::record::{ProducerRecord, Record};
use super::topic::Topic;

/// Broker-level errors (mirrored over the wire by `protocol::ErrorCode`).
#[derive(Debug, Error, Clone, PartialEq, Eq)]
pub enum BrokerError {
    #[error("unknown topic {0:?}")]
    UnknownTopic(String),
    #[error("topic {0:?} already exists")]
    TopicExists(String),
    #[error("partition {partition} out of range for topic {topic:?} ({count} partitions)")]
    BadPartition { topic: String, partition: usize, count: usize },
    #[error("unknown group {0:?}")]
    UnknownGroup(String),
    #[error("member {member:?} not in group {group:?}")]
    UnknownMember { group: String, member: String },
    #[error("transport: {0}")]
    Transport(String),
}

pub type Result<T> = std::result::Result<T, BrokerError>;

/// Snapshot of a topic's per-partition state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopicStats {
    pub partitions: usize,
    pub records: usize,
    pub bytes: usize,
    pub high_watermarks: Vec<u64>,
    pub start_offsets: Vec<u64>,
}

/// The broker state machine: topics + consumer groups.
///
/// Locking: the topic map is an `RwLock` (reads dominate); each partition
/// log has its own `Mutex` inside [`Topic`]; group state is a `Mutex` per
/// (group, topic) entry.
#[derive(Default)]
pub struct BrokerCore {
    topics: RwLock<HashMap<String, Arc<Topic>>>,
    groups: Mutex<HashMap<(String, String), Arc<Mutex<GroupState>>>>,
}

impl BrokerCore {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    // ---- admin ---------------------------------------------------------

    /// Create a topic with `partitions` partitions.
    pub fn create_topic(&self, name: &str, partitions: usize) -> Result<()> {
        let mut topics = self.topics.write().unwrap();
        if topics.contains_key(name) {
            return Err(BrokerError::TopicExists(name.into()));
        }
        topics.insert(name.to_string(), Arc::new(Topic::new(name, partitions)));
        Ok(())
    }

    /// Create if absent (used by ODS lazy publisher/consumer init).
    pub fn ensure_topic(&self, name: &str, partitions: usize) {
        let mut topics = self.topics.write().unwrap();
        topics
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Topic::new(name, partitions)));
    }

    /// Drop a topic and all group state referring to it.
    pub fn delete_topic(&self, name: &str) -> Result<()> {
        let removed = self.topics.write().unwrap().remove(name);
        if removed.is_none() {
            return Err(BrokerError::UnknownTopic(name.into()));
        }
        self.groups.lock().unwrap().retain(|(_, t), _| t != name);
        Ok(())
    }

    pub fn topic_names(&self) -> Vec<String> {
        let mut names: Vec<_> = self.topics.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    fn topic(&self, name: &str) -> Result<Arc<Topic>> {
        self.topics
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| BrokerError::UnknownTopic(name.into()))
    }

    /// Per-topic stats snapshot.
    pub fn topic_stats(&self, name: &str) -> Result<TopicStats> {
        let t = self.topic(name)?;
        let n = t.partition_count();
        Ok(TopicStats {
            partitions: n,
            records: t.total_records(),
            bytes: t.total_bytes(),
            high_watermarks: (0..n).map(|p| t.high_watermark(p)).collect(),
            start_offsets: (0..n).map(|p| t.start_offset(p)).collect(),
        })
    }

    // ---- produce -------------------------------------------------------

    /// Publish one record; returns (partition, offset).
    pub fn publish(&self, topic: &str, rec: ProducerRecord) -> Result<(usize, u64)> {
        Ok(self.topic(topic)?.publish(rec))
    }

    /// Publish a batch (one partitioner decision per record, like Kafka's
    /// per-record send the paper describes for list publishes).
    pub fn publish_batch(&self, topic: &str, recs: Vec<ProducerRecord>) -> Result<Vec<(usize, u64)>> {
        let t = self.topic(topic)?;
        Ok(recs.into_iter().map(|r| t.publish(r)).collect())
    }

    // ---- consume -------------------------------------------------------

    fn group_entry(&self, group: &str, topic: &str, mode: AssignmentMode) -> Arc<Mutex<GroupState>> {
        let mut groups = self.groups.lock().unwrap();
        groups
            .entry((group.to_string(), topic.to_string()))
            .or_insert_with(|| Arc::new(Mutex::new(GroupState::new(mode))))
            .clone()
    }

    /// Join `member` to `group` for `topic`; returns the generation.
    pub fn join_group(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        mode: AssignmentMode,
    ) -> Result<u64> {
        self.topic(topic)?; // must exist
        let entry = self.group_entry(group, topic, mode);
        let mut st = entry.lock().unwrap();
        Ok(st.join(member))
    }

    /// Remove `member`; triggers rebalance (Partitioned) and rewinds the
    /// member's uncommitted claims to the commit point (Shared) so another
    /// member redelivers them — at-least-once on crash.
    pub fn leave_group(&self, group: &str, topic: &str, member: &str) -> Result<bool> {
        let entry = {
            let groups = self.groups.lock().unwrap();
            groups
                .get(&(group.to_string(), topic.to_string()))
                .cloned()
                .ok_or_else(|| BrokerError::UnknownGroup(group.into()))?
        };
        let mut st = entry.lock().unwrap();
        Ok(st.leave(member))
    }

    /// Poll up to `max` records for `member` of `group` on `topic`.
    ///
    /// Shared mode: claims from every partition's shared cursor (greedy).
    /// Partitioned mode: claims only from the member's assigned partitions.
    pub fn poll(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
    ) -> Result<Vec<Arc<Record>>> {
        let t = self.topic(topic)?;
        let entry = {
            let groups = self.groups.lock().unwrap();
            groups
                .get(&(group.to_string(), topic.to_string()))
                .cloned()
                .ok_or_else(|| BrokerError::UnknownGroup(group.into()))?
        };
        let mut st = entry.lock().unwrap();
        if !st.members().iter().any(|m| m == member) {
            return Err(BrokerError::UnknownMember { group: group.into(), member: member.into() });
        }
        let parts = st.assignment(member, t.partition_count());
        let mut out = Vec::new();
        let mut budget = max;
        for p in parts {
            if budget == 0 {
                break;
            }
            let (from, to) = st.claim(p, t.start_offset(p), t.high_watermark(p), budget);
            if to > from {
                let recs = t.fetch(p, from, (to - from) as usize);
                budget -= recs.len().min(budget);
                out.extend(recs);
            }
        }
        Ok(out)
    }

    /// Commit processed offsets: `up_to` per partition.
    pub fn commit(&self, group: &str, topic: &str, commits: &[(usize, u64)]) -> Result<()> {
        let entry = {
            let groups = self.groups.lock().unwrap();
            groups
                .get(&(group.to_string(), topic.to_string()))
                .cloned()
                .ok_or_else(|| BrokerError::UnknownGroup(group.into()))?
        };
        let mut st = entry.lock().unwrap();
        for &(p, up_to) in commits {
            st.commit(p, up_to);
        }
        Ok(())
    }

    /// Delete records below `up_to` in one partition (exactly-once: the ODS
    /// consumer deletes what it processed, as the paper does via Kafka's
    /// AdminClient).
    pub fn delete_records(&self, topic: &str, partition: usize, up_to: u64) -> Result<usize> {
        let t = self.topic(topic)?;
        if partition >= t.partition_count() {
            return Err(BrokerError::BadPartition {
                topic: topic.into(),
                partition,
                count: t.partition_count(),
            });
        }
        Ok(t.delete_records(partition, up_to))
    }

    /// (claim position, committed offset) per partition for a group —
    /// the safe bounds for commit/delete after a poll (deleting up to the
    /// high watermark instead would destroy records published after the
    /// claim).
    pub fn positions(&self, group: &str, topic: &str) -> Result<Vec<(u64, u64)>> {
        let t = self.topic(topic)?;
        let entry = {
            let groups = self.groups.lock().unwrap();
            groups
                .get(&(group.to_string(), topic.to_string()))
                .cloned()
                .ok_or_else(|| BrokerError::UnknownGroup(group.into()))?
        };
        let st = entry.lock().unwrap();
        Ok((0..t.partition_count()).map(|p| (st.position(p), st.committed(p))).collect())
    }

    /// (start_offset, high_watermark) per partition.
    pub fn offsets(&self, topic: &str) -> Result<Vec<(u64, u64)>> {
        let t = self.topic(topic)?;
        Ok((0..t.partition_count()).map(|p| (t.start_offset(p), t.high_watermark(p))).collect())
    }

    /// Simulate a consumer crash: rewind the group's claims to the last
    /// commit so records get redelivered (failure-injection tests).
    pub fn crash_member(&self, group: &str, topic: &str, member: &str) -> Result<()> {
        let t = self.topic(topic)?;
        let entry = {
            let groups = self.groups.lock().unwrap();
            groups
                .get(&(group.to_string(), topic.to_string()))
                .cloned()
                .ok_or_else(|| BrokerError::UnknownGroup(group.into()))?
        };
        let mut st = entry.lock().unwrap();
        for p in 0..t.partition_count() {
            st.rewind_to_committed(p);
        }
        st.leave(member);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(v: u8) -> ProducerRecord {
        ProducerRecord::new(vec![v])
    }

    #[test]
    fn create_publish_poll_roundtrip() {
        let b = BrokerCore::new();
        b.create_topic("t", 2).unwrap();
        for i in 0..6 {
            b.publish("t", rec(i)).unwrap();
        }
        b.join_group("g", "t", "m1", AssignmentMode::Shared).unwrap();
        let got = b.poll("g", "t", "m1", usize::MAX).unwrap();
        assert_eq!(got.len(), 6);
        // Second poll: nothing new.
        assert!(b.poll("g", "t", "m1", usize::MAX).unwrap().is_empty());
    }

    #[test]
    fn duplicate_topic_rejected() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        assert_eq!(b.create_topic("t", 1), Err(BrokerError::TopicExists("t".into())));
        b.ensure_topic("t", 1); // idempotent, no error
    }

    #[test]
    fn two_groups_both_see_all_records() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        for i in 0..4 {
            b.publish("t", rec(i)).unwrap();
        }
        b.join_group("g1", "t", "a", AssignmentMode::Shared).unwrap();
        b.join_group("g2", "t", "b", AssignmentMode::Shared).unwrap();
        assert_eq!(b.poll("g1", "t", "a", usize::MAX).unwrap().len(), 4);
        assert_eq!(b.poll("g2", "t", "b", usize::MAX).unwrap().len(), 4);
    }

    #[test]
    fn same_group_shares_records_without_duplication() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        b.join_group("g", "t", "m1", AssignmentMode::Shared).unwrap();
        b.join_group("g", "t", "m2", AssignmentMode::Shared).unwrap();
        for i in 0..10 {
            b.publish("t", rec(i)).unwrap();
        }
        let a = b.poll("g", "t", "m1", usize::MAX).unwrap();
        let c = b.poll("g", "t", "m2", usize::MAX).unwrap();
        assert_eq!(a.len() + c.len(), 10);
        // Greedy: the first poller takes everything available.
        assert_eq!(a.len(), 10);
    }

    #[test]
    fn partitioned_mode_respects_assignment() {
        let b = BrokerCore::new();
        b.create_topic("t", 4).unwrap();
        b.join_group("g", "t", "m1", AssignmentMode::Partitioned).unwrap();
        b.join_group("g", "t", "m2", AssignmentMode::Partitioned).unwrap();
        for i in 0..40 {
            b.publish("t", rec(i)).unwrap();
        }
        let a = b.poll("g", "t", "m1", usize::MAX).unwrap();
        let c = b.poll("g", "t", "m2", usize::MAX).unwrap();
        assert_eq!(a.len() + c.len(), 40);
        assert_eq!(a.len(), 20);
        assert_eq!(c.len(), 20);
    }

    #[test]
    fn delete_records_supports_exactly_once() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        for i in 0..5 {
            b.publish("t", rec(i)).unwrap();
        }
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let got = b.poll("g", "t", "m", usize::MAX).unwrap();
        let max_off = got.iter().map(|r| r.offset).max().unwrap();
        b.delete_records("t", 0, max_off + 1).unwrap();
        let stats = b.topic_stats("t").unwrap();
        assert_eq!(stats.records, 0);
        // A late-joining group cannot see deleted records.
        b.join_group("g2", "t", "x", AssignmentMode::Shared).unwrap();
        assert!(b.poll("g2", "t", "x", usize::MAX).unwrap().is_empty());
    }

    #[test]
    fn crash_member_triggers_redelivery_of_uncommitted() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        b.join_group("g", "t", "m1", AssignmentMode::Shared).unwrap();
        b.join_group("g", "t", "m2", AssignmentMode::Shared).unwrap();
        for i in 0..8 {
            b.publish("t", rec(i)).unwrap();
        }
        let got = b.poll("g", "t", "m1", usize::MAX).unwrap();
        assert_eq!(got.len(), 8);
        // m1 processed+committed only the first 3, then crashed.
        b.commit("g", "t", &[(0, 3)]).unwrap();
        b.crash_member("g", "t", "m1").unwrap();
        let redelivered = b.poll("g", "t", "m2", usize::MAX).unwrap();
        assert_eq!(redelivered.iter().map(|r| r.offset).collect::<Vec<_>>(), vec![3, 4, 5, 6, 7]);
    }

    #[test]
    fn unknown_entities_error() {
        let b = BrokerCore::new();
        assert!(matches!(b.publish("nope", rec(0)), Err(BrokerError::UnknownTopic(_))));
        b.create_topic("t", 1).unwrap();
        assert!(matches!(
            b.poll("g", "t", "m", 1),
            Err(BrokerError::UnknownGroup(_))
        ));
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        assert!(matches!(
            b.poll("g", "t", "other", 1),
            Err(BrokerError::UnknownMember { .. })
        ));
        assert!(matches!(
            b.delete_records("t", 9, 1),
            Err(BrokerError::BadPartition { .. })
        ));
    }

    #[test]
    fn delete_topic_clears_group_state() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        b.delete_topic("t").unwrap();
        assert!(b.topic_names().is_empty());
        assert!(matches!(b.poll("g", "t", "m", 1), Err(BrokerError::UnknownTopic(_))));
    }
}
