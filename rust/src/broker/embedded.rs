//! `BrokerCore`: the broker's state machine, shared by the embedded client
//! and the TCP server (which is just `BrokerCore` behind sockets).
//!
//! Storage: a [`BrokerConfig`] selects [`StorageMode::Memory`] (default,
//! the unchanged zero-copy broker) or [`StorageMode::Disk`] per topic.
//! [`BrokerCore::with_config`] recovers every durable topic found under
//! the configured data dirs at boot — records, watermarks and consumer-
//! group commit points all survive a restart.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use thiserror::Error;

use super::group::{AssignmentMode, GroupState};
use super::record::{now_ms, ProducerRecord, Record};
use super::storage::{
    is_session_scoped_topic, looks_like_topic_dir, topic_dir_name, topic_from_dir_name,
    BrokerConfig, OffsetEntry, OffsetStore, StorageMode,
};
use super::topic::Topic;
use crate::util::trace;

/// Broker-level errors (mirrored over the wire by `protocol::ErrorCode`).
#[derive(Debug, Error, Clone, PartialEq, Eq)]
pub enum BrokerError {
    #[error("unknown topic {0:?}")]
    UnknownTopic(String),
    #[error("topic {0:?} already exists")]
    TopicExists(String),
    #[error("partition {partition} out of range for topic {topic:?} ({count} partitions)")]
    BadPartition { topic: String, partition: usize, count: usize },
    #[error("unknown group {0:?}")]
    UnknownGroup(String),
    #[error("member {member:?} not in group {group:?}")]
    UnknownMember { group: String, member: String },
    #[error("storage: {0}")]
    Storage(String),
    #[error("transport: {0}")]
    Transport(String),
    /// Cluster routing: this broker does not own the addressed partition;
    /// retry at `owner` (wire code 8 — the message carries only the owner
    /// address so clients can follow the redirect).
    #[error("not the partition owner; retry at {owner}")]
    NotOwner { owner: String },
    /// Replication fencing: the caller's leadership epoch is stale — a
    /// newer leader (elected at `epoch`, enforced by `by`) exists for the
    /// partition. Deposed leaders stop accepting writes on sight of this
    /// (wire code 9; payload `{epoch}@{by}`).
    #[error("fenced at epoch {epoch} by {by}")]
    Fenced { epoch: u64, by: String },
}

pub type Result<T> = std::result::Result<T, BrokerError>;

/// Snapshot of a topic's per-partition state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopicStats {
    pub partitions: usize,
    pub records: usize,
    pub bytes: usize,
    pub high_watermarks: Vec<u64>,
    pub start_offsets: Vec<u64>,
    /// Segment-file bytes across partitions (0 for memory topics).
    pub bytes_on_disk: u64,
    /// Segment count across partitions (0 for memory topics).
    pub segments: usize,
    /// Records replayed from disk when the topic was opened.
    pub recovered_records: u64,
}

/// Result of one multi-partition fetch ([`BrokerCore::fetch_many`]): the
/// per-partition record batches plus the group's cursor positions, taken
/// under the same group lock so callers get a consistent commit bound
/// without a second round trip.
#[derive(Debug, Clone, Default)]
pub struct MultiFetch {
    /// `(partition, records)` — only partitions that yielded records.
    pub batches: Vec<(usize, Vec<Arc<Record>>)>,
    /// `(claim position, committed offset)` for **every** partition,
    /// observed after the claims above (the safe commit/delete bounds).
    pub positions: Vec<(u64, u64)>,
}

impl MultiFetch {
    /// Total records across all batches.
    pub fn record_count(&self) -> usize {
        self.batches.iter().map(|(_, rs)| rs.len()).sum()
    }

    /// Total payload bytes across all batches.
    pub fn byte_count(&self) -> usize {
        self.batches.iter().flat_map(|(_, rs)| rs.iter()).map(|r| r.payload_len()).sum()
    }
}

/// Upper bound on one blocking-wait horizon (~1 year in ms): callers may
/// pass `u64::MAX` as "wait forever", and `Instant + Duration` must not
/// overflow-panic computing the deadline.
pub const MAX_WAIT_HORIZON_MS: u64 = 1000 * 60 * 60 * 24 * 365;

/// The broker state machine: topics + consumer groups.
///
/// Locking: the topic map is an `RwLock` (reads dominate); each partition
/// log has its own `Mutex` inside [`Topic`]; group state is a `Mutex` per
/// (group, topic) entry.
#[derive(Default)]
pub struct BrokerCore {
    topics: RwLock<HashMap<String, Arc<Topic>>>,
    groups: Mutex<HashMap<(String, String), Arc<Mutex<GroupState>>>>,
    /// Per-topic storage selection (default: everything in memory).
    config: BrokerConfig,
    /// Consumer-offset journals, one per durable topic.
    offsets: Mutex<HashMap<String, Arc<Mutex<OffsetStore>>>>,
    /// Serialises topic creation/recovery: `Topic::open` scans and may
    /// truncate segment files, so two racing `ensure_topic` calls must
    /// never both run disk recovery for the same topic (the loser could
    /// truncate a file the winner is already appending to).
    open_lock: Mutex<()>,
}

impl BrokerCore {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Broker with explicit storage configuration. Scans the configured
    /// data dirs and recovers every durable topic found there: records
    /// (torn tails truncated), watermarks, deletion points, and consumer-
    /// group cursors (groups resume from their committed offsets; claims
    /// made by consumers that died with the old process are redelivered).
    pub fn with_config(config: BrokerConfig) -> Result<Arc<Self>> {
        let core = Arc::new(Self { config, ..Self::default() });
        core.recover()?;
        Ok(core)
    }

    /// The active storage configuration.
    pub fn config(&self) -> &BrokerConfig {
        &self.config
    }

    /// Boot-time recovery: re-open every durable topic already on disk.
    fn recover(&self) -> Result<()> {
        let mut dirs: Vec<&std::path::PathBuf> = Vec::new();
        let mut modes: Vec<&StorageMode> = vec![&self.config.default_mode];
        modes.extend(self.config.topic_modes.iter().map(|(_, m)| m));
        for mode in modes {
            if let StorageMode::Disk { data_dir, .. } = mode {
                if !dirs.contains(&data_dir) {
                    dirs.push(data_dir);
                }
            }
        }
        for dir in dirs {
            let Ok(entries) = std::fs::read_dir(dir) else {
                continue; // nothing persisted yet
            };
            for entry in entries.flatten() {
                if !entry.path().is_dir() {
                    continue;
                }
                let Some(topic) =
                    entry.file_name().to_str().and_then(topic_from_dir_name)
                else {
                    continue; // undecodable name: foreign directory
                };
                // Only dirs with broker structure (a p<N> partition dir or
                // an offsets journal) are topics — a foreign directory that
                // happens to live in the data dir must be left alone, not
                // registered as a phantom topic.
                if !looks_like_topic_dir(&entry.path()) {
                    log::warn!("ignoring non-topic directory {:?} in data dir", entry.path());
                    continue;
                }
                match self.config.mode_for(&topic) {
                    StorageMode::Disk { data_dir, .. } if data_dir == dir => {}
                    _ => continue, // configured elsewhere (or memory): skip
                }
                if self.config.reap_session_scoped && is_session_scoped_topic(&topic) {
                    // Anonymous-stream topics are meaningless across
                    // registry sessions (ids restart at 0): reap them so a
                    // new session's stream cannot bind a previous
                    // session's records. Aliased streams (`dstream-a-…`)
                    // are the durable-across-restarts namespace. Gated by
                    // config: only deployments that own the dstream
                    // namespace opt in.
                    log::info!("reaping stale session-scoped topic dir {:?}", entry.path());
                    if let Err(e) = std::fs::remove_dir_all(entry.path()) {
                        log::warn!("could not reap {:?}: {e}", entry.path());
                    }
                    continue;
                }
                self.open_topic(&topic, 1)?;
            }
        }
        Ok(())
    }

    /// Open (or create) `name` under its configured storage mode and
    /// register it, replaying the consumer-offset journal for durable
    /// topics. Caller must hold no topic lock. Returns `(topic, created)`
    /// — `created == false` when the topic already existed (including
    /// losing a creation race), so `create_topic` can keep its exactly-one-
    /// winner `TopicExists` guarantee.
    fn open_topic(&self, name: &str, partitions: usize) -> Result<(Arc<Topic>, bool)> {
        if let Some(t) = self.topics.read().unwrap().get(name) {
            return Ok((Arc::clone(t), false));
        }
        // Creation lock: disk recovery (`Topic::open`) scans and may
        // truncate segment files in place — exactly one thread may run it
        // per topic, and never concurrently with a winner already
        // appending.
        let _creating = self.open_lock.lock().unwrap();
        if let Some(t) = self.topics.read().unwrap().get(name) {
            return Ok((Arc::clone(t), false)); // created while we waited
        }
        let mode = self.config.mode_for(name);
        let topic = Arc::new(Topic::open(name, partitions, mode).map_err(|e| {
            BrokerError::Storage(format!("open topic {name:?}: {e}"))
        })?);
        self.topics.write().unwrap().insert(name.to_string(), Arc::clone(&topic));
        if let StorageMode::Disk { data_dir, .. } = mode {
            let path = data_dir.join(topic_dir_name(name)).join("offsets.log");
            let (store, entries) = OffsetStore::open(&path)
                .map_err(|e| BrokerError::Storage(format!("open offsets for {name:?}: {e}")))?;
            self.offsets.lock().unwrap().insert(name.to_string(), Arc::new(Mutex::new(store)));
            // Replay cursors: resume from the commit point (claims made by
            // the dead process's consumers are redelivered). Clamped to the
            // recovered high watermark — a journal ahead of the record log
            // (degraded disk, torn segment tail behind an intact journal)
            // must not make the group skip records published after the
            // restart. Entries for partitions beyond the recovered layout
            // are ignored.
            let mut groups = self.groups.lock().unwrap();
            for e in entries {
                let p = e.partition as usize;
                if p >= topic.partition_count() {
                    continue;
                }
                let hw = topic.high_watermark(p);
                let entry = groups
                    .entry((e.group.clone(), name.to_string()))
                    .or_insert_with(|| Arc::new(Mutex::new(GroupState::new(e.mode))));
                let mut st = entry.lock().unwrap();
                let cur = st.cursor_mut(p);
                cur.committed = e.committed.min(hw);
                cur.position = cur.committed;
            }
        }
        Ok((topic, true))
    }

    /// Offset journal for a durable topic (`None` for memory topics).
    fn offset_store(&self, topic: &str) -> Option<Arc<Mutex<OffsetStore>>> {
        self.offsets.lock().unwrap().get(topic).cloned()
    }

    /// Journal the cursors of `partitions` for one (group, topic).
    fn persist_cursors(&self, group: &str, topic: &str, st: &GroupState, partitions: &[usize]) {
        if partitions.is_empty() {
            return;
        }
        let Some(store) = self.offset_store(topic) else {
            return;
        };
        let mut store = store.lock().unwrap();
        for &p in partitions {
            store.note(&OffsetEntry {
                group: group.to_string(),
                mode: st.mode,
                partition: p as u64,
                position: st.position(p),
                committed: st.committed(p),
            });
        }
    }

    // ---- admin ---------------------------------------------------------

    /// Create a topic with `partitions` partitions (durable when the
    /// broker config says so — see [`BrokerConfig::mode_for`]). Exactly
    /// one concurrent creator wins; the rest get [`BrokerError::TopicExists`].
    pub fn create_topic(&self, name: &str, partitions: usize) -> Result<()> {
        let (_, created) = self.open_topic(name, partitions)?;
        if created {
            Ok(())
        } else {
            Err(BrokerError::TopicExists(name.into()))
        }
    }

    /// Create if absent (used by ODS lazy publisher/consumer init).
    pub fn ensure_topic(&self, name: &str, partitions: usize) -> Result<()> {
        self.open_topic(name, partitions)?;
        Ok(())
    }

    /// Drop a topic and all group state referring to it. Durable topics
    /// also lose their on-disk segments and offset journal.
    pub fn delete_topic(&self, name: &str) -> Result<()> {
        // Serialise against lazy `ensure_topic`: without this, a racing
        // creator could re-open the topic from disk between our map remove
        // and the dir removal, leaving a registered topic whose segment
        // files we just deleted.
        let _creating = self.open_lock.lock().unwrap();
        let removed = self.topics.write().unwrap().remove(name);
        let Some(topic) = removed else {
            return Err(BrokerError::UnknownTopic(name.into()));
        };
        self.groups.lock().unwrap().retain(|(_, t), _| t != name);
        self.offsets.lock().unwrap().remove(name);
        if let StorageMode::Disk { data_dir, .. } = self.config.mode_for(name) {
            let dir = data_dir.join(topic_dir_name(name));
            if let Err(e) = std::fs::remove_dir_all(&dir) {
                if e.kind() != std::io::ErrorKind::NotFound {
                    log::warn!("delete_topic {name:?}: could not remove {dir:?}: {e}");
                }
            }
        }
        // Wake parked long-poll fetches so they re-check and surface
        // `UnknownTopic` instead of sleeping out their deadline.
        topic.notify_publish();
        Ok(())
    }

    pub fn topic_names(&self) -> Vec<String> {
        let mut names: Vec<_> = self.topics.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    fn topic(&self, name: &str) -> Result<Arc<Topic>> {
        self.topics
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| BrokerError::UnknownTopic(name.into()))
    }

    /// Per-topic stats snapshot.
    pub fn topic_stats(&self, name: &str) -> Result<TopicStats> {
        let t = self.topic(name)?;
        let n = t.partition_count();
        Ok(TopicStats {
            partitions: n,
            records: t.total_records(),
            bytes: t.total_bytes(),
            high_watermarks: (0..n).map(|p| t.high_watermark(p)).collect(),
            start_offsets: (0..n).map(|p| t.start_offset(p)).collect(),
            bytes_on_disk: t.total_bytes_on_disk(),
            segments: t.total_segments(),
            recovered_records: t.total_recovered(),
        })
    }

    // ---- produce -------------------------------------------------------

    /// Publish one record; returns (partition, offset).
    pub fn publish(&self, topic: &str, rec: ProducerRecord) -> Result<(usize, u64)> {
        Ok(self.topic(topic)?.publish(rec))
    }

    /// Publish a batch: one partitioner decision per record (like Kafka's
    /// per-record send the paper describes for list publishes) but records
    /// are grouped so each partition lock is taken once per batch.
    pub fn publish_batch(
        &self,
        topic: &str,
        recs: Vec<ProducerRecord>,
    ) -> Result<Vec<(usize, u64)>> {
        Ok(self.topic(topic)?.publish_many(recs))
    }

    /// Publish a batch to one **explicit** partition (the cluster data
    /// plane: the client picked the partition from the shared placement
    /// function; the owning broker just appends). One lock acquisition and
    /// one wakeup per batch; returns the assigned offsets in order.
    pub fn publish_to(
        &self,
        topic: &str,
        partition: usize,
        recs: Vec<ProducerRecord>,
    ) -> Result<Vec<u64>> {
        let t = self.topic(topic)?;
        if partition >= t.partition_count() {
            return Err(BrokerError::BadPartition {
                topic: topic.into(),
                partition,
                count: t.partition_count(),
            });
        }
        Ok(t.publish_many_to(partition, recs))
    }

    /// Partition count of a topic (cluster routing / dispatch).
    pub fn partition_count(&self, topic: &str) -> Result<usize> {
        Ok(self.topic(topic)?.partition_count())
    }

    // ---- replication (HA plane) ----------------------------------------

    /// Follower-side apply of one leader replication frame: append `recs`
    /// (offsets and timestamps preserved verbatim — the wire `Record` is
    /// byte-identical to the segment frame body) starting at `base`.
    ///
    /// Returns the follower's high watermark after the apply; the leader
    /// treats a returned watermark `< base + recs.len()` as a backfill
    /// request and resends from there. Specifically:
    ///
    /// * `epoch <` the locally adopted epoch → [`BrokerError::Fenced`]
    ///   (the sender is a deposed leader; `by` is filled in by the server
    ///   dispatch with this broker's address).
    /// * `epoch >` local → adopt the new epoch (a promotion happened).
    /// * `base >` local watermark → no append, return the watermark so
    ///   the leader backfills the gap.
    /// * Records at offsets `<` the watermark are skipped (duplicate
    ///   delivery after a leader retry is idempotent).
    ///
    /// The topic is lazily opened with `partitions` partitions so a fresh
    /// follower can start replicating without an admin round trip.
    pub fn replica_append(
        &self,
        topic: &str,
        partitions: usize,
        partition: usize,
        epoch: u64,
        base: u64,
        recs: Vec<Record>,
    ) -> Result<u64> {
        let (t, _) = self.open_topic(topic, partitions.max(1))?;
        if partition >= t.partition_count() {
            return Err(BrokerError::BadPartition {
                topic: topic.into(),
                partition,
                count: t.partition_count(),
            });
        }
        let local = t.partition_epoch(partition);
        if epoch < local {
            return Err(BrokerError::Fenced { epoch: local, by: String::new() });
        }
        if epoch > local {
            t.set_partition_epoch(partition, epoch);
        }
        let hw = t.high_watermark(partition);
        if base > hw {
            return Ok(hw); // gap: ask the leader to backfill from hw
        }
        let mut appended = false;
        for rec in recs {
            let hw = t.high_watermark(partition);
            if rec.offset < hw {
                continue; // duplicate prefix from a leader retry
            }
            if rec.offset > hw {
                break; // gap inside the batch: stop, report hw
            }
            t.append_replica(partition, Arc::new(rec));
            appended = true;
        }
        if appended {
            t.notify_publish(); // wake long-polls reading from this replica
        }
        Ok(t.high_watermark(partition))
    }

    /// Adopt replicated consumer-group cursors from the partition leader
    /// (and journal them for durable topics). Adoption is forward-only —
    /// `max()` against the local cursor — so a delayed sync frame can
    /// never rewind a group that already advanced on a new leader.
    pub fn sync_offsets(&self, topic: &str, entries: Vec<OffsetEntry>) -> Result<()> {
        if self.topics.read().unwrap().get(topic).is_none() {
            return Ok(()); // no replica state yet: nothing to anchor to
        }
        for e in &entries {
            let entry = self.group_entry(&e.group, topic, e.mode);
            let mut st = entry.lock().unwrap();
            let cur = st.cursor_mut(e.partition as usize);
            cur.committed = cur.committed.max(e.committed);
            cur.position = cur.position.max(e.position).max(cur.committed);
        }
        if let Some(store) = self.offset_store(topic) {
            let mut store = store.lock().unwrap();
            for e in &entries {
                store.note(e);
            }
        }
        Ok(())
    }

    /// Leadership epoch currently adopted for one partition.
    pub fn partition_epoch(&self, topic: &str, partition: usize) -> Result<u64> {
        Ok(self.topic(topic)?.partition_epoch(partition))
    }

    /// High watermark of one partition — the next offset to be assigned.
    /// The replication and migration planes use it to measure how far a
    /// catch-up still has to go.
    pub fn high_watermark(&self, topic: &str, partition: usize) -> Result<u64> {
        Ok(self.topic(topic)?.high_watermark(partition))
    }

    /// Adopt `epoch` for one partition (promotion path — persisted in the
    /// partition's `meta.bin` for durable topics).
    pub fn set_partition_epoch(&self, topic: &str, partition: usize, epoch: u64) -> Result<()> {
        self.topic(topic)?.set_partition_epoch(partition, epoch);
        Ok(())
    }

    /// Raw log read for the replication plane: up to `max` records from
    /// `from` — no group, no claims, shared `Arc<Record>` handles.
    pub fn read_records(
        &self,
        topic: &str,
        partition: usize,
        from: u64,
        max: usize,
    ) -> Result<Vec<Arc<Record>>> {
        Ok(self.topic(topic)?.fetch(partition, from, max))
    }

    /// Snapshot every consumer-group cursor of `topic` as journal entries
    /// — the payload the leader ships to followers so groups resume from
    /// their commit points after a failover.
    pub fn group_offset_entries(&self, topic: &str) -> Vec<OffsetEntry> {
        let Ok(t) = self.topic(topic) else {
            return Vec::new();
        };
        let groups = self.groups.lock().unwrap();
        let mut out = Vec::new();
        for ((g, tname), st) in groups.iter() {
            if tname != topic {
                continue;
            }
            let st = st.lock().unwrap();
            for p in 0..t.partition_count() {
                out.push(OffsetEntry {
                    group: g.clone(),
                    mode: st.mode,
                    partition: p as u64,
                    position: st.position(p),
                    committed: st.committed(p),
                });
            }
        }
        out
    }

    // ---- consume -------------------------------------------------------

    fn group_entry(
        &self,
        group: &str,
        topic: &str,
        mode: AssignmentMode,
    ) -> Arc<Mutex<GroupState>> {
        let mut groups = self.groups.lock().unwrap();
        groups
            .entry((group.to_string(), topic.to_string()))
            .or_insert_with(|| Arc::new(Mutex::new(GroupState::new(mode))))
            .clone()
    }

    /// Join `member` to `group` for `topic`; returns the generation.
    pub fn join_group(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        mode: AssignmentMode,
    ) -> Result<u64> {
        self.topic(topic)?; // must exist
        let entry = self.group_entry(group, topic, mode);
        let mut st = entry.lock().unwrap();
        Ok(st.join(member))
    }

    /// Remove `member`; triggers rebalance (Partitioned) and rewinds the
    /// member's uncommitted claims to the commit point (Shared) so another
    /// member redelivers them — at-least-once on crash.
    pub fn leave_group(&self, group: &str, topic: &str, member: &str) -> Result<bool> {
        let entry = {
            let groups = self.groups.lock().unwrap();
            groups
                .get(&(group.to_string(), topic.to_string()))
                .cloned()
                .ok_or_else(|| BrokerError::UnknownGroup(group.into()))?
        };
        let left = entry.lock().unwrap().leave(member);
        if left {
            // A rebalance can make records claimable by surviving members:
            // wake parked fetches so redelivery starts now, not at their
            // deadline.
            if let Ok(t) = self.topic(topic) {
                t.notify_publish();
            }
        }
        Ok(left)
    }

    /// Poll up to `max` records for `member` of `group` on `topic`.
    ///
    /// Shared mode: claims from every partition's shared cursor (greedy).
    /// Partitioned mode: claims only from the member's assigned partitions.
    /// Thin wrapper over [`BrokerCore::fetch_many`] with an unlimited byte
    /// budget, flattened — one claim/fetch code path to maintain.
    pub fn poll(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
    ) -> Result<Vec<Arc<Record>>> {
        let mf = self.fetch_many(group, topic, member, max, usize::MAX)?;
        Ok(mf.batches.into_iter().flat_map(|(_, recs)| recs).collect())
    }

    /// Drain every partition assigned to `member` in **one call**: up to
    /// `max` records totalling at most `max_bytes` of payload, plus the
    /// group's post-claim cursor positions. One group-lock acquisition (and
    /// one wire frame, over TCP) replaces the per-partition poll +
    /// positions round trips of the record-at-a-time path.
    pub fn fetch_many(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
    ) -> Result<MultiFetch> {
        let t = self.topic(topic)?;
        let entry = {
            let groups = self.groups.lock().unwrap();
            groups
                .get(&(group.to_string(), topic.to_string()))
                .cloned()
                .ok_or_else(|| BrokerError::UnknownGroup(group.into()))?
        };
        let mut st = entry.lock().unwrap();
        if !st.members().iter().any(|m| m == member) {
            return Err(BrokerError::UnknownMember { group: group.into(), member: member.into() });
        }
        let parts = st.assignment(member, t.partition_count());
        let mut batches: Vec<(usize, Vec<Arc<Record>>)> = Vec::new();
        let mut rec_budget = max;
        let mut byte_budget = max_bytes;
        for p in parts {
            if rec_budget == 0 || (byte_budget == 0 && !batches.is_empty()) {
                break;
            }
            let (start, hw) = t.offsets_of(p);
            let (from, to) = st.claim(p, start, hw, rec_budget);
            if to <= from {
                continue;
            }
            // Deliberately keep scanning later partitions even when this
            // one yields nothing under the remaining byte budget: another
            // partition may hold smaller records that still fit. The cost
            // is a bounded O(partitions) claim+rewind, not lost records.
            let mut recs = t.fetch_budgeted(p, from, (to - from) as usize, byte_budget);
            if recs.is_empty() && batches.is_empty() {
                // Progress guarantee: a fetch that would otherwise return
                // nothing delivers one record even if it overflows the
                // byte budget — a single oversized record must not wedge
                // its consumers.
                recs = t.fetch(p, from, 1);
            }
            // The byte budget may cut the batch short of the claim: give
            // the unfetched suffix back so other members can take it.
            if (recs.len() as u64) < to - from {
                st.cursor_mut(p).position = from + recs.len() as u64;
            }
            if recs.is_empty() {
                continue;
            }
            rec_budget -= recs.len().min(rec_budget);
            let bytes: usize = recs.iter().map(|r| r.payload_len()).sum();
            byte_budget = byte_budget.saturating_sub(bytes);
            batches.push((p, recs));
        }
        let positions =
            (0..t.partition_count()).map(|p| (st.position(p), st.committed(p))).collect();
        // Durable topics journal the claim ("committed on claim"): after a
        // restart the group resumes from its commit point, and the claim
        // positions are on record for forensics.
        let claimed: Vec<usize> = batches.iter().map(|&(p, _)| p).collect();
        self.persist_cursors(group, topic, &st, &claimed);
        if !batches.is_empty() {
            // Trace linkage: the publish that produced (some of) this data
            // stashed its ctx on the topic — file a `fetch.wakeup` under it
            // and hand the child ctx to the response path, so the consumer
            // poll stitches into the publish's span tree.
            let pctx = t.take_publish_ctx();
            if pctx.sampled() {
                let child = trace::record_at(pctx, "fetch.wakeup", trace::now_us(), 0);
                trace::set_reply(child);
            }
            crate::obs_counter!("broker.fetch.calls").inc();
            let now = now_ms();
            for (_, recs) in &batches {
                crate::obs_counter!("broker.fetch.records").add(recs.len() as u64);
                // End-to-end delivery latency: the batch's oldest record
                // was stamped at publish; "now" is the fetch handing it to
                // a consumer. One observation per batch keeps the hot path
                // O(batches), not O(records).
                if let Some(first) = recs.first() {
                    crate::obs_hist!("broker.latency.publish_to_fetch_us")
                        .observe_ms_span(first.timestamp_ms, now);
                }
            }
        }
        Ok(MultiFetch { batches, positions })
    }

    /// [`BrokerCore::fetch_many`] that **blocks** until at least one record
    /// is available or `wait_ms` elapses — the long-poll face of the
    /// notification plane. `wait_ms == 0` degenerates to a plain fetch.
    ///
    /// The wait parks on the topic's publish `Condvar`; the publish
    /// sequence is snapshotted *before* each fetch so a record that lands
    /// between the fetch and the park wakes the caller immediately (no
    /// lost-wakeup window). Errors (unknown topic/group/member) surface on
    /// every recheck, including topics deleted mid-wait.
    pub fn fetch_many_wait(
        &self,
        group: &str,
        topic: &str,
        member: &str,
        max: usize,
        max_bytes: usize,
        wait_ms: u64,
    ) -> Result<MultiFetch> {
        use std::time::{Duration, Instant};
        // Clamp the horizon so `u64::MAX` ("wait forever") cannot overflow
        // the Instant addition.
        let deadline = Instant::now() + Duration::from_millis(wait_ms.min(MAX_WAIT_HORIZON_MS));
        loop {
            let t = self.topic(topic)?; // re-resolve: deletion must surface
            let seen = t.publish_seq();
            let mf = self.fetch_many(group, topic, member, max, max_bytes)?;
            if !mf.batches.is_empty() || wait_ms == 0 {
                return Ok(mf);
            }
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                return Ok(mf); // deadline passed: empty fetch, no spin
            };
            t.wait_publish(seen, remaining);
        }
    }

    /// Commit processed offsets: `up_to` per partition. Durable topics
    /// journal the new commit points (the restart resume points).
    pub fn commit(&self, group: &str, topic: &str, commits: &[(usize, u64)]) -> Result<()> {
        let entry = {
            let groups = self.groups.lock().unwrap();
            groups
                .get(&(group.to_string(), topic.to_string()))
                .cloned()
                .ok_or_else(|| BrokerError::UnknownGroup(group.into()))?
        };
        let mut st = entry.lock().unwrap();
        for &(p, up_to) in commits {
            st.commit(p, up_to);
        }
        let committed: Vec<usize> = commits.iter().map(|&(p, _)| p).collect();
        self.persist_cursors(group, topic, &st, &committed);
        Ok(())
    }

    /// Delete records below `up_to` in one partition (exactly-once: the ODS
    /// consumer deletes what it processed, as the paper does via Kafka's
    /// AdminClient).
    pub fn delete_records(&self, topic: &str, partition: usize, up_to: u64) -> Result<usize> {
        let t = self.topic(topic)?;
        if partition >= t.partition_count() {
            return Err(BrokerError::BadPartition {
                topic: topic.into(),
                partition,
                count: t.partition_count(),
            });
        }
        Ok(t.delete_records(partition, up_to))
    }

    /// (claim position, committed offset) per partition for a group —
    /// the safe bounds for commit/delete after a poll (deleting up to the
    /// high watermark instead would destroy records published after the
    /// claim).
    pub fn positions(&self, group: &str, topic: &str) -> Result<Vec<(u64, u64)>> {
        let t = self.topic(topic)?;
        let entry = {
            let groups = self.groups.lock().unwrap();
            groups
                .get(&(group.to_string(), topic.to_string()))
                .cloned()
                .ok_or_else(|| BrokerError::UnknownGroup(group.into()))?
        };
        let st = entry.lock().unwrap();
        Ok((0..t.partition_count()).map(|p| (st.position(p), st.committed(p))).collect())
    }

    /// (start_offset, high_watermark) per partition.
    pub fn offsets(&self, topic: &str) -> Result<Vec<(u64, u64)>> {
        let t = self.topic(topic)?;
        Ok((0..t.partition_count()).map(|p| (t.start_offset(p), t.high_watermark(p))).collect())
    }

    /// Simulate a consumer crash: rewind the group's claims to the last
    /// commit so records get redelivered (failure-injection tests).
    pub fn crash_member(&self, group: &str, topic: &str, member: &str) -> Result<()> {
        let t = self.topic(topic)?;
        let entry = {
            let groups = self.groups.lock().unwrap();
            groups
                .get(&(group.to_string(), topic.to_string()))
                .cloned()
                .ok_or_else(|| BrokerError::UnknownGroup(group.into()))?
        };
        let mut st = entry.lock().unwrap();
        for p in 0..t.partition_count() {
            st.rewind_to_committed(p);
        }
        st.leave(member);
        let all: Vec<usize> = (0..t.partition_count()).collect();
        self.persist_cursors(group, topic, &st, &all);
        drop(st);
        // The rewound records are claimable again: wake parked fetches so
        // surviving members redeliver immediately instead of waiting out
        // their long-poll deadline.
        t.notify_publish();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(v: u8) -> ProducerRecord {
        ProducerRecord::new(vec![v])
    }

    #[test]
    fn create_publish_poll_roundtrip() {
        let b = BrokerCore::new();
        b.create_topic("t", 2).unwrap();
        for i in 0..6 {
            b.publish("t", rec(i)).unwrap();
        }
        b.join_group("g", "t", "m1", AssignmentMode::Shared).unwrap();
        let got = b.poll("g", "t", "m1", usize::MAX).unwrap();
        assert_eq!(got.len(), 6);
        // Second poll: nothing new.
        assert!(b.poll("g", "t", "m1", usize::MAX).unwrap().is_empty());
    }

    #[test]
    fn duplicate_topic_rejected() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        assert_eq!(b.create_topic("t", 1), Err(BrokerError::TopicExists("t".into())));
        b.ensure_topic("t", 1).unwrap(); // idempotent, no error
    }

    #[test]
    fn two_groups_both_see_all_records() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        for i in 0..4 {
            b.publish("t", rec(i)).unwrap();
        }
        b.join_group("g1", "t", "a", AssignmentMode::Shared).unwrap();
        b.join_group("g2", "t", "b", AssignmentMode::Shared).unwrap();
        assert_eq!(b.poll("g1", "t", "a", usize::MAX).unwrap().len(), 4);
        assert_eq!(b.poll("g2", "t", "b", usize::MAX).unwrap().len(), 4);
    }

    #[test]
    fn same_group_shares_records_without_duplication() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        b.join_group("g", "t", "m1", AssignmentMode::Shared).unwrap();
        b.join_group("g", "t", "m2", AssignmentMode::Shared).unwrap();
        for i in 0..10 {
            b.publish("t", rec(i)).unwrap();
        }
        let a = b.poll("g", "t", "m1", usize::MAX).unwrap();
        let c = b.poll("g", "t", "m2", usize::MAX).unwrap();
        assert_eq!(a.len() + c.len(), 10);
        // Greedy: the first poller takes everything available.
        assert_eq!(a.len(), 10);
    }

    #[test]
    fn partitioned_mode_respects_assignment() {
        let b = BrokerCore::new();
        b.create_topic("t", 4).unwrap();
        b.join_group("g", "t", "m1", AssignmentMode::Partitioned).unwrap();
        b.join_group("g", "t", "m2", AssignmentMode::Partitioned).unwrap();
        for i in 0..40 {
            b.publish("t", rec(i)).unwrap();
        }
        let a = b.poll("g", "t", "m1", usize::MAX).unwrap();
        let c = b.poll("g", "t", "m2", usize::MAX).unwrap();
        assert_eq!(a.len() + c.len(), 40);
        assert_eq!(a.len(), 20);
        assert_eq!(c.len(), 20);
    }

    #[test]
    fn delete_records_supports_exactly_once() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        for i in 0..5 {
            b.publish("t", rec(i)).unwrap();
        }
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let got = b.poll("g", "t", "m", usize::MAX).unwrap();
        let max_off = got.iter().map(|r| r.offset).max().unwrap();
        b.delete_records("t", 0, max_off + 1).unwrap();
        let stats = b.topic_stats("t").unwrap();
        assert_eq!(stats.records, 0);
        // A late-joining group cannot see deleted records.
        b.join_group("g2", "t", "x", AssignmentMode::Shared).unwrap();
        assert!(b.poll("g2", "t", "x", usize::MAX).unwrap().is_empty());
    }

    #[test]
    fn crash_member_triggers_redelivery_of_uncommitted() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        b.join_group("g", "t", "m1", AssignmentMode::Shared).unwrap();
        b.join_group("g", "t", "m2", AssignmentMode::Shared).unwrap();
        for i in 0..8 {
            b.publish("t", rec(i)).unwrap();
        }
        let got = b.poll("g", "t", "m1", usize::MAX).unwrap();
        assert_eq!(got.len(), 8);
        // m1 processed+committed only the first 3, then crashed.
        b.commit("g", "t", &[(0, 3)]).unwrap();
        b.crash_member("g", "t", "m1").unwrap();
        let redelivered = b.poll("g", "t", "m2", usize::MAX).unwrap();
        assert_eq!(redelivered.iter().map(|r| r.offset).collect::<Vec<_>>(), vec![3, 4, 5, 6, 7]);
    }

    #[test]
    fn fetch_many_drains_all_partitions_in_one_call() {
        let b = BrokerCore::new();
        b.create_topic("t", 4).unwrap();
        for i in 0..20 {
            b.publish("t", rec(i)).unwrap();
        }
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let mf = b.fetch_many("g", "t", "m", usize::MAX, usize::MAX).unwrap();
        assert_eq!(mf.batches.len(), 4, "every partition yields a batch");
        assert_eq!(mf.record_count(), 20);
        assert_eq!(mf.byte_count(), 20, "one byte per record");
        // Positions agree with the standalone positions() call.
        assert_eq!(mf.positions, b.positions("g", "t").unwrap());
        // Nothing left afterwards.
        assert_eq!(b.fetch_many("g", "t", "m", usize::MAX, usize::MAX).unwrap().record_count(), 0);
    }

    #[test]
    fn fetch_many_respects_byte_budget_and_rewinds() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        for _ in 0..10 {
            b.publish("t", ProducerRecord::new(vec![0; 10])).unwrap();
        }
        b.join_group("g", "t", "m1", AssignmentMode::Shared).unwrap();
        b.join_group("g", "t", "m2", AssignmentMode::Shared).unwrap();
        // 35-byte budget → 3 whole records; the claimed-but-unfetched
        // suffix must be re-claimable by another member.
        let a = b.fetch_many("g", "t", "m1", usize::MAX, 35).unwrap();
        assert_eq!(a.record_count(), 3);
        let c = b.fetch_many("g", "t", "m2", usize::MAX, usize::MAX).unwrap();
        assert_eq!(c.record_count(), 7, "budget cut must not lose records");
        let offsets: Vec<u64> =
            c.batches.iter().flat_map(|(_, rs)| rs.iter().map(|r| r.offset)).collect();
        assert_eq!(offsets, (3..10).collect::<Vec<u64>>());
    }

    #[test]
    fn fetch_many_delivers_one_oversized_record() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        b.publish("t", ProducerRecord::new(vec![0; 1000])).unwrap();
        b.publish("t", ProducerRecord::new(vec![0; 1000])).unwrap();
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        // A 10-byte budget cannot hold either record, but the consumer
        // must still make progress — exactly one record per call.
        let a = b.fetch_many("g", "t", "m", usize::MAX, 10).unwrap();
        assert_eq!(a.record_count(), 1);
        let c = b.fetch_many("g", "t", "m", usize::MAX, 10).unwrap();
        assert_eq!(c.record_count(), 1);
        assert_eq!(b.fetch_many("g", "t", "m", usize::MAX, 10).unwrap().record_count(), 0);
    }

    #[test]
    fn fetch_many_respects_record_cap_and_partitioned_assignment() {
        let b = BrokerCore::new();
        b.create_topic("t", 4).unwrap();
        b.join_group("g", "t", "m1", AssignmentMode::Partitioned).unwrap();
        b.join_group("g", "t", "m2", AssignmentMode::Partitioned).unwrap();
        for i in 0..40 {
            b.publish("t", rec(i)).unwrap();
        }
        let a = b.fetch_many("g", "t", "m1", 5, usize::MAX).unwrap();
        assert_eq!(a.record_count(), 5, "record cap applies across partitions");
        let a2 = b.fetch_many("g", "t", "m1", usize::MAX, usize::MAX).unwrap();
        let c = b.fetch_many("g", "t", "m2", usize::MAX, usize::MAX).unwrap();
        assert_eq!(a.record_count() + a2.record_count(), 20);
        assert_eq!(c.record_count(), 20);
    }

    #[test]
    fn fetch_many_matches_poll_results() {
        let setup = || {
            let b = BrokerCore::new();
            b.create_topic("t", 3).unwrap();
            for i in 0..17 {
                b.publish("t", rec(i)).unwrap();
            }
            b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
            b
        };
        let via_poll: Vec<u8> = {
            let b = setup();
            b.poll("g", "t", "m", usize::MAX).unwrap().iter().map(|r| r.value.0[0]).collect()
        };
        let via_fetch_many: Vec<u8> = {
            let b = setup();
            let mf = b.fetch_many("g", "t", "m", usize::MAX, usize::MAX).unwrap();
            mf.batches.iter().flat_map(|(_, rs)| rs.iter().map(|r| r.value.0[0])).collect()
        };
        assert_eq!(via_poll, via_fetch_many, "batched and per-record paths must agree");
    }

    #[test]
    fn fetch_many_wait_wakes_on_publish() {
        use std::time::{Duration, Instant};
        let b = BrokerCore::new();
        b.create_topic("t", 2).unwrap();
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let b2 = Arc::clone(&b);
        let publisher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(15));
            b2.publish("t", rec(7)).unwrap();
        });
        let t0 = Instant::now();
        let mf = b.fetch_many_wait("g", "t", "m", usize::MAX, usize::MAX, 5_000).unwrap();
        assert_eq!(mf.record_count(), 1);
        assert!(t0.elapsed() < Duration::from_secs(4), "woken by notify, not deadline");
        publisher.join().unwrap();
    }

    #[test]
    fn fetch_many_wait_expires_empty() {
        use std::time::{Duration, Instant};
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let t0 = Instant::now();
        let mf = b.fetch_many_wait("g", "t", "m", usize::MAX, usize::MAX, 30).unwrap();
        assert_eq!(mf.record_count(), 0);
        assert!(t0.elapsed() >= Duration::from_millis(30));
        // Data already present: returns immediately, wait or not.
        b.publish("t", rec(1)).unwrap();
        let t0 = Instant::now();
        let mf = b.fetch_many_wait("g", "t", "m", usize::MAX, usize::MAX, 5_000).unwrap();
        assert_eq!(mf.record_count(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn fetch_many_wait_surfaces_mid_wait_topic_deletion() {
        use std::time::Duration;
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let b2 = Arc::clone(&b);
        let deleter = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(15));
            b2.delete_topic("t").unwrap();
        });
        let t0 = std::time::Instant::now();
        let err = b.fetch_many_wait("g", "t", "m", usize::MAX, usize::MAX, 5_000).unwrap_err();
        assert!(matches!(err, BrokerError::UnknownTopic(_)));
        assert!(t0.elapsed() < Duration::from_secs(4), "deletion must wake the waiter");
        deleter.join().unwrap();
    }

    #[test]
    fn crash_rewind_wakes_parked_fetch_for_redelivery() {
        use std::time::{Duration, Instant};
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        b.join_group("g", "t", "a", AssignmentMode::Shared).unwrap();
        b.join_group("g", "t", "b", AssignmentMode::Shared).unwrap();
        for i in 0..4 {
            b.publish("t", rec(i)).unwrap();
        }
        // Member a claims everything but commits nothing.
        assert_eq!(b.poll("g", "t", "a", usize::MAX).unwrap().len(), 4);
        // Member b parks; a's crash rewinds the claims and must wake b.
        let b2 = Arc::clone(&b);
        let crasher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(15));
            b2.crash_member("g", "t", "a").unwrap();
        });
        let t0 = Instant::now();
        let mf = b.fetch_many_wait("g", "t", "b", usize::MAX, usize::MAX, 5_000).unwrap();
        assert_eq!(mf.record_count(), 4, "rewound records must redeliver");
        assert!(t0.elapsed() < Duration::from_secs(4), "crash must wake the waiter");
        crasher.join().unwrap();
    }

    #[test]
    fn embedded_fetch_shares_the_published_allocation() {
        // The zero-copy contract: publish → PartitionLog → fetch_many
        // hands consumers the producer's own allocation.
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        let payload = crate::util::wire::Blob::new(vec![0xEE; 1 << 20]);
        b.publish("t", ProducerRecord { key: None, value: payload.clone() }).unwrap();
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let mf = b.fetch_many("g", "t", "m", usize::MAX, usize::MAX).unwrap();
        let rec = &mf.batches[0].1[0];
        assert!(rec.value.ptr_eq(&payload), "embedded fetch must not copy payload bytes");
    }

    #[test]
    fn unknown_entities_error() {
        let b = BrokerCore::new();
        assert!(matches!(b.publish("nope", rec(0)), Err(BrokerError::UnknownTopic(_))));
        b.create_topic("t", 1).unwrap();
        assert!(matches!(
            b.poll("g", "t", "m", 1),
            Err(BrokerError::UnknownGroup(_))
        ));
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        assert!(matches!(
            b.poll("g", "t", "other", 1),
            Err(BrokerError::UnknownMember { .. })
        ));
        assert!(matches!(
            b.delete_records("t", 9, 1),
            Err(BrokerError::BadPartition { .. })
        ));
    }

    #[test]
    fn disk_broker_restart_preserves_records_and_commits() {
        let dir =
            std::env::temp_dir().join(format!("hybridws-core-disk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = super::BrokerConfig::disk(&dir);
        {
            let b = BrokerCore::with_config(cfg.clone()).unwrap();
            b.create_topic("t", 2).unwrap();
            for i in 0..10 {
                b.publish("t", rec(i)).unwrap();
            }
            b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
            // Claim everything, commit only up to offset 2 on partition 0.
            assert_eq!(b.poll("g", "t", "m", usize::MAX).unwrap().len(), 10);
            b.commit("g", "t", &[(0, 2)]).unwrap();
        } // "crash"
        let b = BrokerCore::with_config(cfg).unwrap();
        assert_eq!(b.topic_names(), vec!["t".to_string()]);
        let stats = b.topic_stats("t").unwrap();
        assert_eq!(stats.partitions, 2);
        assert_eq!(stats.records, 10, "all acked records recovered");
        assert_eq!(stats.recovered_records, 10);
        assert!(stats.bytes_on_disk > 0);
        assert!(stats.segments >= 2);
        // The group's commit point survived; uncommitted claims rewound.
        assert_eq!(b.positions("g", "t").unwrap()[0], (2, 2));
        let m = b.poll("g", "t", "m2-after-restart", usize::MAX);
        assert!(m.is_err(), "members do not survive the restart, only cursors");
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        let redelivered = b.poll("g", "t", "m", usize::MAX).unwrap();
        assert_eq!(redelivered.len(), 8, "10 records minus 2 committed on partition 0");
        assert!(redelivered.iter().all(|r| r.offset >= 2 || r.value.0[0] % 2 == 1));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn delete_topic_reclaims_disk_state() {
        let dir =
            std::env::temp_dir().join(format!("hybridws-core-del-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = super::BrokerConfig::disk(&dir);
        {
            let b = BrokerCore::with_config(cfg.clone()).unwrap();
            b.create_topic("gone", 1).unwrap();
            b.publish("gone", rec(1)).unwrap();
            assert!(dir.join("gone").exists());
            b.delete_topic("gone").unwrap();
            assert!(!dir.join("gone").exists(), "segments deleted with the topic");
        }
        let b = BrokerCore::with_config(cfg).unwrap();
        assert!(b.topic_names().is_empty(), "deleted topic must not resurrect");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn delete_topic_clears_group_state() {
        let b = BrokerCore::new();
        b.create_topic("t", 1).unwrap();
        b.join_group("g", "t", "m", AssignmentMode::Shared).unwrap();
        b.delete_topic("t").unwrap();
        assert!(b.topic_names().is_empty());
        assert!(matches!(b.poll("g", "t", "m", 1), Err(BrokerError::UnknownTopic(_))));
    }
}
