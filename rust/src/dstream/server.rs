//! The **DistroStream Server** (paper §4.3): the unique, per-deployment
//! registry coordinating stream metadata.
//!
//! Responsibilities (verbatim from the paper): assign unique ids to new
//! streams, check the access permissions of producers and consumers,
//! and notify all registered consumers when the stream has been completely
//! closed and there are no producers remaining. For file streams it also
//! deduplicates deliveries (which file paths have already been handed out).
//!
//! [`StreamRegistry`] is the pure state machine; [`DistroStreamServer`]
//! serves it over TCP with the same framed protocol style as the broker.

use std::collections::{BTreeSet, HashMap, HashSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use log::{debug, warn};

use crate::util::fault;
use crate::util::mux::{serve_legacy_conn, serve_mux_conn, sniff_first_frame, ServeAction, Sniff};
use crate::util::wire::{read_frame_patient, Wire};

use super::api::{ConsumerMode, StreamId, StreamType};
use super::protocol::{DsRequest, DsResponse, StreamInfoWire};

/// Server-side clamp on one `PollFiles` long-poll park (see the broker's
/// `MAX_SERVER_WAIT_MS` — same rationale: bound shutdown latency).
pub const MAX_FILES_WAIT_MS: u64 = 5_000;

/// Read timeout on connection sockets (stop-flag granularity).
const CONN_READ_TIMEOUT: Duration = Duration::from_millis(100);

/// Registered state of one stream.
#[derive(Debug, Clone)]
pub struct StreamEntry {
    pub id: StreamId,
    pub alias: Option<String>,
    pub stype: StreamType,
    pub partitions: usize,
    pub base_dir: Option<String>,
    pub mode: ConsumerMode,
    /// Registered producer names (process/task instances).
    pub producers: HashSet<String>,
    /// Registered consumer names.
    pub consumers: HashSet<String>,
    /// Producers that called `close()`.
    pub closed_producers: HashSet<String>,
    /// Set once the stream is completely closed.
    pub closed: bool,
    /// FDS: file paths already delivered to some consumer.
    pub delivered_files: HashSet<String>,
    /// FDS: paths announced by producers ([`DsRequest::AnnounceFile`]) but
    /// not yet delivered. Merged into every poll's candidate set so a
    /// parked consumer can be handed a file the moment it is announced,
    /// before its own directory rescan would find it. Sorted for
    /// deterministic delivery order.
    pub announced_files: BTreeSet<String>,
}

impl StreamEntry {
    fn closed_check(&mut self) {
        // Completely closed: someone closed, and no still-open producer
        // remains. A stream with no registered producers closes on the
        // first explicit close().
        if !self.closed_producers.is_empty()
            && self.producers.iter().all(|p| self.closed_producers.contains(p))
        {
            self.closed = true;
        }
    }
}

/// Pure in-memory registry — the server's state machine.
#[derive(Debug, Default)]
pub struct StreamRegistry {
    streams: HashMap<StreamId, StreamEntry>,
    by_alias: HashMap<String, StreamId>,
    next_id: StreamId,
    /// Wakes consumers parked in a long-poll `PollFiles` when a producer
    /// announces a file (or a stream closes). Lives behind an `Arc` so
    /// [`dispatch`] can wait on it with the registry's own `Mutex` guard.
    files_cv: Arc<Condvar>,
}

impl StreamRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a stream. With an alias, re-registration returns the
    /// existing id (aliases let different applications share streams, §4.1).
    pub fn register(
        &mut self,
        alias: Option<String>,
        stype: StreamType,
        partitions: usize,
        base_dir: Option<String>,
        mode: ConsumerMode,
    ) -> StreamId {
        if let Some(a) = &alias {
            if let Some(&id) = self.by_alias.get(a) {
                return id;
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        if let Some(a) = &alias {
            self.by_alias.insert(a.clone(), id);
        }
        self.streams.insert(
            id,
            StreamEntry {
                id,
                alias,
                stype,
                partitions,
                base_dir,
                mode,
                producers: HashSet::new(),
                consumers: HashSet::new(),
                closed_producers: HashSet::new(),
                closed: false,
                delivered_files: HashSet::new(),
                announced_files: BTreeSet::new(),
            },
        );
        id
    }

    /// The condvar long-poll `PollFiles` parks on (cloned out so the
    /// registry guard can be handed back to `Condvar::wait_timeout`).
    pub fn files_condvar(&self) -> Arc<Condvar> {
        Arc::clone(&self.files_cv)
    }

    fn entry_mut(&mut self, id: StreamId) -> Option<&mut StreamEntry> {
        self.streams.get_mut(&id)
    }

    pub fn entry(&self, id: StreamId) -> Option<&StreamEntry> {
        self.streams.get(&id)
    }

    /// Register a producer instance (idempotent). Returns false for
    /// unknown streams.
    pub fn add_producer(&mut self, id: StreamId, name: &str) -> bool {
        match self.entry_mut(id) {
            Some(e) => {
                e.producers.insert(name.to_string());
                true
            }
            None => false,
        }
    }

    /// Register a consumer instance (idempotent).
    pub fn add_consumer(&mut self, id: StreamId, name: &str) -> bool {
        match self.entry_mut(id) {
            Some(e) => {
                e.consumers.insert(name.to_string());
                true
            }
            None => false,
        }
    }

    /// A producer announces it will publish no more.
    pub fn close_producer(&mut self, id: StreamId, name: &str) -> bool {
        match self.entry_mut(id) {
            Some(e) => {
                e.producers.insert(name.to_string());
                e.closed_producers.insert(name.to_string());
                e.closed_check();
                // Close may end a consumer's wait-for-more loop: wake any
                // parked file polls so they re-check promptly.
                self.files_cv.notify_all();
                true
            }
            None => false,
        }
    }

    /// Force-close the whole stream regardless of producers.
    pub fn close_stream(&mut self, id: StreamId) -> bool {
        match self.entry_mut(id) {
            Some(e) => {
                e.closed = true;
                self.files_cv.notify_all();
                true
            }
            None => false,
        }
    }

    /// FDS: a producer announces a freshly published (canonical) path.
    /// Paths already delivered are ignored. Wakes parked file polls.
    pub fn announce_file(&mut self, id: StreamId, path: &str) -> bool {
        match self.entry_mut(id) {
            Some(e) => {
                if !e.delivered_files.contains(path) {
                    e.announced_files.insert(path.to_string());
                }
                self.files_cv.notify_all();
                true
            }
            None => false,
        }
    }

    /// Completely closed? (`None` for unknown streams.)
    pub fn is_closed(&self, id: StreamId) -> Option<bool> {
        self.streams.get(&id).map(|e| e.closed)
    }

    /// FDS dedup: of `candidates` (the caller's directory scan) plus any
    /// producer-announced paths, return (and mark) up to `max` of the
    /// not-yet-delivered paths. Greedy first-poller-wins, mirroring ODS
    /// shared consumption; candidates beyond the cap stay undelivered so a
    /// later (or another consumer's) poll can claim them — the FDS face of
    /// the batched data plane's `max_records` budget.
    pub fn poll_files(
        &mut self,
        id: StreamId,
        candidates: Vec<String>,
        max: usize,
    ) -> Option<Vec<String>> {
        let e = self.entry_mut(id)?;
        let mut fresh = Vec::new();
        for c in candidates {
            if fresh.len() >= max {
                break;
            }
            if e.delivered_files.insert(c.clone()) {
                e.announced_files.remove(&c);
                fresh.push(c);
            }
        }
        // Announced-but-unscanned paths fill the remaining budget: this is
        // what hands a parked consumer a file the instant a producer
        // announces it.
        while fresh.len() < max {
            let Some(a) = e.announced_files.pop_first() else { break };
            if e.delivered_files.insert(a.clone()) {
                fresh.push(a);
            }
        }
        Some(fresh)
    }

    /// Remove a stream entirely.
    pub fn unregister(&mut self, id: StreamId) -> bool {
        if let Some(e) = self.streams.remove(&id) {
            if let Some(a) = e.alias {
                self.by_alias.remove(&a);
            }
            true
        } else {
            false
        }
    }

    pub fn ids(&self) -> Vec<StreamId> {
        let mut ids: Vec<_> = self.streams.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    pub fn len(&self) -> usize {
        self.streams.len()
    }

    pub fn is_empty(&self) -> bool {
        self.streams.is_empty()
    }
}

/// Apply one protocol request to the registry.
pub fn dispatch(reg: &Mutex<StreamRegistry>, req: DsRequest) -> DsResponse {
    use DsRequest as Q;
    use DsResponse as A;
    match req {
        Q::Ping => A::Pong,
        Q::Register { alias, stype, partitions, base_dir, mode } => {
            let id = reg.lock().unwrap().register(alias, stype, partitions, base_dir, mode);
            A::Registered(id)
        }
        Q::AddProducer { id, name } => bool_resp(reg.lock().unwrap().add_producer(id, &name), id),
        Q::AddConsumer { id, name } => bool_resp(reg.lock().unwrap().add_consumer(id, &name), id),
        Q::CloseProducer { id, name } => {
            bool_resp(reg.lock().unwrap().close_producer(id, &name), id)
        }
        Q::CloseStream { id } => bool_resp(reg.lock().unwrap().close_stream(id), id),
        Q::IsClosed { id } => match reg.lock().unwrap().is_closed(id) {
            Some(b) => A::Bool(b),
            None => A::Unknown(id),
        },
        Q::PollFiles { id, candidates, max, wait_ms } => {
            // Long-poll: hold the registry guard from check through park
            // (the condvar releases it while waiting), so an announce can
            // never slip between "nothing fresh" and the wait — no lost
            // wakeups. Clamped like the broker's fetch wait.
            let deadline = Instant::now() + Duration::from_millis(wait_ms.min(MAX_FILES_WAIT_MS));
            // The candidate scan is consumed by the first check: delivered
            // paths never become fresh again, so wakeup rechecks only need
            // the announced set — don't re-probe thousands of scanned
            // paths under the registry lock on every announce.
            let mut candidates = candidates;
            let mut guard = reg.lock().unwrap();
            loop {
                match guard.poll_files(id, std::mem::take(&mut candidates), max) {
                    None => return A::Unknown(id),
                    Some(fresh) if !fresh.is_empty() => return A::Files(fresh),
                    Some(fresh) => {
                        let Some(remaining) = deadline.checked_duration_since(Instant::now())
                        else {
                            return A::Files(fresh); // expired: empty, no spin
                        };
                        let cv = guard.files_condvar();
                        let (g, _) = cv.wait_timeout(guard, remaining).unwrap();
                        guard = g;
                    }
                }
            }
        }
        Q::AnnounceFile { id, path } => bool_resp(reg.lock().unwrap().announce_file(id, &path), id),
        Q::Info { id } => {
            let reg = reg.lock().unwrap();
            match reg.entry(id) {
                Some(e) => A::Info(StreamInfoWire {
                    id: e.id,
                    alias: e.alias.clone(),
                    stype: e.stype,
                    partitions: e.partitions,
                    base_dir: e.base_dir.clone(),
                    mode: e.mode,
                    producers: e.producers.len(),
                    consumers: e.consumers.len(),
                    closed: e.closed,
                }),
                None => A::Unknown(id),
            }
        }
        Q::Unregister { id } => bool_resp(reg.lock().unwrap().unregister(id), id),
        Q::Shutdown => A::Ok,
    }
}

fn bool_resp(ok: bool, id: StreamId) -> DsResponse {
    if ok {
        DsResponse::Ok
    } else {
        DsResponse::Unknown(id)
    }
}

/// TCP front-end for the registry.
pub struct DistroStreamServer {
    pub addr: SocketAddr,
    registry: Arc<Mutex<StreamRegistry>>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl DistroStreamServer {
    pub fn start(addr: &str) -> std::io::Result<Self> {
        Self::start_with(Arc::new(Mutex::new(StreamRegistry::new())), addr)
    }

    pub fn start_with(
        registry: Arc<Mutex<StreamRegistry>>,
        addr: &str,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let reg = Arc::clone(&registry);
        let st = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("dstream-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if st.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(sock) => {
                            let reg = Arc::clone(&reg);
                            let st = Arc::clone(&st);
                            std::thread::Builder::new()
                                .name("dstream-conn".into())
                                .spawn(move || handle_conn(reg, st, sock))
                                .expect("spawn dstream conn");
                        }
                        Err(e) => {
                            warn!("dstream accept error: {e}");
                            break;
                        }
                    }
                }
            })?;
        Ok(Self { addr: local, registry, stop, accept_thread: Some(accept_thread) })
    }

    pub fn registry(&self) -> Arc<Mutex<StreamRegistry>> {
        Arc::clone(&self.registry)
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for DistroStreamServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(reg: Arc<Mutex<StreamRegistry>>, stop: Arc<AtomicBool>, mut sock: TcpStream) {
    // Fault seam: sever a scripted server-side connection before any frame
    // is served (the ODS client sees an abrupt close and reconnects).
    if fault::active() {
        let local = sock.local_addr().map(|a| a.to_string()).unwrap_or_default();
        if fault::check(fault::site::DSTREAM_CONN, &local).is_some() {
            debug!("dstream conn: injected drop");
            return;
        }
    }
    // Small replies must not sit out a Nagle delay (PR 5: servers now set
    // nodelay on accepted sockets, like clients always did).
    let _ = sock.set_nodelay(true);
    // Read timeout + patient readers: the loops poll the stop flag between
    // frames, so shutdown no longer leaks threads blocked on idle peers.
    let _ = sock.set_read_timeout(Some(CONN_READ_TIMEOUT));
    // First frame picks the protocol: mux hello upgrades, else lock-step.
    let first = match read_frame_patient(&mut sock, || !stop.load(Ordering::SeqCst)) {
        Ok(Some(buf)) => buf,
        Ok(None) => return,
        Err(e) => {
            debug!("dstream conn read error: {e}");
            return;
        }
    };
    match sniff_first_frame(&mut sock, &first, "dstream") {
        Sniff::Mux { trace } => serve_mux(reg, stop, sock, trace),
        Sniff::Reject => {}
        Sniff::Legacy => match DsRequest::decode_exact(&first) {
            Ok(req) => serve_legacy(reg, stop, sock, req),
            Err(e) => debug!("dstream conn bad first frame: {e}"),
        },
    }
}

/// Legacy lock-step mode (old peers, raw-socket tools), on the shared loop
/// ([`serve_legacy_conn`]): one pair at a time, reused encode buffer,
/// vectored reply writes.
fn serve_legacy(
    reg: Arc<Mutex<StreamRegistry>>,
    stop: Arc<AtomicBool>,
    sock: TcpStream,
    first: DsRequest,
) {
    let keep_going = {
        let stop = Arc::clone(&stop);
        move || !stop.load(Ordering::SeqCst)
    };
    let classify = move |req: &DsRequest| {
        if matches!(req, DsRequest::Shutdown) {
            stop.store(true, Ordering::SeqCst);
            ServeAction::Terminal
        } else {
            ServeAction::Inline
        }
    };
    let dispatch_one = Arc::new(move |req: DsRequest| dispatch(&reg, req));
    serve_legacy_conn(sock, "dstream", keep_going, classify, dispatch_one, first);
}

/// Pipelined mux mode (PR 5), on the shared serve loop
/// ([`serve_mux_conn`]): long-poll `PollFiles` park on their own threads
/// and answer out of order by correlation id, so an `AnnounceFile`
/// pipelined behind a parked poll on the **same** connection is dispatched
/// immediately — it is the very frame that wakes the poll.
fn serve_mux(reg: Arc<Mutex<StreamRegistry>>, stop: Arc<AtomicBool>, sock: TcpStream, trace: bool) {
    let keep_going = {
        let stop = Arc::clone(&stop);
        move || !stop.load(Ordering::SeqCst)
    };
    let classify = move |req: &DsRequest| {
        if matches!(req, DsRequest::Shutdown) {
            stop.store(true, Ordering::SeqCst);
            ServeAction::Terminal
        } else if matches!(req, DsRequest::PollFiles { wait_ms, .. } if *wait_ms > 0) {
            ServeAction::Park
        } else {
            ServeAction::Inline
        }
    };
    let dispatch_one = Arc::new(move |req: DsRequest| dispatch(&reg, req));
    serve_mux_conn(sock, "dstream", "dstream-park", trace, keep_going, classify, dispatch_one);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::wire::{recv_msg, send_msg};

    fn reg() -> StreamRegistry {
        StreamRegistry::new()
    }

    #[test]
    fn ids_are_unique_and_aliases_dedupe() {
        let mut r = reg();
        let a =
            r.register(Some("s".into()), StreamType::Object, 1, None, ConsumerMode::ExactlyOnce);
        let b = r.register(None, StreamType::Object, 1, None, ConsumerMode::ExactlyOnce);
        let c =
            r.register(Some("s".into()), StreamType::Object, 4, None, ConsumerMode::ExactlyOnce);
        assert_ne!(a, b);
        assert_eq!(a, c, "same alias must return the same stream");
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn close_requires_all_producers() {
        let mut r = reg();
        let id = r.register(None, StreamType::Object, 1, None, ConsumerMode::ExactlyOnce);
        r.add_producer(id, "p1");
        r.add_producer(id, "p2");
        r.close_producer(id, "p1");
        assert_eq!(r.is_closed(id), Some(false), "p2 still open");
        r.close_producer(id, "p2");
        assert_eq!(r.is_closed(id), Some(true));
    }

    #[test]
    fn close_with_no_registered_producers_is_immediate() {
        let mut r = reg();
        let id = r.register(None, StreamType::Object, 1, None, ConsumerMode::ExactlyOnce);
        // A close from a producer that never explicitly registered.
        r.close_producer(id, "main");
        assert_eq!(r.is_closed(id), Some(true));
    }

    #[test]
    fn force_close_overrides() {
        let mut r = reg();
        let id = r.register(None, StreamType::Object, 1, None, ConsumerMode::ExactlyOnce);
        r.add_producer(id, "p1");
        r.close_stream(id);
        assert_eq!(r.is_closed(id), Some(true));
    }

    #[test]
    fn poll_files_delivers_each_path_once() {
        let mut r = reg();
        let id =
            r.register(None, StreamType::File, 1, Some("/d".into()), ConsumerMode::ExactlyOnce);
        let first = r.poll_files(id, vec!["a".into(), "b".into()], usize::MAX).unwrap();
        assert_eq!(first, vec!["a".to_string(), "b".to_string()]);
        let second =
            r.poll_files(id, vec!["a".into(), "b".into(), "c".into()], usize::MAX).unwrap();
        assert_eq!(second, vec!["c".to_string()]);
    }

    #[test]
    fn poll_files_cap_leaves_remainder_claimable() {
        let mut r = reg();
        let id =
            r.register(None, StreamType::File, 1, Some("/d".into()), ConsumerMode::ExactlyOnce);
        let all: Vec<String> = (0..5).map(|i| format!("f{i}")).collect();
        // A capped poll takes 2 fresh paths; delivered ones don't count
        // against the cap on later polls.
        assert_eq!(r.poll_files(id, all.clone(), 2).unwrap().len(), 2);
        assert_eq!(r.poll_files(id, all.clone(), 2).unwrap(), vec!["f2", "f3"]);
        assert_eq!(r.poll_files(id, all.clone(), 2).unwrap(), vec!["f4"]);
        assert!(r.poll_files(id, all, 2).unwrap().is_empty());
    }

    #[test]
    fn announced_files_deliver_once_through_either_path() {
        let mut r = reg();
        let id =
            r.register(None, StreamType::File, 1, Some("/d".into()), ConsumerMode::ExactlyOnce);
        assert!(r.announce_file(id, "/d/a"));
        // Announced path delivers even without appearing in the scan.
        assert_eq!(r.poll_files(id, vec![], usize::MAX).unwrap(), vec!["/d/a".to_string()]);
        // ... and never again, from announce or scan.
        assert!(r.announce_file(id, "/d/a"));
        assert!(r.poll_files(id, vec!["/d/a".into()], usize::MAX).unwrap().is_empty());
        // Scan-delivered paths clear a pending announce too.
        assert!(r.announce_file(id, "/d/b"));
        assert_eq!(r.poll_files(id, vec!["/d/b".into()], usize::MAX).unwrap().len(), 1);
        assert!(r.poll_files(id, vec![], usize::MAX).unwrap().is_empty());
        assert!(!r.announce_file(99, "/d/x"), "unknown stream");
    }

    #[test]
    fn long_poll_files_parks_until_announce() {
        let registry = Arc::new(Mutex::new(StreamRegistry::new()));
        let id = registry.lock().unwrap().register(
            None,
            StreamType::File,
            1,
            Some("/d".into()),
            ConsumerMode::ExactlyOnce,
        );
        // Expiry: empty answer after ~the wait, not an instant empty.
        let t0 = Instant::now();
        let resp = dispatch(
            &registry,
            DsRequest::PollFiles { id, candidates: vec![], max: usize::MAX, wait_ms: 30 },
        );
        assert_eq!(resp, DsResponse::Files(vec![]));
        assert!(t0.elapsed() >= Duration::from_millis(30));
        // Announce from another thread wakes the parked poll early.
        let reg2 = Arc::clone(&registry);
        let announcer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(15));
            dispatch(&reg2, DsRequest::AnnounceFile { id, path: "/d/late".into() });
        });
        let t0 = Instant::now();
        let resp = dispatch(
            &registry,
            DsRequest::PollFiles { id, candidates: vec![], max: usize::MAX, wait_ms: 5_000 },
        );
        assert_eq!(resp, DsResponse::Files(vec!["/d/late".into()]));
        assert!(t0.elapsed() < Duration::from_secs(4), "woken by announce, not deadline");
        announcer.join().unwrap();
    }

    #[test]
    fn unknown_stream_operations_return_false_or_none() {
        let mut r = reg();
        assert!(!r.add_producer(99, "p"));
        assert!(!r.close_stream(99));
        assert_eq!(r.is_closed(99), None);
        assert!(r.poll_files(99, vec![], usize::MAX).is_none());
        assert!(!r.unregister(99));
    }

    #[test]
    fn unregister_frees_alias() {
        let mut r = reg();
        let id =
            r.register(Some("x".into()), StreamType::Object, 1, None, ConsumerMode::ExactlyOnce);
        assert!(r.unregister(id));
        let id2 =
            r.register(Some("x".into()), StreamType::Object, 1, None, ConsumerMode::ExactlyOnce);
        assert_ne!(id, id2);
    }

    #[test]
    fn tcp_server_roundtrip() {
        let server = DistroStreamServer::start("127.0.0.1:0").unwrap();
        let mut sock = TcpStream::connect(server.addr).unwrap();
        send_msg(
            &mut sock,
            &DsRequest::Register {
                alias: Some("s".into()),
                stype: StreamType::Object,
                partitions: 2,
                base_dir: None,
                mode: ConsumerMode::ExactlyOnce,
            },
        )
        .unwrap();
        let resp: Option<DsResponse> = recv_msg(&mut sock).unwrap();
        assert_eq!(resp, Some(DsResponse::Registered(0)));
        send_msg(&mut sock, &DsRequest::IsClosed { id: 0 }).unwrap();
        let resp: Option<DsResponse> = recv_msg(&mut sock).unwrap();
        assert_eq!(resp, Some(DsResponse::Bool(false)));
        drop(sock);
        server.shutdown();
    }
}
