//! Core DistroStream types: stream kinds, consumer modes, handles, errors.

use thiserror::Error;

use crate::broker::embedded::BrokerError;
use crate::util::bytes::{ByteReader, ByteWriter, DecodeError};
use crate::util::wire::Wire;
use crate::wire_struct;

/// Kind of stream (paper §4.2: object vs file implementations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StreamType {
    Object,
    File,
}

impl Wire for StreamType {
    fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            StreamType::Object => 0,
            StreamType::File => 1,
        });
    }
    fn decode(r: &mut ByteReader) -> std::result::Result<Self, DecodeError> {
        let at = r.position();
        match r.get_u8()? {
            0 => Ok(StreamType::Object),
            1 => Ok(StreamType::File),
            tag => Err(DecodeError::BadTag { at, tag: tag as u32, ty: "StreamType" }),
        }
    }
}

/// Delivery discipline for multi-consumer streams (paper §5.3: "the library
/// allows to configure the consumer mode to process the data at least once,
/// at most once, or exactly once when using many consumers").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ConsumerMode {
    /// Poll commits *and deletes* processed records (the paper's default
    /// ODS behaviour via Kafka's AdminClient).
    #[default]
    ExactlyOnce,
    /// Poll commits immediately; a crash after poll loses the records.
    AtMostOnce,
    /// Poll does not commit; callers `ack()` after processing; a crash
    /// before ack redelivers to surviving members.
    AtLeastOnce,
}

impl Wire for ConsumerMode {
    fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            ConsumerMode::ExactlyOnce => 0,
            ConsumerMode::AtMostOnce => 1,
            ConsumerMode::AtLeastOnce => 2,
        });
    }
    fn decode(r: &mut ByteReader) -> std::result::Result<Self, DecodeError> {
        let at = r.position();
        match r.get_u8()? {
            0 => Ok(ConsumerMode::ExactlyOnce),
            1 => Ok(ConsumerMode::AtMostOnce),
            2 => Ok(ConsumerMode::AtLeastOnce),
            tag => Err(DecodeError::BadTag { at, tag: tag as u32, ty: "ConsumerMode" }),
        }
    }
}

/// Globally unique stream identifier (assigned by the DistroStream Server).
pub type StreamId = u64;

/// The serialisable face of a stream: what travels inside task parameters
/// annotated `STREAM` and across processes. Any process holding a handle
/// can materialise the stream via its local [`super::hub::DistroStreamHub`].
#[derive(Debug, Clone, PartialEq)]
pub struct StreamHandle {
    pub id: StreamId,
    pub alias: Option<String>,
    pub stype: StreamType,
    /// Broker partitions (ODS only).
    pub partitions: usize,
    /// Monitored directory (FDS only).
    pub base_dir: Option<String>,
    pub mode: ConsumerMode,
}

wire_struct!(StreamHandle {
    id: StreamId,
    alias: Option<String>,
    stype: StreamType,
    partitions: usize,
    base_dir: Option<String>,
    mode: ConsumerMode,
});

impl StreamHandle {
    /// Broker topic name for this stream.
    pub fn topic(&self) -> String {
        format!("dstream-{}", self.id)
    }
}

/// Errors surfaced by the DistroStream library.
#[derive(Debug, Error)]
pub enum DStreamError {
    /// The paper's `RegistrationException`.
    #[error("registration failed: {0}")]
    Registration(String),
    /// The paper's `BackendException`.
    #[error("backend error: {0}")]
    Backend(#[from] BrokerError),
    #[error("stream {0} is unknown to the server")]
    UnknownStream(StreamId),
    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),
    #[error("codec error: {0}")]
    Codec(#[from] DecodeError),
    #[error("transport error: {0}")]
    Transport(String),
    #[error("operation invalid on a {0:?} stream")]
    WrongType(StreamType),
}

pub type Result<T> = std::result::Result<T, DStreamError>;

/// Typed payload codec for object streams. Blanket-implemented for every
/// [`Wire`] type, so any protocol struct can ride a stream; applications can
/// also implement it directly for foreign types.
pub trait StreamItem: Sized {
    fn to_stream_bytes(&self) -> Vec<u8>;
    fn from_stream_bytes(buf: &[u8]) -> Result<Self>;
}

impl<T: Wire> StreamItem for T {
    fn to_stream_bytes(&self) -> Vec<u8> {
        self.encode_vec()
    }
    fn from_stream_bytes(buf: &[u8]) -> Result<Self> {
        Ok(T::decode_exact(buf)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_roundtrip() {
        let h = StreamHandle {
            id: 7,
            alias: Some("myStream".into()),
            stype: StreamType::File,
            partitions: 1,
            base_dir: Some("/tmp/x".into()),
            mode: ConsumerMode::AtLeastOnce,
        };
        assert_eq!(StreamHandle::decode_exact(&h.encode_vec()).unwrap(), h);
        assert_eq!(h.topic(), "dstream-7");
    }

    #[test]
    fn enums_roundtrip() {
        for t in [StreamType::Object, StreamType::File] {
            assert_eq!(StreamType::decode_exact(&t.encode_vec()).unwrap(), t);
        }
        for m in [ConsumerMode::ExactlyOnce, ConsumerMode::AtMostOnce, ConsumerMode::AtLeastOnce] {
            assert_eq!(ConsumerMode::decode_exact(&m.encode_vec()).unwrap(), m);
        }
    }

    #[test]
    fn stream_item_blanket_impl() {
        let v: Vec<u64> = vec![1, 2, 3];
        let bytes = v.to_stream_bytes();
        assert_eq!(Vec::<u64>::from_stream_bytes(&bytes).unwrap(), v);
    }
}
