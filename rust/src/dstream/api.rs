//! Core DistroStream types: stream kinds, consumer modes, handles, errors.

use thiserror::Error;

use crate::broker::embedded::BrokerError;
use crate::util::bytes::{ByteReader, ByteWriter, DecodeError};
use crate::util::wire::{Blob, Wire};
use crate::wire_struct;

/// Kind of stream (paper §4.2: object vs file implementations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StreamType {
    Object,
    File,
}

impl Wire for StreamType {
    fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            StreamType::Object => 0,
            StreamType::File => 1,
        });
    }
    fn decode(r: &mut ByteReader) -> std::result::Result<Self, DecodeError> {
        let at = r.position();
        match r.get_u8()? {
            0 => Ok(StreamType::Object),
            1 => Ok(StreamType::File),
            tag => Err(DecodeError::BadTag { at, tag: tag as u32, ty: "StreamType" }),
        }
    }
}

/// Delivery discipline for multi-consumer streams (paper §5.3: "the library
/// allows to configure the consumer mode to process the data at least once,
/// at most once, or exactly once when using many consumers").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ConsumerMode {
    /// Poll commits *and deletes* processed records (the paper's default
    /// ODS behaviour via Kafka's AdminClient).
    #[default]
    ExactlyOnce,
    /// Poll commits immediately; a crash after poll loses the records.
    AtMostOnce,
    /// Poll does not commit; callers `ack()` after processing; a crash
    /// before ack redelivers to surviving members.
    AtLeastOnce,
}

impl Wire for ConsumerMode {
    fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            ConsumerMode::ExactlyOnce => 0,
            ConsumerMode::AtMostOnce => 1,
            ConsumerMode::AtLeastOnce => 2,
        });
    }
    fn decode(r: &mut ByteReader) -> std::result::Result<Self, DecodeError> {
        let at = r.position();
        match r.get_u8()? {
            0 => Ok(ConsumerMode::ExactlyOnce),
            1 => Ok(ConsumerMode::AtMostOnce),
            2 => Ok(ConsumerMode::AtLeastOnce),
            tag => Err(DecodeError::BadTag { at, tag: tag as u32, ty: "ConsumerMode" }),
        }
    }
}

/// Globally unique stream identifier (assigned by the DistroStream Server).
pub type StreamId = u64;

/// Tuning of the batched data plane, carried inside [`StreamHandle`] so a
/// stream keeps its configuration when the handle travels through task
/// parameters to another process.
///
/// - `max_records` — per-poll record cap (combined with the deployment-wide
///   `max_poll_records` knob; the smaller wins).
/// - `max_bytes` — per-poll payload byte budget; a poll stops before the
///   record that would overflow it (one oversized record still delivers).
/// - `linger_ms` — publish-side buffering: `publish` appends to a local
///   batch that is flushed as one broker request when `max_records` /
///   `max_bytes` fills up, when a `publish` arrives after the linger has
///   expired, or on `flush()` / `close()`. There is no background timer:
///   a producer that stops publishing without closing must call `flush()`
///   itself, or its tail batch stays local. `0` (the default) publishes
///   every record immediately.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    pub max_records: usize,
    pub max_bytes: usize,
    pub linger_ms: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_records: usize::MAX, max_bytes: usize::MAX, linger_ms: 0 }
    }
}

wire_struct!(BatchPolicy { max_records: usize, max_bytes: usize, linger_ms: u64 });

impl BatchPolicy {
    /// Cap the number of records per poll/flush. A computed `0` clamps
    /// to one-at-a-time delivery on the poll side (polls never wedge).
    pub fn records(mut self, n: usize) -> Self {
        self.max_records = n;
        self
    }

    /// Cap the payload bytes per poll/flush.
    pub fn bytes(mut self, n: usize) -> Self {
        self.max_bytes = n;
        self
    }

    /// Buffer publishes for up to `ms` milliseconds before flushing (the
    /// expiry is checked on each subsequent `publish`; see the field docs
    /// for the no-background-timer caveat).
    pub fn linger_ms(mut self, ms: u64) -> Self {
        self.linger_ms = ms;
        self
    }
}

/// The serialisable face of a stream: what travels inside task parameters
/// annotated `STREAM` and across processes. Any process holding a handle
/// can materialise the stream via its local [`super::hub::DistroStreamHub`].
#[derive(Debug, Clone, PartialEq)]
pub struct StreamHandle {
    pub id: StreamId,
    pub alias: Option<String>,
    pub stype: StreamType,
    /// Broker partitions (ODS only).
    pub partitions: usize,
    /// Monitored directory (FDS only).
    pub base_dir: Option<String>,
    pub mode: ConsumerMode,
    /// Batched data-plane tuning (travels with the handle).
    pub batch: BatchPolicy,
}

wire_struct!(StreamHandle {
    id: StreamId,
    alias: Option<String>,
    stype: StreamType,
    partitions: usize,
    base_dir: Option<String>,
    mode: ConsumerMode,
    batch: BatchPolicy,
});

/// Broker topic name for an **anonymous** stream id. Ids are assigned
/// densely per registry session, so these names are only meaningful within
/// one deployment lifetime — durable storage should not rely on them
/// across restarts (see [`StreamHandle::topic`]).
pub fn topic_for(id: StreamId) -> String {
    format!("dstream-{id}")
}

/// Broker topic name for an **aliased** stream. Aliases are chosen by the
/// application and stable across restarts, so this is the name durable
/// (disk-mode) topics recover under: a restarted runtime that re-creates
/// the stream by alias binds to the same on-disk topic, records and
/// consumer cursors. (The `a-` infix keeps alias names disjoint from the
/// numeric anonymous namespace — alias `"3"` cannot collide with id 3.)
pub fn topic_for_alias(alias: &str) -> String {
    format!("dstream-a-{alias}")
}

impl StreamHandle {
    /// Broker topic name for this stream: alias-keyed when the stream has
    /// an alias (stable across restarts — what durable topics recover
    /// under), id-keyed otherwise (session-scoped).
    pub fn topic(&self) -> String {
        match &self.alias {
            Some(a) => topic_for_alias(a),
            None => topic_for(self.id),
        }
    }

    /// Replace the batch policy (builder style).
    pub fn with_batch(mut self, batch: BatchPolicy) -> Self {
        self.batch = batch;
        self
    }
}

/// Errors surfaced by the DistroStream library.
#[derive(Debug, Error)]
pub enum DStreamError {
    /// The paper's `RegistrationException`.
    #[error("registration failed: {0}")]
    Registration(String),
    /// The paper's `BackendException`.
    #[error("backend error: {0}")]
    Backend(#[from] BrokerError),
    #[error("stream {0} is unknown to the server")]
    UnknownStream(StreamId),
    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),
    #[error("codec error: {0}")]
    Codec(#[from] DecodeError),
    #[error("transport error: {0}")]
    Transport(String),
    #[error("operation invalid on a {0:?} stream")]
    WrongType(StreamType),
}

pub type Result<T> = std::result::Result<T, DStreamError>;

/// Typed payload codec for object streams. Blanket-implemented for every
/// [`Wire`] type, so any protocol struct can ride a stream; applications can
/// also implement it directly for foreign types.
pub trait StreamItem: Sized {
    fn to_stream_bytes(&self) -> Vec<u8>;
    fn from_stream_bytes(buf: &[u8]) -> Result<Self>;

    /// Encode into a caller-provided writer (batched publishes reuse one
    /// buffer across records instead of allocating per item). The default
    /// delegates to [`StreamItem::to_stream_bytes`].
    fn to_stream_bytes_into(&self, w: &mut ByteWriter) {
        w.put_raw(&self.to_stream_bytes());
    }

    /// Wrap this item into the broker payload. The default encodes into a
    /// fresh buffer; [`Blob`] overrides it (via the blanket impl) to share
    /// its allocation, making the embedded publish path copy-free.
    fn to_stream_blob(&self) -> Blob {
        Blob::new(self.to_stream_bytes())
    }

    /// Decode an item out of a broker payload. The default copies through
    /// [`StreamItem::from_stream_bytes`]; [`Blob`] shares the record's
    /// allocation instead (zero-copy embedded poll).
    fn from_stream_blob(blob: &Blob) -> Result<Self> {
        Self::from_stream_bytes(blob.as_slice())
    }
}

impl<T: Wire + std::any::Any> StreamItem for T {
    fn to_stream_bytes(&self) -> Vec<u8> {
        self.encode_vec()
    }
    fn from_stream_bytes(buf: &[u8]) -> Result<Self> {
        Ok(T::decode_exact(buf)?)
    }
    fn to_stream_bytes_into(&self, w: &mut ByteWriter) {
        self.encode(w);
    }
    fn to_stream_blob(&self) -> Blob {
        // `Blob` payloads ride the stream as-is: the record's value IS the
        // producer's buffer (an `Arc` clone, no bytes moved, no length
        // prefix). Poor man's specialisation via `Any` — a `TypeId`
        // compare, not a real downcast cost, on non-Blob items.
        if let Some(blob) = (self as &dyn std::any::Any).downcast_ref::<Blob>() {
            return blob.clone();
        }
        Blob::new(self.to_stream_bytes())
    }
    fn from_stream_blob(blob: &Blob) -> Result<Self> {
        if std::any::TypeId::of::<Self>() == std::any::TypeId::of::<Blob>() {
            let boxed: Box<dyn std::any::Any> = Box::new(blob.clone());
            return Ok(*boxed.downcast::<Self>().expect("TypeId just checked"));
        }
        Self::from_stream_bytes(blob.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_roundtrip() {
        let h = StreamHandle {
            id: 7,
            alias: Some("myStream".into()),
            stype: StreamType::File,
            partitions: 1,
            base_dir: Some("/tmp/x".into()),
            mode: ConsumerMode::AtLeastOnce,
            batch: BatchPolicy::default().records(128).bytes(1 << 20).linger_ms(5),
        };
        assert_eq!(StreamHandle::decode_exact(&h.encode_vec()).unwrap(), h);
        // Aliased streams get a restart-stable, alias-keyed topic name;
        // anonymous streams fall back to the session-scoped id.
        assert_eq!(h.topic(), "dstream-a-myStream");
        assert_eq!(StreamHandle { alias: None, ..h.clone() }.topic(), "dstream-7");
        assert_eq!(h.batch.max_records, 128);
    }

    #[test]
    fn batch_policy_default_is_unbatched() {
        let p = BatchPolicy::default();
        assert_eq!(p.max_records, usize::MAX);
        assert_eq!(p.max_bytes, usize::MAX);
        assert_eq!(p.linger_ms, 0);
        assert_eq!(BatchPolicy::decode_exact(&p.encode_vec()).unwrap(), p);
    }

    #[test]
    fn enums_roundtrip() {
        for t in [StreamType::Object, StreamType::File] {
            assert_eq!(StreamType::decode_exact(&t.encode_vec()).unwrap(), t);
        }
        for m in [ConsumerMode::ExactlyOnce, ConsumerMode::AtMostOnce, ConsumerMode::AtLeastOnce] {
            assert_eq!(ConsumerMode::decode_exact(&m.encode_vec()).unwrap(), m);
        }
    }

    #[test]
    fn stream_item_blanket_impl() {
        let v: Vec<u64> = vec![1, 2, 3];
        let bytes = v.to_stream_bytes();
        assert_eq!(Vec::<u64>::from_stream_bytes(&bytes).unwrap(), v);
    }

    #[test]
    fn blob_items_ride_streams_without_copying() {
        let b = Blob::new(vec![42u8; 4096]);
        let payload = b.to_stream_blob();
        assert!(payload.ptr_eq(&b), "Blob → stream payload must share the allocation");
        let back = Blob::from_stream_blob(&payload).unwrap();
        assert!(back.ptr_eq(&b), "stream payload → Blob must share the allocation");
        // Non-Blob items still roundtrip through the encoded form.
        let n = 7u64;
        let payload = n.to_stream_blob();
        assert_eq!(u64::from_stream_blob(&payload).unwrap(), 7);
    }
}
