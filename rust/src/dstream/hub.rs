//! `DistroStreamHub`: per-process wiring of the DistroStream components.
//!
//! The paper's deployment (Fig 8): the master spawns the DistroStream
//! Server and the backend (Kafka / Directory Monitor) and owns a client;
//! every worker owns a client. A hub bundles the client + a broker handle +
//! this process's identity, and is the factory for stream objects — either
//! fresh ones or re-materialised from a [`StreamHandle`] received as a task
//! parameter.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::broker::{BrokerClient, BrokerCore, ClusterClient, StreamBroker};

use super::api::{BatchPolicy, ConsumerMode, Result, StreamHandle, StreamId, StreamItem, StreamType};
use super::client::DistroStreamClient;
use super::file_stream::FileDistroStream;
use super::object_stream::ObjectDistroStream;
use super::server::StreamRegistry;

/// Default number of broker partitions per object stream.
pub const DEFAULT_PARTITIONS: usize = 4;

/// Per-stream data-plane counters kept by each hub (batch-efficiency
/// instrumentation: records / batches / bytes, in and out). The runtime
/// aggregates these across its hubs into the coordinator metrics.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StreamCounters {
    pub records_out: u64,
    pub batches_out: u64,
    pub bytes_out: u64,
    pub records_in: u64,
    pub batches_in: u64,
    pub bytes_in: u64,
    /// Blocking fetch calls issued on behalf of this stream, **including
    /// ones that returned empty** — the wakeup plane's efficiency witness:
    /// a blocked `poll_timeout` costs O(1) fetches per wakeup where the
    /// old spin loop cost one per 500 µs. Counts client `fetch_many_wait`
    /// invocations; a remote wait may slice one invocation into several
    /// bounded wire frames internally.
    pub fetches: u64,
    /// Segment-file bytes backing this stream's topic (0 for memory-mode
    /// and file streams). Broker-side state: hubs leave it 0; the runtime
    /// fills it from `BrokerCore::topic_stats` when aggregating (see
    /// `CometRuntime::stream_metrics`).
    pub bytes_on_disk: u64,
    /// On-disk segment count for this stream's topic (broker-side, like
    /// `bytes_on_disk`).
    pub segments: u64,
    /// Records replayed from disk when this stream's topic was recovered
    /// (broker-side, like `bytes_on_disk`).
    pub recovered_records: u64,
}

impl StreamCounters {
    /// Fold another sample into this one. The broker-side storage gauges
    /// (`bytes_on_disk`, `segments`, `recovered_records`) are taken by max
    /// — every hub observes the same broker, so summing would overcount.
    pub fn merge(&mut self, other: &StreamCounters) {
        self.records_out += other.records_out;
        self.batches_out += other.batches_out;
        self.bytes_out += other.bytes_out;
        self.records_in += other.records_in;
        self.batches_in += other.batches_in;
        self.bytes_in += other.bytes_in;
        self.fetches += other.fetches;
        self.bytes_on_disk = self.bytes_on_disk.max(other.bytes_on_disk);
        self.segments = self.segments.max(other.segments);
        self.recovered_records = self.recovered_records.max(other.recovered_records);
    }

    /// Mean records per delivering poll batch — the batch-efficiency
    /// figure of the data plane (`0.0` before the first poll).
    pub fn records_per_poll(&self) -> f64 {
        if self.batches_in == 0 {
            0.0
        } else {
            self.records_in as f64 / self.batches_in as f64
        }
    }

    /// Mean records per publish request.
    pub fn records_per_publish(&self) -> f64 {
        if self.batches_out == 0 {
            0.0
        } else {
            self.records_out as f64 / self.batches_out as f64
        }
    }
}

/// Per-process access point to the DistroStream library.
pub struct DistroStreamHub {
    client: Arc<DistroStreamClient>,
    /// The streaming back-end behind one trait object: a single broker
    /// ([`BrokerClient`], embedded or TCP) or a sharded cluster
    /// ([`ClusterClient`]) — streams never learn which.
    broker: Arc<dyn StreamBroker>,
    /// Unique name of this process (consumer-group member identity).
    process: String,
    /// Consumer group shared by all consumers of this application
    /// ("registered to a consumer group shared by all the consumers of the
    /// same application to avoid replicated messages", §4.2.1).
    group: String,
    /// Per-poll record cap (usize::MAX = paper's greedy behaviour; finite
    /// values implement the balanced-poll policy of §6.4's future work).
    max_poll_records: AtomicU64,
    /// Mount table for FDS over shared disks with different mount points
    /// (the paper's §7 future work): canonical prefix → local prefix.
    mounts: RwLock<Vec<(String, String)>>,
    /// Per-stream publish/poll counters (batched data-plane metrics).
    counters: Mutex<HashMap<StreamId, StreamCounters>>,
}

impl DistroStreamHub {
    /// Single-process deployment: embedded registry + embedded broker.
    /// Returns the hub and the shared state so more hubs (one per simulated
    /// process) can attach via [`DistroStreamHub::attach_embedded`].
    pub fn embedded(process: &str) -> (Arc<Self>, Arc<Mutex<StreamRegistry>>, Arc<BrokerCore>) {
        Self::embedded_with(process, crate::broker::BrokerConfig::memory())
            .expect("memory-mode embedded hub cannot fail")
    }

    /// [`DistroStreamHub::embedded`] with explicit broker storage
    /// configuration — durable object streams when the config says
    /// [`crate::broker::StorageMode::Disk`]. Recovers any topics already
    /// persisted under the configured data dirs.
    pub fn embedded_with(
        process: &str,
        config: crate::broker::BrokerConfig,
    ) -> Result<(Arc<Self>, Arc<Mutex<StreamRegistry>>, Arc<BrokerCore>)> {
        let registry = Arc::new(Mutex::new(StreamRegistry::new()));
        let core = BrokerCore::with_config(config)?;
        let hub = Self::attach_embedded(process, &registry, &core);
        Ok((hub, registry, core))
    }

    /// Attach another in-process hub (a simulated worker process) to shared
    /// embedded state.
    pub fn attach_embedded(
        process: &str,
        registry: &Arc<Mutex<StreamRegistry>>,
        core: &Arc<BrokerCore>,
    ) -> Arc<Self> {
        Self::attach_with_broker(
            process,
            registry,
            Arc::new(BrokerClient::embedded(Arc::clone(core))),
        )
    }

    /// Attach a hub to a shared registry with an **explicit** streaming
    /// back-end — the seam that makes hubs backend-count agnostic: pass a
    /// [`BrokerClient`] for one broker or a [`ClusterClient`] for a
    /// sharded cluster.
    pub fn attach_with_broker(
        process: &str,
        registry: &Arc<Mutex<StreamRegistry>>,
        broker: Arc<dyn StreamBroker>,
    ) -> Arc<Self> {
        Arc::new(Self {
            client: Arc::new(DistroStreamClient::embedded(Arc::clone(registry))),
            broker,
            process: process.to_string(),
            group: "app".to_string(),
            max_poll_records: AtomicU64::new(u64::MAX),
            mounts: RwLock::new(Vec::new()),
            counters: Mutex::new(HashMap::new()),
        })
    }

    /// Distributed deployment: connect to a DistroStream Server and broker
    /// over TCP.
    pub fn connect(process: &str, ds_addr: &str, broker_addr: &str) -> Result<Arc<Self>> {
        let broker: Arc<dyn StreamBroker> = Arc::new(BrokerClient::connect(broker_addr)?);
        Self::connect_with(process, ds_addr, broker)
    }

    /// Distributed deployment over a **sharded broker cluster**: connect
    /// to a DistroStream Server plus a [`ClusterClient`] over the seed
    /// list. Stream code is unchanged — the hub simply routes through the
    /// cluster's placement function.
    pub fn connect_cluster<S: AsRef<str>>(
        process: &str,
        ds_addr: &str,
        seeds: &[S],
    ) -> Result<Arc<Self>> {
        let broker: Arc<dyn StreamBroker> = Arc::new(ClusterClient::connect(seeds)?);
        Self::connect_with(process, ds_addr, broker)
    }

    fn connect_with(
        process: &str,
        ds_addr: &str,
        broker: Arc<dyn StreamBroker>,
    ) -> Result<Arc<Self>> {
        let client = DistroStreamClient::connect(ds_addr)?;
        Ok(Arc::new(Self {
            client: Arc::new(client),
            broker,
            process: process.to_string(),
            group: "app".to_string(),
            max_poll_records: AtomicU64::new(u64::MAX),
            mounts: RwLock::new(Vec::new()),
            counters: Mutex::new(HashMap::new()),
        }))
    }

    /// Record one publish batch against a stream's counters.
    pub(crate) fn note_publish(&self, id: StreamId, records: u64, bytes: u64) {
        crate::obs_counter!("stream.records_out").add(records);
        crate::obs_counter!("stream.bytes_out").add(bytes);
        let mut c = self.counters.lock().unwrap();
        let e = c.entry(id).or_default();
        e.records_out += records;
        e.batches_out += 1;
        e.bytes_out += bytes;
    }

    /// Record one poll batch against a stream's counters (empty polls are
    /// not counted — batch efficiency is records per *delivering* batch).
    pub(crate) fn note_poll(&self, id: StreamId, records: u64, bytes: u64) {
        crate::obs_counter!("stream.records_in").add(records);
        crate::obs_counter!("stream.bytes_in").add(bytes);
        let mut c = self.counters.lock().unwrap();
        let e = c.entry(id).or_default();
        e.records_in += records;
        e.batches_in += 1;
        e.bytes_in += bytes;
    }

    /// Record one broker fetch round trip (delivering or empty) — the
    /// wakeup plane's spin detector.
    pub(crate) fn note_fetch(&self, id: StreamId) {
        crate::obs_counter!("stream.fetches").inc();
        self.counters.lock().unwrap().entry(id).or_default().fetches += 1;
    }

    /// This hub's counters for one stream.
    pub fn stream_counters(&self, id: StreamId) -> StreamCounters {
        self.counters.lock().unwrap().get(&id).copied().unwrap_or_default()
    }

    /// Snapshot of every stream this hub touched.
    pub fn all_stream_counters(&self) -> Vec<(StreamId, StreamCounters)> {
        let mut v: Vec<_> =
            self.counters.lock().unwrap().iter().map(|(&k, &c)| (k, c)).collect();
        v.sort_by_key(|&(k, _)| k);
        v
    }

    pub fn process(&self) -> &str {
        &self.process
    }

    pub fn group(&self) -> &str {
        &self.group
    }

    pub fn client(&self) -> &Arc<DistroStreamClient> {
        &self.client
    }

    pub fn broker(&self) -> &Arc<dyn StreamBroker> {
        &self.broker
    }

    /// Per-poll cap (balanced-poll policy; `usize::MAX` = unlimited).
    pub fn set_max_poll_records(&self, n: usize) {
        self.max_poll_records.store(n as u64, Ordering::SeqCst);
    }

    pub fn max_poll_records(&self) -> usize {
        let v = self.max_poll_records.load(Ordering::SeqCst);
        usize::try_from(v).unwrap_or(usize::MAX)
    }

    /// Map a canonical FDS path prefix to this process's local mount point
    /// (paper §7 future work: "extend the FileDistroStream to support
    /// shared disks with different mount-points"). Stream handles carry
    /// *canonical* paths; each hub resolves them locally.
    pub fn add_mount(&self, canonical_prefix: &str, local_prefix: &str) {
        self.mounts
            .write()
            .unwrap()
            .push((canonical_prefix.to_string(), local_prefix.to_string()));
    }

    /// Canonical → local path (identity without a matching mount).
    pub fn to_local(&self, canonical: &str) -> String {
        for (c, l) in self.mounts.read().unwrap().iter() {
            if let Some(rest) = canonical.strip_prefix(c.as_str()) {
                return format!("{l}{rest}");
            }
        }
        canonical.to_string()
    }

    /// Local → canonical path (identity without a matching mount).
    pub fn to_canonical(&self, local: &str) -> String {
        for (c, l) in self.mounts.read().unwrap().iter() {
            if let Some(rest) = local.strip_prefix(l.as_str()) {
                return format!("{c}{rest}");
            }
        }
        local.to_string()
    }

    /// Create (or look up by alias) a typed object stream.
    pub fn object_stream<T: StreamItem>(
        self: &Arc<Self>,
        alias: Option<&str>,
    ) -> Result<ObjectDistroStream<T>> {
        self.object_stream_with(alias, DEFAULT_PARTITIONS, ConsumerMode::ExactlyOnce)
    }

    /// Object stream with default partitions/mode and an explicit batch
    /// policy — the common way to tune the batched data plane.
    pub fn object_stream_batched<T: StreamItem>(
        self: &Arc<Self>,
        alias: Option<&str>,
        batch: BatchPolicy,
    ) -> Result<ObjectDistroStream<T>> {
        self.object_stream_tuned(alias, DEFAULT_PARTITIONS, ConsumerMode::ExactlyOnce, batch)
    }

    /// Object stream with explicit partitions and consumer mode.
    pub fn object_stream_with<T: StreamItem>(
        self: &Arc<Self>,
        alias: Option<&str>,
        partitions: usize,
        mode: ConsumerMode,
    ) -> Result<ObjectDistroStream<T>> {
        self.object_stream_tuned(alias, partitions, mode, BatchPolicy::default())
    }

    /// Object stream with explicit partitions, consumer mode and batch
    /// policy. The policy travels inside the [`StreamHandle`], so tasks
    /// receiving the handle as a `STREAM` parameter inherit the tuning.
    pub fn object_stream_tuned<T: StreamItem>(
        self: &Arc<Self>,
        alias: Option<&str>,
        partitions: usize,
        mode: ConsumerMode,
        batch: BatchPolicy,
    ) -> Result<ObjectDistroStream<T>> {
        let id = self.client.register(
            alias.map(str::to_string),
            StreamType::Object,
            partitions,
            None,
            mode,
        )?;
        let handle = StreamHandle {
            id,
            alias: alias.map(str::to_string),
            stype: StreamType::Object,
            partitions,
            base_dir: None,
            mode,
            batch,
        };
        Ok(ObjectDistroStream::attach(handle, Arc::clone(self)))
    }

    /// Create (or look up by alias) a file stream over `base_dir`.
    pub fn file_stream(
        self: &Arc<Self>,
        alias: Option<&str>,
        base_dir: &str,
    ) -> Result<FileDistroStream> {
        let id = self.client.register(
            alias.map(str::to_string),
            StreamType::File,
            1,
            Some(base_dir.to_string()),
            ConsumerMode::ExactlyOnce,
        )?;
        let handle = StreamHandle {
            id,
            alias: alias.map(str::to_string),
            stype: StreamType::File,
            partitions: 1,
            base_dir: Some(base_dir.to_string()),
            mode: ConsumerMode::ExactlyOnce,
            batch: BatchPolicy::default(),
        };
        Ok(FileDistroStream::attach(handle, Arc::clone(self)))
    }

    /// Materialise a typed object stream from a received handle
    /// (task-parameter path).
    pub fn open_object<T: StreamItem>(
        self: &Arc<Self>,
        handle: &StreamHandle,
    ) -> ObjectDistroStream<T> {
        debug_assert_eq!(handle.stype, StreamType::Object);
        ObjectDistroStream::attach(handle.clone(), Arc::clone(self))
    }

    /// Materialise a file stream from a received handle.
    pub fn open_file(self: &Arc<Self>, handle: &StreamHandle) -> FileDistroStream {
        debug_assert_eq!(handle.stype, StreamType::File);
        FileDistroStream::attach(handle.clone(), Arc::clone(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedded_hub_creates_streams() {
        let (hub, _reg, _core) = DistroStreamHub::embedded("main");
        let ods = hub.object_stream::<u64>(Some("numbers")).unwrap();
        assert_eq!(ods.alias(), Some("numbers"));
        let handle = ods.handle().clone();
        // Second process attaches to the same stream via the handle.
        let ods2 = hub.open_object::<u64>(&handle);
        assert_eq!(ods2.id(), ods.id());
    }

    #[test]
    fn alias_lookup_shares_stream() {
        let (hub, reg, core) = DistroStreamHub::embedded("p1");
        let hub2 = DistroStreamHub::attach_embedded("p2", &reg, &core);
        let a = hub.object_stream::<u64>(Some("shared")).unwrap();
        let b = hub2.object_stream::<u64>(Some("shared")).unwrap();
        assert_eq!(a.id(), b.id());
    }

    #[test]
    fn max_poll_records_roundtrip() {
        let (hub, _, _) = DistroStreamHub::embedded("p");
        assert_eq!(hub.max_poll_records(), usize::MAX);
        hub.set_max_poll_records(5);
        assert_eq!(hub.max_poll_records(), 5);
    }

    #[test]
    fn tuned_policy_travels_with_the_handle() {
        let (hub, _, _) = DistroStreamHub::embedded("p");
        let policy = BatchPolicy::default().records(16).bytes(4096);
        let s = hub
            .object_stream_tuned::<u64>(Some("tuned"), 2, ConsumerMode::ExactlyOnce, policy)
            .unwrap();
        assert_eq!(s.handle().batch, policy);
        // A re-materialised stream inherits the tuning from the handle.
        let s2 = hub.open_object::<u64>(s.handle());
        assert_eq!(s2.handle().batch, policy);
    }

    #[test]
    fn stream_counters_track_publish_and_poll() {
        let (hub, _, _) = DistroStreamHub::embedded("p");
        let s = hub.object_stream::<u64>(None).unwrap();
        s.publish(&1).unwrap();
        s.publish_list(&[2, 3, 4]).unwrap();
        assert_eq!(s.poll().unwrap().len(), 4);
        let c = hub.stream_counters(s.id());
        assert_eq!(c.records_out, 4);
        assert_eq!(c.batches_out, 2, "one single publish + one list publish");
        assert_eq!(c.records_in, 4);
        assert_eq!(c.batches_in, 1, "one batched poll drained everything");
        assert!(c.bytes_out > 0 && c.bytes_in == c.bytes_out);
        assert_eq!(hub.all_stream_counters().len(), 1);
    }
}
