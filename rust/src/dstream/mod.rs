//! The **Distributed Stream Library** (DistroStreamLib) — the paper's §4.
//!
//! Components, mirroring Fig 4–6 of the paper:
//!
//! - [`api`] — the `DistroStream` representation: stream types, consumer
//!   modes, the serialisable [`api::StreamHandle`] that travels through
//!   task parameters, and the [`api::StreamItem`] codec trait.
//! - [`object_stream`] — `ObjectDistroStream<T>` (ODS): typed object
//!   streams backed by the broker (Kafka in the paper). Publisher and
//!   consumer are instantiated lazily on first publish/poll, exactly as
//!   §4.2.1 describes.
//! - [`file_stream`] — `FileDistroStream` (FDS): file streams backed by a
//!   directory monitor over a shared filesystem (§4.2.2). Publishing is
//!   implicit (write a file into the base dir); `poll` returns newly
//!   created paths.
//! - [`dirmon`] — the directory-scanning backend used by FDS.
//! - [`server`] — the **DistroStream Server**: the per-deployment registry
//!   of streams, producers and consumers; assigns stream ids, checks
//!   access, tracks close state and deduplicates FDS deliveries (§4.3).
//! - [`client`] — the **DistroStream Client**: per-process broker of
//!   metadata requests with a cache of terminal answers (§4.3).
//! - [`hub`] — process-level wiring: one `DistroStreamHub` per process
//!   bundles the client + stream backend and opens streams from handles.

pub mod api;
pub mod client;
pub mod dirmon;
pub mod file_stream;
pub mod hub;
pub mod object_stream;
pub mod protocol;
pub mod server;

pub use api::{BatchPolicy, ConsumerMode, DStreamError, StreamHandle, StreamItem, StreamType};
pub use client::DistroStreamClient;
pub use file_stream::FileDistroStream;
pub use hub::{DistroStreamHub, StreamCounters};
pub use object_stream::ObjectDistroStream;
pub use server::{DistroStreamServer, StreamRegistry};
