//! Wire protocol between the DistroStream Client and Server (paper §4.3:
//! "the DistroStream Server-Client communication is done through Sockets").

use crate::util::bytes::{ByteReader, ByteWriter, DecodeError};
use crate::util::wire::Wire;
use crate::wire_struct;

use super::api::{ConsumerMode, StreamId, StreamType};

/// Client → server control-plane requests.
#[derive(Debug, Clone, PartialEq)]
pub enum DsRequest {
    Ping,
    Register {
        alias: Option<String>,
        stype: StreamType,
        partitions: usize,
        base_dir: Option<String>,
        mode: ConsumerMode,
    },
    AddProducer { id: StreamId, name: String },
    AddConsumer { id: StreamId, name: String },
    CloseProducer { id: StreamId, name: String },
    CloseStream { id: StreamId },
    IsClosed { id: StreamId },
    /// FDS dedup poll. `wait_ms > 0` long-polls: the server parks the
    /// request until a producer announces a new file (see
    /// [`DsRequest::AnnounceFile`]) or the deadline passes, instead of the
    /// client sleeping between rescans.
    PollFiles { id: StreamId, candidates: Vec<String>, max: usize, wait_ms: u64 },
    /// A producer announces a freshly published file (canonical path).
    /// Wakes every consumer parked in a long-poll `PollFiles` — the FDS
    /// face of the notification plane. Out-of-band writes (files dropped
    /// into the directory without this frame) are still found by the
    /// consumers' rescans when their wait ticks over.
    AnnounceFile { id: StreamId, path: String },
    Info { id: StreamId },
    Unregister { id: StreamId },
    Shutdown,
}

impl Wire for DsRequest {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            DsRequest::Ping => w.put_u8(0),
            DsRequest::Register { alias, stype, partitions, base_dir, mode } => {
                w.put_u8(1);
                alias.encode(w);
                stype.encode(w);
                partitions.encode(w);
                base_dir.encode(w);
                mode.encode(w);
            }
            DsRequest::AddProducer { id, name } => {
                w.put_u8(2);
                id.encode(w);
                name.encode(w);
            }
            DsRequest::AddConsumer { id, name } => {
                w.put_u8(3);
                id.encode(w);
                name.encode(w);
            }
            DsRequest::CloseProducer { id, name } => {
                w.put_u8(4);
                id.encode(w);
                name.encode(w);
            }
            DsRequest::CloseStream { id } => {
                w.put_u8(5);
                id.encode(w);
            }
            DsRequest::IsClosed { id } => {
                w.put_u8(6);
                id.encode(w);
            }
            DsRequest::PollFiles { id, candidates, max, wait_ms } => {
                w.put_u8(7);
                id.encode(w);
                candidates.encode(w);
                max.encode(w);
                wait_ms.encode(w);
            }
            DsRequest::AnnounceFile { id, path } => {
                w.put_u8(11);
                id.encode(w);
                path.encode(w);
            }
            DsRequest::Info { id } => {
                w.put_u8(8);
                id.encode(w);
            }
            DsRequest::Unregister { id } => {
                w.put_u8(9);
                id.encode(w);
            }
            DsRequest::Shutdown => w.put_u8(10),
        }
    }

    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        let at = r.position();
        Ok(match r.get_u8()? {
            0 => DsRequest::Ping,
            1 => DsRequest::Register {
                alias: Wire::decode(r)?,
                stype: Wire::decode(r)?,
                partitions: Wire::decode(r)?,
                base_dir: Wire::decode(r)?,
                mode: Wire::decode(r)?,
            },
            2 => DsRequest::AddProducer { id: Wire::decode(r)?, name: Wire::decode(r)? },
            3 => DsRequest::AddConsumer { id: Wire::decode(r)?, name: Wire::decode(r)? },
            4 => DsRequest::CloseProducer { id: Wire::decode(r)?, name: Wire::decode(r)? },
            5 => DsRequest::CloseStream { id: Wire::decode(r)? },
            6 => DsRequest::IsClosed { id: Wire::decode(r)? },
            7 => DsRequest::PollFiles {
                id: Wire::decode(r)?,
                candidates: Wire::decode(r)?,
                max: Wire::decode(r)?,
                wait_ms: Wire::decode(r)?,
            },
            8 => DsRequest::Info { id: Wire::decode(r)? },
            9 => DsRequest::Unregister { id: Wire::decode(r)? },
            10 => DsRequest::Shutdown,
            11 => DsRequest::AnnounceFile { id: Wire::decode(r)?, path: Wire::decode(r)? },
            tag => return Err(DecodeError::BadTag { at, tag: tag as u32, ty: "DsRequest" }),
        })
    }
}

/// Server-side view of a stream (diagnostics / monitoring).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamInfoWire {
    pub id: StreamId,
    pub alias: Option<String>,
    pub stype: StreamType,
    pub partitions: usize,
    pub base_dir: Option<String>,
    pub mode: ConsumerMode,
    pub producers: usize,
    pub consumers: usize,
    pub closed: bool,
}

wire_struct!(StreamInfoWire {
    id: StreamId,
    alias: Option<String>,
    stype: StreamType,
    partitions: usize,
    base_dir: Option<String>,
    mode: ConsumerMode,
    producers: usize,
    consumers: usize,
    closed: bool,
});

/// Server → client responses.
#[derive(Debug, Clone, PartialEq)]
pub enum DsResponse {
    Ok,
    Pong,
    Registered(StreamId),
    Bool(bool),
    Files(Vec<String>),
    Info(StreamInfoWire),
    Unknown(StreamId),
}

impl Wire for DsResponse {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            DsResponse::Ok => w.put_u8(0),
            DsResponse::Pong => w.put_u8(1),
            DsResponse::Registered(id) => {
                w.put_u8(2);
                id.encode(w);
            }
            DsResponse::Bool(b) => {
                w.put_u8(3);
                b.encode(w);
            }
            DsResponse::Files(fs) => {
                w.put_u8(4);
                fs.encode(w);
            }
            DsResponse::Info(i) => {
                w.put_u8(5);
                i.encode(w);
            }
            DsResponse::Unknown(id) => {
                w.put_u8(255);
                id.encode(w);
            }
        }
    }

    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        let at = r.position();
        Ok(match r.get_u8()? {
            0 => DsResponse::Ok,
            1 => DsResponse::Pong,
            2 => DsResponse::Registered(Wire::decode(r)?),
            3 => DsResponse::Bool(Wire::decode(r)?),
            4 => DsResponse::Files(Wire::decode(r)?),
            5 => DsResponse::Info(Wire::decode(r)?),
            255 => DsResponse::Unknown(Wire::decode(r)?),
            tag => return Err(DecodeError::BadTag { at, tag: tag as u32, ty: "DsResponse" }),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_all_variants() {
        let reqs = vec![
            DsRequest::Ping,
            DsRequest::Register {
                alias: Some("a".into()),
                stype: StreamType::File,
                partitions: 3,
                base_dir: Some("/d".into()),
                mode: ConsumerMode::AtMostOnce,
            },
            DsRequest::AddProducer { id: 1, name: "p".into() },
            DsRequest::AddConsumer { id: 1, name: "c".into() },
            DsRequest::CloseProducer { id: 1, name: "p".into() },
            DsRequest::CloseStream { id: 1 },
            DsRequest::IsClosed { id: 1 },
            DsRequest::PollFiles { id: 1, candidates: vec!["x".into()], max: 64, wait_ms: 100 },
            DsRequest::AnnounceFile { id: 1, path: "/gpfs/exp1/x.dat".into() },
            DsRequest::Info { id: 1 },
            DsRequest::Unregister { id: 1 },
            DsRequest::Shutdown,
        ];
        for req in reqs {
            assert_eq!(DsRequest::decode_exact(&req.encode_vec()).unwrap(), req);
        }
    }

    #[test]
    fn response_roundtrip_all_variants() {
        let resps = vec![
            DsResponse::Ok,
            DsResponse::Pong,
            DsResponse::Registered(4),
            DsResponse::Bool(true),
            DsResponse::Files(vec!["a".into(), "b".into()]),
            DsResponse::Info(StreamInfoWire {
                id: 1,
                alias: None,
                stype: StreamType::Object,
                partitions: 1,
                base_dir: None,
                mode: ConsumerMode::ExactlyOnce,
                producers: 2,
                consumers: 3,
                closed: false,
            }),
            DsResponse::Unknown(9),
        ];
        for resp in resps {
            assert_eq!(DsResponse::decode_exact(&resp.encode_vec()).unwrap(), resp);
        }
    }
}
