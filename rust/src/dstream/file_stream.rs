//! `FileDistroStream` (FDS) — file streams over a shared directory
//! (paper §4.2.2).
//!
//! Publishing is *implicit*: producers simply write files into the
//! monitored base directory (use [`FileDistroStream::write_file`] for an
//! atomic create). `poll()` scans the directory and asks the DistroStream
//! Server which of the present paths have not yet been delivered to this
//! stream's consumers — the server-side dedup makes the set global across
//! processes, mirroring the shared-filesystem Directory Monitor.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::api::{BatchPolicy, Result, StreamHandle, StreamId, StreamType};
use super::dirmon;
use super::hub::DistroStreamHub;

/// A file stream bound to this process's hub.
pub struct FileDistroStream {
    handle: StreamHandle,
    hub: Arc<DistroStreamHub>,
    /// Producer/consumer identity at the server (per-task for task args).
    identity: String,
}

impl FileDistroStream {
    pub fn attach(handle: StreamHandle, hub: Arc<DistroStreamHub>) -> Self {
        let identity = hub.process().to_string();
        Self::attach_as(handle, hub, identity)
    }

    /// Bind with an explicit producer/consumer identity.
    pub fn attach_as(handle: StreamHandle, hub: Arc<DistroStreamHub>, identity: String) -> Self {
        debug_assert_eq!(handle.stype, StreamType::File);
        Self { handle, hub, identity }
    }

    /// This stream object's identity.
    pub fn identity(&self) -> &str {
        &self.identity
    }

    // ---- metadata ---------------------------------------------------------

    pub fn id(&self) -> StreamId {
        self.handle.id
    }

    pub fn alias(&self) -> Option<&str> {
        self.handle.alias.as_deref()
    }

    pub fn stream_type(&self) -> StreamType {
        StreamType::File
    }

    pub fn handle(&self) -> &StreamHandle {
        &self.handle
    }

    /// Batch tuning carried by this stream's handle. Only `max_records`
    /// applies to file streams: it caps the paths one `poll` returns, so
    /// a driver spawning one task per polled file emits bounded bursts.
    pub fn batch_policy(&self) -> BatchPolicy {
        self.handle.batch
    }

    /// Override the batch policy on this stream object (and on every
    /// handle cloned from it afterwards).
    pub fn set_batch_policy(&mut self, batch: BatchPolicy) {
        self.handle.batch = batch;
    }

    /// The monitored directory, resolved through this process's mount
    /// table (handles carry canonical paths; see `DistroStreamHub::add_mount`).
    pub fn base_dir(&self) -> PathBuf {
        let canonical = self.handle.base_dir.as_deref().expect("FDS handle without base_dir");
        PathBuf::from(self.hub.to_local(canonical))
    }

    // ---- produce ------------------------------------------------------------

    /// Atomically create `name` with `contents` in the base dir. This is a
    /// convenience — any regular file write into the directory publishes
    /// too (possibly observed mid-write unless written via temp+rename).
    ///
    /// The new path is **announced** to the DistroStream Server, waking
    /// consumers parked in [`FileDistroStream::poll_timeout`] immediately;
    /// out-of-band writes are instead found by those consumers' rescans.
    pub fn write_file(&self, name: &str, contents: &[u8]) -> Result<PathBuf> {
        // First write registers this process as a producer (lazy, like ODS).
        self.hub.client().add_producer(self.handle.id, &self.identity)?;
        let path = dirmon::publish_file(&self.base_dir(), name, contents)?;
        // Best-effort: the file is already durably published above — a
        // failed announce must not report the write as failed (consumers
        // still find the file on their next rescan tick).
        let canonical = self.hub.to_canonical(&path.to_string_lossy());
        if let Err(e) = self.hub.client().announce_file(self.handle.id, &canonical) {
            log::debug!("announce_file({canonical}) failed (rescan will deliver): {e}");
        }
        Ok(path)
    }

    // ---- consume -------------------------------------------------------------

    /// Newly available file paths (each path delivered exactly once across
    /// all consumers), capped at the handle's `batch.max_records`.
    pub fn poll(&self) -> Result<Vec<PathBuf>> {
        self.poll_wait(Duration::ZERO)
    }

    /// One scan + dedup round trip, parking at the server for up to `wait`
    /// when nothing is fresh (woken early by producer announcements).
    fn poll_wait(&self, wait: Duration) -> Result<Vec<PathBuf>> {
        self.hub.client().add_consumer(self.handle.id, &self.identity)?;
        let present = dirmon::scan_dir(&self.base_dir())?;
        // Dedup at the server is on *canonical* paths so that consumers on
        // hosts with different mount points share one delivered-set. The
        // server claims at most `max_records` *fresh* paths per poll, so
        // the remainder stays claimable (by us or by other consumers).
        // An empty scan still goes to the server: producer-announced paths
        // deliver even before the shared filesystem shows the entry here.
        let candidates: Vec<String> = present
            .iter()
            .map(|p| self.hub.to_canonical(&p.to_string_lossy()))
            .collect();
        // Clamped to ≥1 so a zero cap degrades to one-at-a-time delivery
        // instead of wedging the consumer.
        let fresh = self.hub.client().poll_files(
            self.handle.id,
            candidates,
            self.handle.batch.max_records.max(1),
            // Ceiling: a sub-ms tail must stay a blocking park, not a
            // scan+RPC busy-spin (see `timeutil::ceil_ms`).
            crate::util::timeutil::ceil_ms(wait),
        )?;
        Ok(fresh.into_iter().map(|c| PathBuf::from(self.hub.to_local(&c))).collect())
    }

    /// Poll, waiting up to `timeout` for at least one new file.
    ///
    /// Wakeup-driven: each round parks at the DistroStream Server, which
    /// wakes the wait the moment a producer announces a file through
    /// [`FileDistroStream::write_file`]. Files written out-of-band (no
    /// announce) are picked up by the rescan when the park ticks over —
    /// the tick backs off exponentially (1 → 64 ms), so an idle consumer
    /// performs a handful of directory scans per second instead of ~2000
    /// sleep-spin iterations.
    pub fn poll_timeout(&self, timeout: Duration) -> Result<Vec<PathBuf>> {
        // A ~1 year horizon doubles as "forever" without overflowing the
        // Instant addition on e.g. Duration::MAX.
        let deadline = Instant::now() + timeout.min(Duration::from_secs(31_536_000));
        let mut tick = Duration::from_millis(1);
        loop {
            let now = Instant::now();
            let remaining = deadline.saturating_duration_since(now);
            let files = self.poll_wait(tick.min(remaining))?;
            if !files.is_empty() || remaining.is_zero() {
                return Ok(files);
            }
            tick = (tick * 2).min(Duration::from_millis(64));
        }
    }

    /// Alias for [`FileDistroStream::poll_timeout`] (the file-flavoured
    /// name used by drivers that also hold object streams).
    pub fn poll_files_timeout(&self, timeout: Duration) -> Result<Vec<PathBuf>> {
        self.poll_timeout(timeout)
    }

    // ---- status / close --------------------------------------------------------

    pub fn is_closed(&self) -> bool {
        self.hub.client().is_closed(self.handle.id).unwrap_or(false)
    }

    pub fn close(&self) -> Result<()> {
        self.hub.client().close_producer(self.handle.id, &self.identity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dstream::hub::DistroStreamHub;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hybridws-fds-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_then_poll_delivers_once() {
        let d = tmpdir("once");
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.file_stream(None, d.to_str().unwrap()).unwrap();
        s.write_file("f1.dat", b"hello").unwrap();
        s.write_file("f2.dat", b"world").unwrap();
        let got = s.poll().unwrap();
        assert_eq!(got.len(), 2);
        assert!(s.poll().unwrap().is_empty(), "paths must deliver exactly once");
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn delivery_shared_across_consumers() {
        let d = tmpdir("shared");
        let (hub1, reg, core) = DistroStreamHub::embedded("c1");
        let hub2 = DistroStreamHub::attach_embedded("c2", &reg, &core);
        let s1 = hub1.file_stream(Some("fs"), d.to_str().unwrap()).unwrap();
        let s2 = hub2.file_stream(Some("fs"), d.to_str().unwrap()).unwrap();
        for i in 0..6 {
            s1.write_file(&format!("f{i}.dat"), b"x").unwrap();
        }
        let a = s1.poll().unwrap();
        let b = s2.poll().unwrap();
        assert_eq!(a.len() + b.len(), 6);
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn capped_poll_delivers_in_bounded_batches() {
        let d = tmpdir("capped");
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let mut s = hub.file_stream(None, d.to_str().unwrap()).unwrap();
        s.set_batch_policy(crate::dstream::BatchPolicy::default().records(2));
        for i in 0..5 {
            s.write_file(&format!("f{i}.dat"), b"x").unwrap();
        }
        let mut total = 0;
        while total < 5 {
            let got = s.poll().unwrap();
            assert!(got.len() <= 2, "poll exceeded max_records");
            assert!(!got.is_empty(), "capped poll starved");
            total += got.len();
        }
        assert!(s.poll().unwrap().is_empty());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn poll_timeout_sees_late_file() {
        let d = tmpdir("late");
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.file_stream(None, d.to_str().unwrap()).unwrap();
        let dir = d.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            dirmon::publish_file(&dir, "late.dat", b"z").unwrap();
        });
        let got = s.poll_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(got.len(), 1);
        t.join().unwrap();
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn write_file_announce_wakes_parked_consumer() {
        let d = tmpdir("announce");
        let (hub_p, reg, core) = DistroStreamHub::embedded("producer");
        let hub_c = DistroStreamHub::attach_embedded("consumer", &reg, &core);
        let p = hub_p.file_stream(Some("afs"), d.to_str().unwrap()).unwrap();
        let c = hub_c.file_stream(Some("afs"), d.to_str().unwrap()).unwrap();
        let waiter = std::thread::spawn(move || {
            let t0 = Instant::now();
            let files = c.poll_files_timeout(Duration::from_secs(10)).unwrap();
            (files, t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(20));
        p.write_file("wake.dat", b"payload").unwrap();
        let (files, waited) = waiter.join().unwrap();
        assert_eq!(files.len(), 1);
        assert!(
            waited < Duration::from_secs(5),
            "announce must wake the parked poll, waited {waited:?}"
        );
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn close_marks_stream_closed_and_drains() {
        let d = tmpdir("close");
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.file_stream(None, d.to_str().unwrap()).unwrap();
        s.write_file("f.dat", b"x").unwrap();
        s.close().unwrap();
        assert!(s.is_closed());
        assert_eq!(s.poll().unwrap().len(), 1, "drain after close");
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn mount_points_resolve_canonical_paths() {
        // Paper §7 future work: the same share mounted at different local
        // paths on different "hosts". Host A sees the real dir; host B sees
        // it through a symlinked mount point.
        let share = tmpdir("mount-share");
        let host_b_view = std::env::temp_dir()
            .join(format!("hybridws-fds-mount-b-{}", std::process::id()));
        let _ = std::fs::remove_file(&host_b_view);
        std::os::unix::fs::symlink(&share, &host_b_view).unwrap();

        let (hub_a, reg, core) = DistroStreamHub::embedded("hostA");
        let hub_b = DistroStreamHub::attach_embedded("hostB", &reg, &core);
        // Canonical path: "/gpfs/exp1"; each host mounts it differently.
        hub_a.add_mount("/gpfs/exp1", share.to_str().unwrap());
        hub_b.add_mount("/gpfs/exp1", host_b_view.to_str().unwrap());

        let sa = hub_a.file_stream(Some("shared-fs"), "/gpfs/exp1").unwrap();
        let sb = hub_b.file_stream(Some("shared-fs"), "/gpfs/exp1").unwrap();
        sa.write_file("x.dat", b"payload").unwrap();

        // Host B polls through its own mount point and must receive the
        // file exactly once, as a locally-valid path.
        let got = sb.poll().unwrap();
        assert_eq!(got.len(), 1);
        assert!(got[0].starts_with(&host_b_view));
        assert_eq!(std::fs::read(&got[0]).unwrap(), b"payload");
        // Dedup is canonical: host A must not receive the same file again.
        assert!(sa.poll().unwrap().is_empty());

        std::fs::remove_file(&host_b_view).unwrap();
        std::fs::remove_dir_all(&share).unwrap();
    }

    #[test]
    fn unmounted_paths_pass_through_identity() {
        let (hub, _, _) = DistroStreamHub::embedded("h");
        assert_eq!(hub.to_local("/plain/path"), "/plain/path");
        assert_eq!(hub.to_canonical("/plain/path"), "/plain/path");
        hub.add_mount("/gpfs", "/mnt/share");
        assert_eq!(hub.to_local("/gpfs/a/b"), "/mnt/share/a/b");
        assert_eq!(hub.to_canonical("/mnt/share/a/b"), "/gpfs/a/b");
    }

    #[test]
    fn in_progress_files_are_invisible() {
        let d = tmpdir("inprog");
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.file_stream(None, d.to_str().unwrap()).unwrap();
        std::fs::write(d.join(format!("half.dat{}", dirmon::TMP_SUFFIX)), b"partial").unwrap();
        assert!(s.poll().unwrap().is_empty());
        std::fs::remove_dir_all(&d).unwrap();
    }
}
