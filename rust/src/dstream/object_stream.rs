//! `ObjectDistroStream<T>` (ODS) — typed object streams over the broker
//! (paper §4.2.1).
//!
//! Each ODS maps to one broker topic named after the stream id. The
//! publisher and consumer are instantiated lazily on the first `publish` /
//! `poll` ("the producer and consumer instances are only registered when
//! required, avoiding unneeded registrations on the streaming backend").
//! Items are serialised through [`StreamItem`]; a list publish sends one
//! record per element so the backend registers them separately, exactly as
//! the paper describes for `KafkaProducer.send`.

use std::collections::HashMap;
use std::marker::PhantomData;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::broker::record::ProducerRecord;
use crate::broker::AssignmentMode;

use super::api::{ConsumerMode, Result, StreamHandle, StreamId, StreamItem, StreamType};
use super::hub::DistroStreamHub;

/// Lazily-created publisher side (mirrors the paper's `ODSPublisher`).
struct OdsPublisher {
    topic: String,
}

/// Lazily-created consumer side (mirrors the paper's `ODSConsumer`).
struct OdsConsumer {
    topic: String,
    /// Highest claimed offset + 1 per partition (for at-least-once `ack`).
    claimed: Mutex<HashMap<usize, u64>>,
}

/// A typed object stream.
pub struct ObjectDistroStream<T: StreamItem> {
    handle: StreamHandle,
    hub: Arc<DistroStreamHub>,
    /// Producer/consumer identity at the server and in the consumer group.
    /// Defaults to the hub's process name; tasks get a per-task identity so
    /// two tasks on one worker are distinct producers/consumers.
    identity: String,
    publisher: OnceLock<OdsPublisher>,
    consumer: OnceLock<OdsConsumer>,
    _marker: PhantomData<fn() -> T>,
}

impl<T: StreamItem> ObjectDistroStream<T> {
    /// Bind a stream object to this process's hub (used by the hub factory
    /// and by tasks re-materialising a received [`StreamHandle`]).
    pub fn attach(handle: StreamHandle, hub: Arc<DistroStreamHub>) -> Self {
        let identity = hub.process().to_string();
        Self::attach_as(handle, hub, identity)
    }

    /// Bind with an explicit producer/consumer identity.
    pub fn attach_as(handle: StreamHandle, hub: Arc<DistroStreamHub>, identity: String) -> Self {
        debug_assert_eq!(handle.stype, StreamType::Object);
        Self {
            handle,
            hub,
            identity,
            publisher: OnceLock::new(),
            consumer: OnceLock::new(),
            _marker: PhantomData,
        }
    }

    /// This stream object's identity.
    pub fn identity(&self) -> &str {
        &self.identity
    }

    // ---- metadata (paper Listing 3) -------------------------------------

    pub fn id(&self) -> StreamId {
        self.handle.id
    }

    pub fn alias(&self) -> Option<&str> {
        self.handle.alias.as_deref()
    }

    pub fn stream_type(&self) -> StreamType {
        StreamType::Object
    }

    pub fn handle(&self) -> &StreamHandle {
        &self.handle
    }

    pub fn mode(&self) -> ConsumerMode {
        self.handle.mode
    }

    // ---- publish side ----------------------------------------------------

    fn publisher(&self) -> Result<&OdsPublisher> {
        if let Some(p) = self.publisher.get() {
            return Ok(p);
        }
        // First publish: ensure the backend topic exists and register as a
        // producer with the DistroStream Server.
        let topic = self.handle.topic();
        self.hub.broker().ensure_topic(&topic, self.handle.partitions)?;
        self.hub.client().add_producer(self.handle.id, &self.identity)?;
        let _ = self.publisher.set(OdsPublisher { topic });
        Ok(self.publisher.get().unwrap())
    }

    /// Publish a single message.
    pub fn publish(&self, item: &T) -> Result<()> {
        let p = self.publisher()?;
        self.hub.broker().publish(&p.topic, ProducerRecord::new(item.to_stream_bytes()))?;
        Ok(())
    }

    /// Publish a list of messages (one record per element).
    pub fn publish_list(&self, items: &[T]) -> Result<()> {
        let p = self.publisher()?;
        for item in items {
            self.hub.broker().publish(&p.topic, ProducerRecord::new(item.to_stream_bytes()))?;
        }
        Ok(())
    }

    // ---- poll side ---------------------------------------------------------

    fn consumer(&self) -> Result<&OdsConsumer> {
        if let Some(c) = self.consumer.get() {
            return Ok(c);
        }
        let topic = self.handle.topic();
        self.hub.broker().ensure_topic(&topic, self.handle.partitions)?;
        self.hub.broker().join_group(
            self.hub.group(),
            &topic,
            &self.identity,
            AssignmentMode::Shared,
        )?;
        self.hub.client().add_consumer(self.handle.id, &self.identity)?;
        let _ = self.consumer.set(OdsConsumer { topic, claimed: Mutex::new(HashMap::new()) });
        Ok(self.consumer.get().unwrap())
    }

    /// Retrieve all currently-available unread messages (paper `poll()`).
    pub fn poll(&self) -> Result<Vec<T>> {
        let c = self.consumer()?;
        let max = self.hub.max_poll_records();
        let records = self.hub.broker().poll(self.hub.group(), &c.topic, &self.identity, max)?;
        if records.is_empty() {
            return Ok(Vec::new());
        }
        let mut items = Vec::with_capacity(records.len());
        for r in &records {
            items.push(T::from_stream_bytes(&r.value.0)?);
        }
        // Commit/delete bound: the group's *claim position* — never the high
        // watermark, which may already include records published after our
        // claim (deleting those would lose data).
        let positions = self.hub.broker().positions(self.hub.group(), &c.topic)?;
        match self.handle.mode {
            ConsumerMode::ExactlyOnce => {
                let commits: Vec<(usize, u64)> =
                    positions.iter().enumerate().map(|(p, &(pos, _))| (p, pos)).collect();
                self.hub.broker().commit(self.hub.group(), &c.topic, &commits)?;
                for (p, &(pos, _)) in positions.iter().enumerate() {
                    self.hub.broker().delete_records(&c.topic, p, pos)?;
                }
            }
            ConsumerMode::AtMostOnce => {
                let commits: Vec<(usize, u64)> =
                    positions.iter().enumerate().map(|(p, &(pos, _))| (p, pos)).collect();
                self.hub.broker().commit(self.hub.group(), &c.topic, &commits)?;
            }
            ConsumerMode::AtLeastOnce => {
                let mut claimed = c.claimed.lock().unwrap();
                for (p, &(pos, _)) in positions.iter().enumerate() {
                    claimed.insert(p, pos);
                }
            }
        }
        Ok(items)
    }

    /// Poll, waiting up to `timeout` for at least one element (paper
    /// `poll(timeout)`).
    pub fn poll_timeout(&self, timeout: Duration) -> Result<Vec<T>> {
        let deadline = Instant::now() + timeout;
        loop {
            let items = self.poll()?;
            if !items.is_empty() || Instant::now() >= deadline {
                return Ok(items);
            }
            std::thread::sleep(Duration::from_micros(500));
        }
    }

    /// At-least-once: acknowledge everything polled so far as processed.
    pub fn ack(&self) -> Result<()> {
        let c = self.consumer()?;
        let claimed = c.claimed.lock().unwrap();
        let commits: Vec<(usize, u64)> = claimed.iter().map(|(&p, &o)| (p, o)).collect();
        drop(claimed);
        if !commits.is_empty() {
            self.hub.broker().commit(self.hub.group(), &c.topic, &commits)?;
        }
        Ok(())
    }

    // ---- status / close ---------------------------------------------------

    /// True once the stream is completely closed (all producers closed).
    pub fn is_closed(&self) -> bool {
        self.hub.client().is_closed(self.handle.id).unwrap_or(false)
    }

    /// Close this process's producer side. The stream reports closed once
    /// every registered producer has closed.
    pub fn close(&self) -> Result<()> {
        self.hub.client().close_producer(self.handle.id, &self.identity)
    }

    /// Unprocessed records currently retained by the backend.
    pub fn backlog(&self) -> Result<usize> {
        Ok(self.hub.broker().topic_stats(&self.handle.topic()).map(|s| s.records).unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dstream::hub::DistroStreamHub;
    use crate::util::wire::Blob;

    #[test]
    fn publish_poll_roundtrip_typed() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.object_stream::<u64>(None).unwrap();
        s.publish(&7).unwrap();
        s.publish_list(&[8, 9]).unwrap();
        let mut got = s.poll().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![7, 8, 9]);
        assert!(s.poll().unwrap().is_empty(), "exactly-once: nothing redelivered");
    }

    #[test]
    fn exactly_once_deletes_backend_records() {
        let (hub, _, core) = DistroStreamHub::embedded("main");
        let s = hub.object_stream::<u64>(None).unwrap();
        s.publish_list(&[1, 2, 3]).unwrap();
        assert_eq!(s.poll().unwrap().len(), 3);
        let stats = core.topic_stats(&s.handle().topic()).unwrap();
        assert_eq!(stats.records, 0, "processed records must be deleted");
    }

    #[test]
    fn two_processes_share_exactly_once() {
        let (hub1, reg, core) = DistroStreamHub::embedded("p1");
        let hub2 = DistroStreamHub::attach_embedded("p2", &reg, &core);
        let a = hub1.object_stream::<u64>(Some("s")).unwrap();
        let b = hub2.object_stream::<u64>(Some("s")).unwrap();
        a.publish_list(&(0..20).collect::<Vec<u64>>()).unwrap();
        let got_a = a.poll().unwrap();
        let got_b = b.poll().unwrap();
        assert_eq!(got_a.len() + got_b.len(), 20, "no loss, no duplication");
    }

    #[test]
    fn close_semantics_through_stream() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.object_stream::<u64>(None).unwrap();
        s.publish(&1).unwrap(); // registers the producer
        assert!(!s.is_closed());
        s.close().unwrap();
        assert!(s.is_closed());
        // Paper loop: drain after close.
        assert_eq!(s.poll().unwrap(), vec![1]);
    }

    #[test]
    fn poll_timeout_returns_when_data_arrives() {
        let (hub, reg, core) = DistroStreamHub::embedded("consumer");
        let hub_p = DistroStreamHub::attach_embedded("producer", &reg, &core);
        let c = hub.object_stream::<u64>(Some("t")).unwrap();
        let p = hub_p.object_stream::<u64>(Some("t")).unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            p.publish(&42).unwrap();
        });
        let got = c.poll_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(got, vec![42]);
        t.join().unwrap();
    }

    #[test]
    fn poll_timeout_expires_empty() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.object_stream::<u64>(None).unwrap();
        let got = s.poll_timeout(Duration::from_millis(5)).unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn at_least_once_redelivers_unacked() {
        let (hub1, reg, core) = DistroStreamHub::embedded("c1");
        let hub2 = DistroStreamHub::attach_embedded("c2", &reg, &core);
        let s1 = hub1
            .object_stream_with::<u64>(Some("alo"), 1, ConsumerMode::AtLeastOnce)
            .unwrap();
        let s2 = hub2
            .object_stream_with::<u64>(Some("alo"), 1, ConsumerMode::AtLeastOnce)
            .unwrap();
        s1.publish_list(&[1, 2, 3]).unwrap();
        assert_eq!(s1.poll().unwrap().len(), 3);
        // c1 crashes without ack: rewind its claims and redeliver to c2.
        core.crash_member(hub1.group(), &s1.handle().topic(), hub1.process()).unwrap();
        assert_eq!(s2.poll().unwrap().len(), 3);
    }

    #[test]
    fn at_least_once_ack_stops_redelivery() {
        let (hub1, reg, core) = DistroStreamHub::embedded("c1");
        let hub2 = DistroStreamHub::attach_embedded("c2", &reg, &core);
        let s1 = hub1
            .object_stream_with::<u64>(Some("alo2"), 1, ConsumerMode::AtLeastOnce)
            .unwrap();
        let s2 = hub2
            .object_stream_with::<u64>(Some("alo2"), 1, ConsumerMode::AtLeastOnce)
            .unwrap();
        s1.publish_list(&[1, 2]).unwrap();
        assert_eq!(s1.poll().unwrap().len(), 2);
        s1.ack().unwrap();
        core.crash_member(hub1.group(), &s1.handle().topic(), hub1.process()).unwrap();
        assert!(s2.poll().unwrap().is_empty(), "acked records must not redeliver");
    }

    #[test]
    fn blob_payloads_roundtrip() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.object_stream::<Blob>(None).unwrap();
        s.publish(&Blob(vec![0u8; 1024])).unwrap();
        let got = s.poll().unwrap();
        assert_eq!(got[0].0.len(), 1024);
    }
}
