//! `ObjectDistroStream<T>` (ODS) — typed object streams over the broker
//! (paper §4.2.1).
//!
//! Each ODS maps to one broker topic named after the stream id. The
//! publisher and consumer are instantiated lazily on the first `publish` /
//! `poll` ("the producer and consumer instances are only registered when
//! required, avoiding unneeded registrations on the streaming backend").
//! Items are serialised through [`StreamItem`]; a list publish still sends
//! one record per element — so the backend registers them separately,
//! exactly as the paper describes for `KafkaProducer.send` — but the whole
//! list travels as **one** broker request (one lock acquisition embedded,
//! one wire frame over TCP), and `poll` drains every partition through one
//! [`crate::broker::BrokerClient::fetch_many`] call bounded by the
//! stream's [`super::api::BatchPolicy`].
//!
//! # Examples
//!
//! Publish → poll roundtrip on an embedded deployment:
//!
//! ```
//! use hybridws::dstream::DistroStreamHub;
//!
//! let (hub, _registry, _broker) = DistroStreamHub::embedded("doc");
//! let s = hub.object_stream::<u64>(Some("doc-numbers")).unwrap();
//! s.publish(&1).unwrap();
//! s.publish_list(&[2, 3]).unwrap(); // one broker request for the batch
//! let mut got = s.poll().unwrap(); // one fetch_many drains all partitions
//! got.sort_unstable();
//! assert_eq!(got, vec![1, 2, 3]);
//! assert!(s.poll().unwrap().is_empty(), "exactly-once by default");
//! ```

use std::collections::HashMap;
use std::marker::PhantomData;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::broker::record::ProducerRecord;
use crate::broker::{AssignmentMode, StreamBroker};

use super::api::{
    BatchPolicy, ConsumerMode, Result, StreamHandle, StreamId, StreamItem, StreamType,
};
use super::hub::DistroStreamHub;

/// Publish-side batch buffer (the `linger_ms` path of [`BatchPolicy`]).
#[derive(Default)]
struct PendingBatch {
    recs: Vec<ProducerRecord>,
    bytes: usize,
    since: Option<Instant>,
}

/// Lazily-created publisher side (mirrors the paper's `ODSPublisher`).
struct OdsPublisher {
    topic: String,
    pending: Mutex<PendingBatch>,
}

/// Lazily-created consumer side (mirrors the paper's `ODSConsumer`).
struct OdsConsumer {
    topic: String,
    /// Highest claimed offset + 1 per partition (for at-least-once `ack`).
    claimed: Mutex<HashMap<usize, u64>>,
}

/// A typed object stream.
pub struct ObjectDistroStream<T: StreamItem> {
    handle: StreamHandle,
    hub: Arc<DistroStreamHub>,
    /// Producer/consumer identity at the server and in the consumer group.
    /// Defaults to the hub's process name; tasks get a per-task identity so
    /// two tasks on one worker are distinct producers/consumers.
    identity: String,
    publisher: OnceLock<OdsPublisher>,
    consumer: OnceLock<OdsConsumer>,
    _marker: PhantomData<fn() -> T>,
}

impl<T: StreamItem> ObjectDistroStream<T> {
    /// Bind a stream object to this process's hub (used by the hub factory
    /// and by tasks re-materialising a received [`StreamHandle`]).
    pub fn attach(handle: StreamHandle, hub: Arc<DistroStreamHub>) -> Self {
        let identity = hub.process().to_string();
        Self::attach_as(handle, hub, identity)
    }

    /// Bind with an explicit producer/consumer identity.
    pub fn attach_as(handle: StreamHandle, hub: Arc<DistroStreamHub>, identity: String) -> Self {
        debug_assert_eq!(handle.stype, StreamType::Object);
        Self {
            handle,
            hub,
            identity,
            publisher: OnceLock::new(),
            consumer: OnceLock::new(),
            _marker: PhantomData,
        }
    }

    /// This stream object's identity.
    pub fn identity(&self) -> &str {
        &self.identity
    }

    // ---- metadata (paper Listing 3) -------------------------------------

    pub fn id(&self) -> StreamId {
        self.handle.id
    }

    pub fn alias(&self) -> Option<&str> {
        self.handle.alias.as_deref()
    }

    pub fn stream_type(&self) -> StreamType {
        StreamType::Object
    }

    pub fn handle(&self) -> &StreamHandle {
        &self.handle
    }

    pub fn mode(&self) -> ConsumerMode {
        self.handle.mode
    }

    /// Batched data-plane tuning carried by this stream's handle.
    pub fn batch_policy(&self) -> BatchPolicy {
        self.handle.batch
    }

    /// Override the batch policy on this stream object (and on every
    /// handle cloned from it afterwards).
    pub fn set_batch_policy(&mut self, batch: BatchPolicy) {
        self.handle.batch = batch;
    }

    // ---- publish side ----------------------------------------------------

    fn publisher(&self) -> Result<&OdsPublisher> {
        if let Some(p) = self.publisher.get() {
            return Ok(p);
        }
        // First publish: ensure the backend topic exists and register as a
        // producer with the DistroStream Server.
        let topic = self.handle.topic();
        self.hub.broker().ensure_topic(&topic, self.handle.partitions)?;
        self.hub.client().add_producer(self.handle.id, &self.identity)?;
        let _ = self
            .publisher
            .set(OdsPublisher { topic, pending: Mutex::new(PendingBatch::default()) });
        Ok(self.publisher.get().unwrap())
    }

    /// Send everything buffered by `linger_ms` publishes as one batch.
    fn flush_publisher(&self, p: &OdsPublisher) -> Result<()> {
        let batch = {
            let mut pend = p.pending.lock().unwrap();
            if pend.recs.is_empty() {
                return Ok(());
            }
            pend.bytes = 0;
            pend.since = None;
            std::mem::take(&mut pend.recs)
        };
        let n = batch.len() as u64;
        let bytes: u64 = batch.iter().map(|r| r.payload_len() as u64).sum();
        self.hub.broker().publish_batch(&p.topic, batch)?;
        self.hub.note_publish(self.handle.id, n, bytes);
        Ok(())
    }

    /// Publish a single message. With `BatchPolicy::linger_ms == 0` (the
    /// default) the record goes straight to the broker; with a linger the
    /// record is buffered locally and flushed as one batch when the policy
    /// fills up, when a later `publish` finds the linger expired, or on
    /// [`ObjectDistroStream::flush`] / [`ObjectDistroStream::close`].
    /// There is no background timer — a lingering producer that stops
    /// publishing must flush or close to make its tail batch visible.
    pub fn publish(&self, item: &T) -> Result<()> {
        let p = self.publisher()?;
        // `to_stream_blob` shares the item's allocation when it already is
        // a `Blob` — the zero-copy embedded publish path.
        let rec = ProducerRecord { key: None, value: item.to_stream_blob() };
        let policy = self.handle.batch;
        if policy.linger_ms == 0 {
            let bytes = rec.payload_len() as u64;
            self.hub.broker().publish(&p.topic, rec)?;
            self.hub.note_publish(self.handle.id, 1, bytes);
            return Ok(());
        }
        let full = {
            let mut pend = p.pending.lock().unwrap();
            pend.bytes += rec.payload_len();
            pend.recs.push(rec);
            if pend.since.is_none() {
                pend.since = Some(Instant::now());
            }
            pend.recs.len() >= policy.max_records
                || pend.bytes >= policy.max_bytes
                || pend
                    .since
                    .is_some_and(|t| t.elapsed() >= Duration::from_millis(policy.linger_ms))
        };
        if full {
            self.flush_publisher(p)?;
        }
        Ok(())
    }

    /// Publish a list of messages: one record per element (so consumers
    /// still see individual items), shipped as a **single** broker batch
    /// request. `Blob` elements travel by `Arc` clone (no bytes copied).
    pub fn publish_list(&self, items: &[T]) -> Result<()> {
        if items.is_empty() {
            return Ok(());
        }
        let p = self.publisher()?;
        // Preserve publication order with any lingering records.
        self.flush_publisher(p)?;
        let mut recs = Vec::with_capacity(items.len());
        let mut bytes = 0u64;
        for item in items {
            let rec = ProducerRecord { key: None, value: item.to_stream_blob() };
            bytes += rec.payload_len() as u64;
            recs.push(rec);
        }
        self.hub.broker().publish_batch(&p.topic, recs)?;
        self.hub.note_publish(self.handle.id, items.len() as u64, bytes);
        Ok(())
    }

    /// Flush any records buffered by a `linger_ms` policy.
    pub fn flush(&self) -> Result<()> {
        if let Some(p) = self.publisher.get() {
            self.flush_publisher(p)?;
        }
        Ok(())
    }

    // ---- poll side ---------------------------------------------------------

    fn consumer(&self) -> Result<&OdsConsumer> {
        if let Some(c) = self.consumer.get() {
            return Ok(c);
        }
        let topic = self.handle.topic();
        self.hub.broker().ensure_topic(&topic, self.handle.partitions)?;
        self.hub.broker().join_group(
            self.hub.group(),
            &topic,
            &self.identity,
            AssignmentMode::Shared,
        )?;
        self.hub.client().add_consumer(self.handle.id, &self.identity)?;
        let _ = self.consumer.set(OdsConsumer { topic, claimed: Mutex::new(HashMap::new()) });
        Ok(self.consumer.get().unwrap())
    }

    /// Retrieve currently-available unread messages (paper `poll()`),
    /// bounded by the stream's [`BatchPolicy`] (`max_records` combines
    /// with the hub's `max_poll_records`; `max_bytes` caps the payload).
    ///
    /// One [`crate::broker::BrokerClient::fetch_many`] call drains every
    /// partition *and* returns the group's claim positions, so the whole
    /// poll — including the exactly-once commit bound — costs a single
    /// broker round trip on the fetch side.
    pub fn poll(&self) -> Result<Vec<T>> {
        self.poll_wait(Duration::ZERO)
    }

    /// [`ObjectDistroStream::poll`] that blocks inside the broker until at
    /// least one record is available or `wait` elapses — **one** fetch
    /// round trip parks on the topic's publish notifier instead of the
    /// caller spinning empty polls.
    fn poll_wait(&self, wait: Duration) -> Result<Vec<T>> {
        let c = self.consumer()?;
        let policy = self.handle.batch;
        // Clamp to ≥1: a zero record cap (e.g. a computed `records(n)`
        // with n == 0) must degrade to one-at-a-time delivery, not wedge
        // the consumer on eternally-empty polls.
        let max = self.hub.max_poll_records().min(policy.max_records).max(1);
        self.hub.note_fetch(self.handle.id);
        let mf = self.hub.broker().fetch_many_wait(
            self.hub.group(),
            &c.topic,
            &self.identity,
            max,
            policy.max_bytes,
            // Ceiling, not truncation: a sub-ms tail must stay a blocking
            // wait, or the last slice of every poll_timeout degenerates
            // into a burst of non-blocking fetches.
            crate::util::timeutil::ceil_ms(wait),
        )?;
        if mf.batches.is_empty() {
            return Ok(Vec::new());
        }
        let mut items = Vec::with_capacity(mf.record_count());
        let mut bytes = 0u64;
        for (_p, records) in &mf.batches {
            for r in records {
                bytes += r.payload_len() as u64;
                // Zero-copy for `Blob` items on the embedded backend: the
                // decoded item shares the record's (= the producer's)
                // allocation.
                items.push(T::from_stream_blob(&r.value)?);
            }
        }
        self.hub.note_poll(self.handle.id, items.len() as u64, bytes);
        // Commit/delete bound: the group's *claim position* — never the high
        // watermark, which may already include records published after our
        // claim (deleting those would lose data). fetch_many snapshots the
        // positions under the same group lock as the claims.
        let positions = mf.positions;
        match self.handle.mode {
            ConsumerMode::ExactlyOnce => {
                let commits: Vec<(usize, u64)> =
                    positions.iter().enumerate().map(|(p, &(pos, _))| (p, pos)).collect();
                self.hub.broker().commit(self.hub.group(), &c.topic, &commits)?;
                for (p, &(pos, _)) in positions.iter().enumerate() {
                    self.hub.broker().delete_records(&c.topic, p, pos)?;
                }
            }
            ConsumerMode::AtMostOnce => {
                let commits: Vec<(usize, u64)> =
                    positions.iter().enumerate().map(|(p, &(pos, _))| (p, pos)).collect();
                self.hub.broker().commit(self.hub.group(), &c.topic, &commits)?;
            }
            ConsumerMode::AtLeastOnce => {
                let mut claimed = c.claimed.lock().unwrap();
                for (p, &(pos, _)) in positions.iter().enumerate() {
                    claimed.insert(p, pos);
                }
            }
        }
        Ok(items)
    }

    /// Poll, waiting up to `timeout` for at least one element (paper
    /// `poll(timeout)`).
    ///
    /// Wakeup-driven: the wait parks inside the broker (embedded: on the
    /// topic's publish `Condvar`; TCP: the server holds the `FetchMany`
    /// frame), so an idle consumer issues O(1) fetch round trips per
    /// timeout instead of one per 500 µs. A publish — including a
    /// `linger_ms` batch flushing via `flush()`/`close()` or filling up —
    /// wakes the consumer immediately. The loop exists only because remote
    /// waits are sliced server-side; each iteration is one blocking fetch.
    pub fn poll_timeout(&self, timeout: Duration) -> Result<Vec<T>> {
        // A ~1 year horizon doubles as "forever" without overflowing the
        // Instant addition on e.g. Duration::MAX.
        let deadline = Instant::now() + timeout.min(Duration::from_secs(31_536_000));
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            let items = self.poll_wait(remaining)?;
            if !items.is_empty() || Instant::now() >= deadline {
                return Ok(items);
            }
        }
    }

    /// At-least-once: acknowledge everything polled so far as processed.
    pub fn ack(&self) -> Result<()> {
        let c = self.consumer()?;
        let claimed = c.claimed.lock().unwrap();
        let commits: Vec<(usize, u64)> = claimed.iter().map(|(&p, &o)| (p, o)).collect();
        drop(claimed);
        if !commits.is_empty() {
            self.hub.broker().commit(self.hub.group(), &c.topic, &commits)?;
        }
        Ok(())
    }

    // ---- status / close ---------------------------------------------------

    /// True once the stream is completely closed (all producers closed).
    pub fn is_closed(&self) -> bool {
        self.hub.client().is_closed(self.handle.id).unwrap_or(false)
    }

    /// Close this process's producer side (flushing any lingered batch
    /// first). The stream reports closed once every registered producer
    /// has closed.
    pub fn close(&self) -> Result<()> {
        self.flush()?;
        self.hub.client().close_producer(self.handle.id, &self.identity)
    }

    /// Unprocessed records currently retained by the backend.
    pub fn backlog(&self) -> Result<usize> {
        Ok(self.hub.broker().topic_stats(&self.handle.topic()).map(|s| s.records).unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dstream::hub::DistroStreamHub;
    use crate::util::wire::Blob;

    #[test]
    fn publish_poll_roundtrip_typed() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.object_stream::<u64>(None).unwrap();
        s.publish(&7).unwrap();
        s.publish_list(&[8, 9]).unwrap();
        let mut got = s.poll().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![7, 8, 9]);
        assert!(s.poll().unwrap().is_empty(), "exactly-once: nothing redelivered");
    }

    #[test]
    fn exactly_once_deletes_backend_records() {
        let (hub, _, core) = DistroStreamHub::embedded("main");
        let s = hub.object_stream::<u64>(None).unwrap();
        s.publish_list(&[1, 2, 3]).unwrap();
        assert_eq!(s.poll().unwrap().len(), 3);
        let stats = core.topic_stats(&s.handle().topic()).unwrap();
        assert_eq!(stats.records, 0, "processed records must be deleted");
    }

    #[test]
    fn two_processes_share_exactly_once() {
        let (hub1, reg, core) = DistroStreamHub::embedded("p1");
        let hub2 = DistroStreamHub::attach_embedded("p2", &reg, &core);
        let a = hub1.object_stream::<u64>(Some("s")).unwrap();
        let b = hub2.object_stream::<u64>(Some("s")).unwrap();
        a.publish_list(&(0..20).collect::<Vec<u64>>()).unwrap();
        let got_a = a.poll().unwrap();
        let got_b = b.poll().unwrap();
        assert_eq!(got_a.len() + got_b.len(), 20, "no loss, no duplication");
    }

    #[test]
    fn close_semantics_through_stream() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.object_stream::<u64>(None).unwrap();
        s.publish(&1).unwrap(); // registers the producer
        assert!(!s.is_closed());
        s.close().unwrap();
        assert!(s.is_closed());
        // Paper loop: drain after close.
        assert_eq!(s.poll().unwrap(), vec![1]);
    }

    #[test]
    fn poll_timeout_returns_when_data_arrives() {
        let (hub, reg, core) = DistroStreamHub::embedded("consumer");
        let hub_p = DistroStreamHub::attach_embedded("producer", &reg, &core);
        let c = hub.object_stream::<u64>(Some("t")).unwrap();
        let p = hub_p.object_stream::<u64>(Some("t")).unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            p.publish(&42).unwrap();
        });
        let got = c.poll_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(got, vec![42]);
        t.join().unwrap();
    }

    #[test]
    fn poll_timeout_expires_empty() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.object_stream::<u64>(None).unwrap();
        let got = s.poll_timeout(Duration::from_millis(5)).unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn at_least_once_redelivers_unacked() {
        let (hub1, reg, core) = DistroStreamHub::embedded("c1");
        let hub2 = DistroStreamHub::attach_embedded("c2", &reg, &core);
        let s1 = hub1
            .object_stream_with::<u64>(Some("alo"), 1, ConsumerMode::AtLeastOnce)
            .unwrap();
        let s2 = hub2
            .object_stream_with::<u64>(Some("alo"), 1, ConsumerMode::AtLeastOnce)
            .unwrap();
        s1.publish_list(&[1, 2, 3]).unwrap();
        assert_eq!(s1.poll().unwrap().len(), 3);
        // c1 crashes without ack: rewind its claims and redeliver to c2.
        core.crash_member(hub1.group(), &s1.handle().topic(), hub1.process()).unwrap();
        assert_eq!(s2.poll().unwrap().len(), 3);
    }

    #[test]
    fn at_least_once_ack_stops_redelivery() {
        let (hub1, reg, core) = DistroStreamHub::embedded("c1");
        let hub2 = DistroStreamHub::attach_embedded("c2", &reg, &core);
        let s1 = hub1
            .object_stream_with::<u64>(Some("alo2"), 1, ConsumerMode::AtLeastOnce)
            .unwrap();
        let s2 = hub2
            .object_stream_with::<u64>(Some("alo2"), 1, ConsumerMode::AtLeastOnce)
            .unwrap();
        s1.publish_list(&[1, 2]).unwrap();
        assert_eq!(s1.poll().unwrap().len(), 2);
        s1.ack().unwrap();
        core.crash_member(hub1.group(), &s1.handle().topic(), hub1.process()).unwrap();
        assert!(s2.poll().unwrap().is_empty(), "acked records must not redeliver");
    }

    #[test]
    fn blob_payloads_roundtrip() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.object_stream::<Blob>(None).unwrap();
        s.publish(&Blob::new(vec![0u8; 1024])).unwrap();
        let got = s.poll().unwrap();
        assert_eq!(got[0].0.len(), 1024);
    }

    #[test]
    fn embedded_blob_path_is_zero_copy_end_to_end() {
        // The full chain — publish → PartitionLog → fetch_many → poll →
        // decode — must hand the consumer the producer's own allocation.
        let (hub, reg, core) = DistroStreamHub::embedded("producer");
        let hub_c = DistroStreamHub::attach_embedded("consumer", &reg, &core);
        let p = hub.object_stream::<Blob>(Some("zc")).unwrap();
        let c = hub_c.object_stream::<Blob>(Some("zc")).unwrap();
        let payload = Blob::new(vec![0xAB; 1 << 20]);
        p.publish(&payload).unwrap();
        let got = c.poll().unwrap();
        assert_eq!(got.len(), 1);
        assert!(
            got[0].ptr_eq(&payload),
            "embedded publish→poll must move zero payload bytes (Arc identity)"
        );
        // publish_list shares allocations the same way.
        let more = vec![Blob::new(vec![1u8; 4096]), Blob::new(vec![2u8; 4096])];
        p.publish_list(&more).unwrap();
        let got = c.poll().unwrap();
        for item in &got {
            assert!(
                more.iter().any(|m| m.ptr_eq(item)),
                "batched publish must share allocations too"
            );
        }
    }

    #[test]
    fn poll_timeout_blocks_instead_of_spinning() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub.object_stream::<u64>(Some("idle")).unwrap();
        let _ = s.poll().unwrap(); // register the consumer
        let before = hub.stream_counters(s.id()).fetches;
        let t0 = Instant::now();
        assert!(s.poll_timeout(Duration::from_millis(300)).unwrap().is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(300));
        let spent = hub.stream_counters(s.id()).fetches - before;
        assert!(
            spent <= 2,
            "an idle embedded poll_timeout must park, not spin: {spent} fetches"
        );
    }

    #[test]
    fn lingered_flush_wakes_a_blocked_consumer() {
        let (hub, reg, core) = DistroStreamHub::embedded("producer");
        let hub_c = DistroStreamHub::attach_embedded("consumer", &reg, &core);
        let p = hub
            .object_stream_tuned::<u64>(
                Some("linger-wake"),
                1,
                ConsumerMode::ExactlyOnce,
                crate::dstream::BatchPolicy::default().linger_ms(60_000),
            )
            .unwrap();
        let c = hub_c.object_stream::<u64>(Some("linger-wake")).unwrap();
        let waiter = std::thread::spawn(move || {
            let t0 = Instant::now();
            let got = c.poll_timeout(Duration::from_secs(10)).unwrap();
            (got, t0.elapsed())
        });
        p.publish(&1).unwrap();
        p.publish(&2).unwrap(); // both buffered by the linger
        std::thread::sleep(Duration::from_millis(20));
        p.flush().unwrap(); // the flush is a publish batch → wakes the waiter
        let (mut got, waited) = waiter.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
        assert!(waited < Duration::from_secs(5), "flush must wake the blocked poll");
    }

    #[test]
    fn batched_publish_equals_record_at_a_time() {
        // The batched list publish and N single publishes must deliver the
        // exact same multiset of items through poll.
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let items: Vec<u64> = (0..100).collect();
        let singles = hub.object_stream::<u64>(Some("one-by-one")).unwrap();
        for i in &items {
            singles.publish(i).unwrap();
        }
        let batched = hub.object_stream::<u64>(Some("batched")).unwrap();
        batched.publish_list(&items).unwrap();
        let mut a = singles.poll().unwrap();
        let mut b = batched.poll().unwrap();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        assert_eq!(b, items);
    }

    #[test]
    fn batch_policy_caps_poll_records() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub
            .object_stream_tuned::<u64>(
                Some("capped"),
                1,
                ConsumerMode::ExactlyOnce,
                crate::dstream::BatchPolicy::default().records(3),
            )
            .unwrap();
        s.publish_list(&(0..10).collect::<Vec<u64>>()).unwrap();
        let mut total = Vec::new();
        let mut polls = 0;
        while total.len() < 10 {
            let got = s.poll().unwrap();
            assert!(got.len() <= 3, "poll exceeded max_records: {}", got.len());
            total.extend(got);
            polls += 1;
            assert!(polls < 50, "stuck: {total:?}");
        }
        assert_eq!(total, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn batch_policy_caps_poll_bytes() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub
            .object_stream_tuned::<Blob>(
                Some("byte-capped"),
                1,
                ConsumerMode::ExactlyOnce,
                crate::dstream::BatchPolicy::default().bytes(64),
            )
            .unwrap();
        // Blob items ride the stream raw (no length prefix): each record
        // is exactly 30 payload bytes → a 64-byte budget fits two.
        s.publish_list(&vec![Blob::new(vec![7u8; 30]); 4]).unwrap();
        let mut seen = 0;
        while seen < 4 {
            let got = s.poll().unwrap();
            assert!(got.len() <= 2, "64-byte budget allows at most two 30-byte items");
            assert!(!got.is_empty(), "byte-capped poll starved");
            seen += got.len();
        }
        assert!(s.poll().unwrap().is_empty());
    }

    #[test]
    fn zero_record_cap_degrades_to_one_at_a_time() {
        let (hub, _, _) = DistroStreamHub::embedded("main");
        let s = hub
            .object_stream_tuned::<u64>(
                Some("zero-cap"),
                1,
                ConsumerMode::ExactlyOnce,
                crate::dstream::BatchPolicy::default().records(0),
            )
            .unwrap();
        s.publish_list(&[1, 2, 3]).unwrap();
        let mut total = Vec::new();
        for _ in 0..3 {
            let got = s.poll().unwrap();
            assert_eq!(got.len(), 1, "zero cap must clamp to one record, not wedge");
            total.extend(got);
        }
        assert_eq!(total, vec![1, 2, 3]);
    }

    #[test]
    fn linger_buffers_until_flush_or_close() {
        let (hub, reg, core) = DistroStreamHub::embedded("producer");
        let hub_c = DistroStreamHub::attach_embedded("consumer", &reg, &core);
        let p = hub
            .object_stream_tuned::<u64>(
                Some("lingered"),
                1,
                ConsumerMode::ExactlyOnce,
                crate::dstream::BatchPolicy::default().linger_ms(60_000),
            )
            .unwrap();
        let c = hub_c.object_stream::<u64>(Some("lingered")).unwrap();
        p.publish(&1).unwrap();
        p.publish(&2).unwrap();
        assert!(c.poll().unwrap().is_empty(), "lingered records stay local");
        p.flush().unwrap();
        let mut got = c.poll().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
        // close() flushes the tail.
        p.publish(&3).unwrap();
        p.close().unwrap();
        assert_eq!(c.poll().unwrap(), vec![3]);
    }

    #[test]
    fn linger_flushes_when_batch_fills() {
        let (hub, reg, core) = DistroStreamHub::embedded("producer");
        let hub_c = DistroStreamHub::attach_embedded("consumer", &reg, &core);
        let p = hub
            .object_stream_tuned::<u64>(
                Some("fill"),
                1,
                ConsumerMode::ExactlyOnce,
                crate::dstream::BatchPolicy::default().linger_ms(60_000).records(3),
            )
            .unwrap();
        let c = hub_c.object_stream::<u64>(Some("fill")).unwrap();
        p.publish(&1).unwrap();
        p.publish(&2).unwrap();
        assert!(c.poll().unwrap().is_empty());
        p.publish(&3).unwrap(); // 3rd record fills the batch → auto-flush
        let mut got = c.poll().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3]);
    }
}
