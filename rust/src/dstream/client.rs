//! The **DistroStream Client** (paper §4.3): the per-process broker of all
//! stream metadata requests.
//!
//! "The client is used to forward any stream metadata request to the
//! DistroStream Server [...] To avoid repeated queries to the server, the
//! client stores the retrieved metadata in a cache-like fashion."
//!
//! Our cache keeps *terminal* answers only — a stream that reports closed
//! stays closed forever, so `is_closed == true` is cached and every later
//! query is served locally; `false` answers always go to the server (they
//! can be invalidated at any time by a producer closing).

use std::collections::HashSet;
use std::sync::{Arc, Mutex};

use super::api::{ConsumerMode, DStreamError, Result, StreamId, StreamType};
use super::protocol::{DsRequest, DsResponse, StreamInfoWire};
use super::server::{dispatch, StreamRegistry};
use crate::util::mux::{MuxConn, MuxSlot};

enum Transport {
    /// Shared in-process registry (single-node deployments, tests).
    Embedded(Arc<Mutex<StreamRegistry>>),
    /// One pipelined mux connection (PR 5) to a remote
    /// [`super::server::DistroStreamServer`], in a reconnectable slot: a
    /// consumer parked in a server-side long-poll `PollFiles` is just an
    /// outstanding correlation id, so it no longer blocks `announce_file`
    /// (the very frame that wakes it) or metadata calls from threads
    /// sharing the client — the old dedicated poll socket folded into the
    /// mux. A broken connection is dropped from the slot and the next
    /// request reconnects.
    Remote(MuxSlot),
}

/// Per-process client with a terminal-answer metadata cache.
pub struct DistroStreamClient {
    transport: Transport,
    /// Streams known to be completely closed (terminal).
    closed_cache: Mutex<HashSet<StreamId>>,
}

impl DistroStreamClient {
    pub fn embedded(registry: Arc<Mutex<StreamRegistry>>) -> Self {
        Self { transport: Transport::Embedded(registry), closed_cache: Mutex::new(HashSet::new()) }
    }

    pub fn connect(addr: &str) -> Result<Self> {
        let conn = MuxConn::connect(addr)
            .map(Arc::new)
            .map_err(|e| DStreamError::Transport(format!("connect {addr}: {e}")))?;
        Ok(Self {
            transport: Transport::Remote(MuxSlot::connected(addr, conn)),
            closed_cache: Mutex::new(HashSet::new()),
        })
    }

    fn rpc(&self, req: DsRequest) -> Result<DsResponse> {
        match &self.transport {
            Transport::Embedded(reg) => Ok(dispatch(reg, req)),
            Transport::Remote(slot) => {
                // The slot hands every concurrent caller (a parked
                // long-poll, an announce, metadata lookups) the same live
                // connection, so they are all in flight on the mux at once.
                let c = slot.get().map_err(|e| {
                    DStreamError::Transport(format!("connect {}: {e}", slot.addr()))
                })?;
                match c.call::<DsRequest, DsResponse>(&req) {
                    Ok(resp) => Ok(resp),
                    Err(e) => {
                        // Forget the broken connection so the next request
                        // reconnects.
                        slot.invalidate(&c);
                        Err(DStreamError::Transport(format!("rpc: {e}")))
                    }
                }
            }
        }
    }

    fn expect_ok(&self, req: DsRequest) -> Result<()> {
        match self.rpc(req)? {
            DsResponse::Ok => Ok(()),
            DsResponse::Unknown(id) => Err(DStreamError::UnknownStream(id)),
            other => Err(DStreamError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Register (or look up by alias) a stream; returns its id.
    pub fn register(
        &self,
        alias: Option<String>,
        stype: StreamType,
        partitions: usize,
        base_dir: Option<String>,
        mode: ConsumerMode,
    ) -> Result<StreamId> {
        match self.rpc(DsRequest::Register { alias, stype, partitions, base_dir, mode })? {
            DsResponse::Registered(id) => Ok(id),
            other => Err(DStreamError::Registration(format!("unexpected response {other:?}"))),
        }
    }

    pub fn add_producer(&self, id: StreamId, name: &str) -> Result<()> {
        self.expect_ok(DsRequest::AddProducer { id, name: name.into() })
    }

    pub fn add_consumer(&self, id: StreamId, name: &str) -> Result<()> {
        self.expect_ok(DsRequest::AddConsumer { id, name: name.into() })
    }

    pub fn close_producer(&self, id: StreamId, name: &str) -> Result<()> {
        self.expect_ok(DsRequest::CloseProducer { id, name: name.into() })
    }

    pub fn close_stream(&self, id: StreamId) -> Result<()> {
        self.expect_ok(DsRequest::CloseStream { id })
    }

    /// Completely closed? Cached once true.
    pub fn is_closed(&self, id: StreamId) -> Result<bool> {
        if self.closed_cache.lock().unwrap().contains(&id) {
            return Ok(true);
        }
        match self.rpc(DsRequest::IsClosed { id })? {
            DsResponse::Bool(true) => {
                self.closed_cache.lock().unwrap().insert(id);
                Ok(true)
            }
            DsResponse::Bool(false) => Ok(false),
            DsResponse::Unknown(id) => Err(DStreamError::UnknownStream(id)),
            other => Err(DStreamError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// FDS dedup poll: claim up to `max` undelivered candidates (see
    /// server docs). `wait_ms > 0` parks at the server until a producer
    /// announces a new file or the deadline passes — no client-side
    /// sleeping. The server clamps one park (callers with longer budgets
    /// re-issue, rescanning their directory in between).
    pub fn poll_files(
        &self,
        id: StreamId,
        candidates: Vec<String>,
        max: usize,
        wait_ms: u64,
    ) -> Result<Vec<String>> {
        // A waiting poll parks server-side as one outstanding mux id: the
        // announce that wakes it flows on the same connection (PR 5 — no
        // dedicated poll socket any more).
        match self.rpc(DsRequest::PollFiles { id, candidates, max, wait_ms })? {
            DsResponse::Files(fs) => Ok(fs),
            DsResponse::Unknown(id) => Err(DStreamError::UnknownStream(id)),
            other => Err(DStreamError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// FDS: announce a freshly published file (canonical path) so parked
    /// consumers wake immediately instead of on their next rescan tick.
    pub fn announce_file(&self, id: StreamId, path: &str) -> Result<()> {
        self.expect_ok(DsRequest::AnnounceFile { id, path: path.into() })
    }

    pub fn info(&self, id: StreamId) -> Result<StreamInfoWire> {
        match self.rpc(DsRequest::Info { id })? {
            DsResponse::Info(i) => Ok(i),
            DsResponse::Unknown(id) => Err(DStreamError::UnknownStream(id)),
            other => Err(DStreamError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn unregister(&self, id: StreamId) -> Result<()> {
        self.closed_cache.lock().unwrap().remove(&id);
        self.expect_ok(DsRequest::Unregister { id })
    }

    pub fn ping(&self) -> Result<()> {
        match self.rpc(DsRequest::Ping)? {
            DsResponse::Pong => Ok(()),
            other => Err(DStreamError::Transport(format!("unexpected response {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dstream::server::DistroStreamServer;

    fn exercise(c: &DistroStreamClient) {
        let id = c
            .register(Some("s".into()), StreamType::Object, 2, None, ConsumerMode::ExactlyOnce)
            .unwrap();
        // Alias dedupe.
        let id2 = c
            .register(Some("s".into()), StreamType::Object, 2, None, ConsumerMode::ExactlyOnce)
            .unwrap();
        assert_eq!(id, id2);
        c.add_producer(id, "p").unwrap();
        c.add_consumer(id, "c").unwrap();
        assert!(!c.is_closed(id).unwrap());
        c.close_producer(id, "p").unwrap();
        assert!(c.is_closed(id).unwrap());
        // Cached terminal answer (works even if we unregister the stream
        // behind the cache's back).
        assert!(c.is_closed(id).unwrap());
        let info = c.info(id).unwrap();
        assert_eq!(info.producers, 1);
        assert_eq!(info.consumers, 1);
        assert!(info.closed);
        c.unregister(id).unwrap();
        assert!(matches!(c.is_closed(id), Err(DStreamError::UnknownStream(_))));
    }

    #[test]
    fn embedded_flow() {
        let reg = Arc::new(Mutex::new(StreamRegistry::new()));
        exercise(&DistroStreamClient::embedded(reg));
    }

    #[test]
    fn remote_flow() {
        let server = DistroStreamServer::start("127.0.0.1:0").unwrap();
        let client = DistroStreamClient::connect(&server.addr.to_string()).unwrap();
        client.ping().unwrap();
        exercise(&client);
        server.shutdown();
    }

    #[test]
    fn two_clients_share_server_state() {
        let server = DistroStreamServer::start("127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let a = DistroStreamClient::connect(&addr).unwrap();
        let b = DistroStreamClient::connect(&addr).unwrap();
        let id = a
            .register(
                Some("x".into()),
                StreamType::File,
                1,
                Some("/d".into()),
                ConsumerMode::ExactlyOnce,
            )
            .unwrap();
        // b sees the same stream through the alias.
        let id_b = b
            .register(
                Some("x".into()),
                StreamType::File,
                1,
                Some("/d".into()),
                ConsumerMode::ExactlyOnce,
            )
            .unwrap();
        assert_eq!(id, id_b);
        // File dedup is global across clients.
        assert_eq!(
            a.poll_files(id, vec!["f1".into()], usize::MAX, 0).unwrap(),
            vec!["f1".to_string()]
        );
        assert!(b.poll_files(id, vec!["f1".into()], usize::MAX, 0).unwrap().is_empty());
        // Announce → the other client's poll sees the path with no scan.
        a.announce_file(id, "f2").unwrap();
        assert_eq!(b.poll_files(id, vec![], usize::MAX, 0).unwrap(), vec!["f2".to_string()]);
        server.shutdown();
    }
}
