//! The **DistroStream Client** (paper §4.3): the per-process broker of all
//! stream metadata requests.
//!
//! "The client is used to forward any stream metadata request to the
//! DistroStream Server [...] To avoid repeated queries to the server, the
//! client stores the retrieved metadata in a cache-like fashion."
//!
//! Our cache keeps *terminal* answers only — a stream that reports closed
//! stays closed forever, so `is_closed == true` is cached and every later
//! query is served locally; `false` answers always go to the server (they
//! can be invalidated at any time by a producer closing).

use std::collections::HashSet;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};

use super::api::{ConsumerMode, DStreamError, Result, StreamId, StreamType};
use super::protocol::{DsRequest, DsResponse, StreamInfoWire};
use super::server::{dispatch, StreamRegistry};
use crate::util::wire::{recv_msg, send_msg};

enum Transport {
    /// Shared in-process registry (single-node deployments, tests).
    Embedded(Arc<Mutex<StreamRegistry>>),
    /// Framed TCP to a remote [`super::server::DistroStreamServer`].
    ///
    /// Long-poll `PollFiles` requests travel over a **separate**
    /// lazily-opened socket (`poll_sock`): a consumer parked server-side
    /// must not block `announce_file` (the very frame that would wake it)
    /// or other metadata calls from threads sharing the client.
    Remote { sock: Mutex<TcpStream>, addr: String, poll_sock: Mutex<Option<TcpStream>> },
}

/// Per-process client with a terminal-answer metadata cache.
pub struct DistroStreamClient {
    transport: Transport,
    /// Streams known to be completely closed (terminal).
    closed_cache: Mutex<HashSet<StreamId>>,
}

impl DistroStreamClient {
    pub fn embedded(registry: Arc<Mutex<StreamRegistry>>) -> Self {
        Self { transport: Transport::Embedded(registry), closed_cache: Mutex::new(HashSet::new()) }
    }

    pub fn connect(addr: &str) -> Result<Self> {
        let sock = TcpStream::connect(addr)
            .map_err(|e| DStreamError::Transport(format!("connect {addr}: {e}")))?;
        sock.set_nodelay(true).ok();
        Ok(Self {
            transport: Transport::Remote {
                sock: Mutex::new(sock),
                addr: addr.to_string(),
                poll_sock: Mutex::new(None),
            },
            closed_cache: Mutex::new(HashSet::new()),
        })
    }

    fn roundtrip(sock: &mut TcpStream, req: &DsRequest) -> Result<DsResponse> {
        send_msg(sock, req).map_err(|e| DStreamError::Transport(format!("send: {e}")))?;
        match recv_msg(sock) {
            Ok(Some(resp)) => Ok(resp),
            Ok(None) => Err(DStreamError::Transport("server closed connection".into())),
            Err(e) => Err(DStreamError::Transport(format!("recv: {e}"))),
        }
    }

    fn rpc(&self, req: DsRequest) -> Result<DsResponse> {
        match &self.transport {
            Transport::Embedded(reg) => Ok(dispatch(reg, req)),
            Transport::Remote { sock, .. } => {
                let mut sock = sock.lock().unwrap();
                Self::roundtrip(&mut sock, &req)
            }
        }
    }

    /// One request over the dedicated long-poll socket (remote only;
    /// opened on first use).
    fn poll_rpc(&self, req: DsRequest) -> Result<DsResponse> {
        let Transport::Remote { addr, poll_sock, .. } = &self.transport else {
            unreachable!("poll_rpc is remote-only");
        };
        let mut slot = poll_sock.lock().unwrap();
        if slot.is_none() {
            let sock = TcpStream::connect(addr)
                .map_err(|e| DStreamError::Transport(format!("connect {addr}: {e}")))?;
            sock.set_nodelay(true).ok();
            *slot = Some(sock);
        }
        let sock = slot.as_mut().expect("poll socket just ensured");
        let resp = Self::roundtrip(sock, &req);
        if resp.is_err() {
            // Drop a broken socket so the next long-poll reconnects.
            *slot = None;
        }
        resp
    }

    fn expect_ok(&self, req: DsRequest) -> Result<()> {
        match self.rpc(req)? {
            DsResponse::Ok => Ok(()),
            DsResponse::Unknown(id) => Err(DStreamError::UnknownStream(id)),
            other => Err(DStreamError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Register (or look up by alias) a stream; returns its id.
    pub fn register(
        &self,
        alias: Option<String>,
        stype: StreamType,
        partitions: usize,
        base_dir: Option<String>,
        mode: ConsumerMode,
    ) -> Result<StreamId> {
        match self.rpc(DsRequest::Register { alias, stype, partitions, base_dir, mode })? {
            DsResponse::Registered(id) => Ok(id),
            other => Err(DStreamError::Registration(format!("unexpected response {other:?}"))),
        }
    }

    pub fn add_producer(&self, id: StreamId, name: &str) -> Result<()> {
        self.expect_ok(DsRequest::AddProducer { id, name: name.into() })
    }

    pub fn add_consumer(&self, id: StreamId, name: &str) -> Result<()> {
        self.expect_ok(DsRequest::AddConsumer { id, name: name.into() })
    }

    pub fn close_producer(&self, id: StreamId, name: &str) -> Result<()> {
        self.expect_ok(DsRequest::CloseProducer { id, name: name.into() })
    }

    pub fn close_stream(&self, id: StreamId) -> Result<()> {
        self.expect_ok(DsRequest::CloseStream { id })
    }

    /// Completely closed? Cached once true.
    pub fn is_closed(&self, id: StreamId) -> Result<bool> {
        if self.closed_cache.lock().unwrap().contains(&id) {
            return Ok(true);
        }
        match self.rpc(DsRequest::IsClosed { id })? {
            DsResponse::Bool(true) => {
                self.closed_cache.lock().unwrap().insert(id);
                Ok(true)
            }
            DsResponse::Bool(false) => Ok(false),
            DsResponse::Unknown(id) => Err(DStreamError::UnknownStream(id)),
            other => Err(DStreamError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// FDS dedup poll: claim up to `max` undelivered candidates (see
    /// server docs). `wait_ms > 0` parks at the server until a producer
    /// announces a new file or the deadline passes — no client-side
    /// sleeping. The server clamps one park (callers with longer budgets
    /// re-issue, rescanning their directory in between).
    pub fn poll_files(
        &self,
        id: StreamId,
        candidates: Vec<String>,
        max: usize,
        wait_ms: u64,
    ) -> Result<Vec<String>> {
        let req = DsRequest::PollFiles { id, candidates, max, wait_ms };
        // Waiting polls park server-side: keep them off the shared
        // metadata socket so they can't block the announce that wakes them.
        let resp = match (&self.transport, wait_ms) {
            (Transport::Remote { .. }, w) if w > 0 => self.poll_rpc(req)?,
            _ => self.rpc(req)?,
        };
        match resp {
            DsResponse::Files(fs) => Ok(fs),
            DsResponse::Unknown(id) => Err(DStreamError::UnknownStream(id)),
            other => Err(DStreamError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// FDS: announce a freshly published file (canonical path) so parked
    /// consumers wake immediately instead of on their next rescan tick.
    pub fn announce_file(&self, id: StreamId, path: &str) -> Result<()> {
        self.expect_ok(DsRequest::AnnounceFile { id, path: path.into() })
    }

    pub fn info(&self, id: StreamId) -> Result<StreamInfoWire> {
        match self.rpc(DsRequest::Info { id })? {
            DsResponse::Info(i) => Ok(i),
            DsResponse::Unknown(id) => Err(DStreamError::UnknownStream(id)),
            other => Err(DStreamError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    pub fn unregister(&self, id: StreamId) -> Result<()> {
        self.closed_cache.lock().unwrap().remove(&id);
        self.expect_ok(DsRequest::Unregister { id })
    }

    pub fn ping(&self) -> Result<()> {
        match self.rpc(DsRequest::Ping)? {
            DsResponse::Pong => Ok(()),
            other => Err(DStreamError::Transport(format!("unexpected response {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dstream::server::DistroStreamServer;

    fn exercise(c: &DistroStreamClient) {
        let id = c
            .register(Some("s".into()), StreamType::Object, 2, None, ConsumerMode::ExactlyOnce)
            .unwrap();
        // Alias dedupe.
        let id2 = c
            .register(Some("s".into()), StreamType::Object, 2, None, ConsumerMode::ExactlyOnce)
            .unwrap();
        assert_eq!(id, id2);
        c.add_producer(id, "p").unwrap();
        c.add_consumer(id, "c").unwrap();
        assert!(!c.is_closed(id).unwrap());
        c.close_producer(id, "p").unwrap();
        assert!(c.is_closed(id).unwrap());
        // Cached terminal answer (works even if we unregister the stream
        // behind the cache's back).
        assert!(c.is_closed(id).unwrap());
        let info = c.info(id).unwrap();
        assert_eq!(info.producers, 1);
        assert_eq!(info.consumers, 1);
        assert!(info.closed);
        c.unregister(id).unwrap();
        assert!(matches!(c.is_closed(id), Err(DStreamError::UnknownStream(_))));
    }

    #[test]
    fn embedded_flow() {
        let reg = Arc::new(Mutex::new(StreamRegistry::new()));
        exercise(&DistroStreamClient::embedded(reg));
    }

    #[test]
    fn remote_flow() {
        let server = DistroStreamServer::start("127.0.0.1:0").unwrap();
        let client = DistroStreamClient::connect(&server.addr.to_string()).unwrap();
        client.ping().unwrap();
        exercise(&client);
        server.shutdown();
    }

    #[test]
    fn two_clients_share_server_state() {
        let server = DistroStreamServer::start("127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let a = DistroStreamClient::connect(&addr).unwrap();
        let b = DistroStreamClient::connect(&addr).unwrap();
        let id = a
            .register(
                Some("x".into()),
                StreamType::File,
                1,
                Some("/d".into()),
                ConsumerMode::ExactlyOnce,
            )
            .unwrap();
        // b sees the same stream through the alias.
        let id_b = b
            .register(
                Some("x".into()),
                StreamType::File,
                1,
                Some("/d".into()),
                ConsumerMode::ExactlyOnce,
            )
            .unwrap();
        assert_eq!(id, id_b);
        // File dedup is global across clients.
        assert_eq!(
            a.poll_files(id, vec!["f1".into()], usize::MAX, 0).unwrap(),
            vec!["f1".to_string()]
        );
        assert!(b.poll_files(id, vec!["f1".into()], usize::MAX, 0).unwrap().is_empty());
        // Announce → the other client's poll sees the path with no scan.
        a.announce_file(id, "f2").unwrap();
        assert_eq!(b.poll_files(id, vec![], usize::MAX, 0).unwrap(), vec!["f2".to_string()]);
        server.shutdown();
    }
}
