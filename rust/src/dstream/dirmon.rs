//! Directory monitor — the FDS backend (paper §4.2.2).
//!
//! "A custom implementation that monitors the creation of files inside a
//! given directory. The Directory Monitor backend sends the file locations
//! through the stream and relies on a distributed file system to share the
//! file content."
//!
//! We scan on demand (each `poll`) instead of inotify: std-only, portable,
//! and the dedup lives in the DistroStream Server so that *all* clients
//! (processes) share one delivered-set, like a shared GPFS directory.

use std::path::{Path, PathBuf};

/// Scan `dir` for regular files, sorted by (mtime, name) so delivery order
/// approximates creation order. Non-recursive, mirrors the paper's backend.
///
/// Files whose name starts with `.` or ends with [`TMP_SUFFIX`] are skipped:
/// producers write `name.tmp` then rename, so consumers never observe
/// partially-written files.
pub fn scan_dir(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut entries: Vec<(std::time::SystemTime, PathBuf)> = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let meta = match entry.metadata() {
            Ok(m) => m,
            Err(_) => continue, // raced with deletion
        };
        if !meta.is_file() {
            continue;
        }
        if let Some(name) = path.file_name().and_then(|n| n.to_str()) {
            if name.starts_with('.') || name.ends_with(TMP_SUFFIX) {
                continue;
            }
        }
        let mtime = meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
        entries.push((mtime, path));
    }
    entries.sort();
    Ok(entries.into_iter().map(|(_, p)| p).collect())
}

/// Suffix used for in-progress writes (see [`publish_file`]).
pub const TMP_SUFFIX: &str = ".inprogress";

/// Atomically create a file in a monitored directory: write to a hidden
/// temp name, then rename. Consumers polling concurrently either see the
/// complete file or nothing.
pub fn publish_file(dir: &Path, name: &str, contents: &[u8]) -> std::io::Result<PathBuf> {
    let tmp = dir.join(format!("{name}{TMP_SUFFIX}"));
    let fin = dir.join(name);
    std::fs::write(&tmp, contents)?;
    std::fs::rename(&tmp, &fin)?;
    Ok(fin)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hybridws-dirmon-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn scan_lists_only_complete_regular_files() {
        let d = tmpdir("scan");
        publish_file(&d, "a.dat", b"1").unwrap();
        publish_file(&d, "b.dat", b"2").unwrap();
        std::fs::write(d.join(format!("c.dat{TMP_SUFFIX}")), b"partial").unwrap();
        std::fs::write(d.join(".hidden"), b"x").unwrap();
        std::fs::create_dir(d.join("subdir")).unwrap();
        let got = scan_dir(&d).unwrap();
        let names: Vec<_> =
            got.iter().map(|p| p.file_name().unwrap().to_str().unwrap().to_string()).collect();
        assert_eq!(names.len(), 2);
        assert!(names.contains(&"a.dat".to_string()));
        assert!(names.contains(&"b.dat".to_string()));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn publish_is_atomic_rename() {
        let d = tmpdir("atomic");
        let p = publish_file(&d, "x.bin", &[1, 2, 3]).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), vec![1, 2, 3]);
        assert!(!d.join(format!("x.bin{TMP_SUFFIX}")).exists());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn scan_missing_dir_errors() {
        assert!(scan_dir(Path::new("/definitely/not/here")).is_err());
    }
}
