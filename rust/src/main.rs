//! `hybridws` — the launcher binary.
//!
//! Subcommands:
//!
//! - `run <uc1|uc2|uc3|uc4>` — run a use-case workload on a local runtime.
//! - `worker --listen <addr> --slots N` — serve as a remote worker process.
//! - `broker --listen <addr>` — run a standalone stream-broker server
//!   (`--cluster-seed` for static membership, `--join <seed>` to join a
//!   running cluster live — PR 10).
//! - `drain <addr>` — decommission a cluster member: it hands every owned
//!   partition off under a fenced migration, then leaves the spec (PR 10).
//! - `dstream-server --listen <addr>` — run a standalone DistroStream Server.
//! - `stats --brokers <addrs>` — scrape and render broker metrics (PR 8).
//! - `trace --brokers <addrs>` — merge broker span rings into stitched
//!   trace timelines (PR 9).
//! - `info` — registered task functions + AOT model inventory.

use std::net::TcpListener;

use hybridws::apps;
use hybridws::broker::{
    BrokerConfig, BrokerCore, BrokerServer, ClusterSpec, ClusterView, Retention, StorageMode,
};
use hybridws::coordinator::api::CometRuntime;
use hybridws::coordinator::remote::serve_worker;
use hybridws::dstream::DistroStreamServer;
use hybridws::util::cli::ArgSpec;
use hybridws::util::timeutil::TimeScale;

fn main() {
    hybridws::util::logging::init();
    apps::register_all();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    };
    let code = match cmd {
        "run" => cmd_run(&rest),
        "worker" => cmd_worker(&rest),
        "broker" => cmd_broker(&rest),
        "drain" => cmd_drain(&rest),
        "dstream-server" => cmd_dstream(&rest),
        "stats" => cmd_stats(&rest),
        "trace" => cmd_trace(&rest),
        "info" => cmd_info(),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            0
        }
        other => {
            eprintln!("unknown command {other:?}\n{}", usage());
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    format!(
        "hybridws {} — Hybrid Workflows (task-based + dataflows)\n\n\
         USAGE: hybridws <COMMAND> [OPTIONS]\n\n\
         COMMANDS:\n  \
           run <uc1|uc2|uc3|uc4>   run a use-case workload locally (--data-dir durable streams, --cluster scale-out)\n  \
           worker                  serve as a remote worker (--listen, --slots)\n  \
           broker                  broker server (--listen, --data-dir, --retention-*, --cluster-seed for sharding, --join <seed> for live join, --metrics-addr for Prometheus)\n  \
           drain <addr>            decommission a cluster member: fenced handoff of every owned partition, then leave the spec\n  \
           dstream-server          standalone DistroStream Server (--listen)\n  \
           stats                   scrape broker metrics (--brokers, --watch) into one cluster-wide snapshot\n  \
           trace                   merge broker span rings (--brokers) into stitched trace timelines (--trace-id, --slow-ms, --self-test)\n  \
           info                    registered tasks + AOT models",
        hybridws::version()
    )
}

fn parse_or_exit(spec: ArgSpec, raw: &[String]) -> hybridws::util::cli::Args {
    match spec.parse(raw) {
        Ok(a) => a,
        Err(help) => {
            eprintln!("{help}");
            std::process::exit(2);
        }
    }
}

fn cmd_run(raw: &[String]) -> i32 {
    let spec = ArgSpec::new("run a use-case workload")
        .positional("usecase", "one of uc1, uc2, uc3, uc4")
        .opt("workers", Some("8,8"), "core slots per worker (comma list)")
        .opt("scale", Some("0.02"), "paper-time scale factor")
        .opt("data-dir", None, "durable streams: persist broker topics under this directory")
        .opt(
            "cluster",
            None,
            "scale-out streams: comma list of broker cluster seed addresses \
             (each started with `hybridws broker --cluster-seed <same list>`)",
        )
        .flag("models", "load AOT artifacts (requires `make artifacts`)");
    let a = parse_or_exit(spec, raw);
    let workers = a.usize_list("workers");
    let scale = TimeScale::new(a.f64("scale"));
    let mut builder = CometRuntime::builder().workers(&workers).scale(scale);
    if let Some(dir) = a.get("data-dir") {
        // Flip the embedded broker to StorageMode::Disk: stream records and
        // consumer-group offsets survive a restart of this process.
        builder = builder.data_dir(dir);
    }
    if let Some(seeds) = a.get("cluster") {
        let seeds: Vec<&str> = seeds.split(',').filter(|s| !s.is_empty()).collect();
        builder = builder.cluster(&seeds);
    }
    if a.flag("models") {
        builder = builder.with_models();
    }
    let rt = match builder.build() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("failed to build runtime: {e}");
            return 1;
        }
    };
    let result = match a.positional(0).unwrap_or("uc1") {
        "uc1" => {
            let cfg = apps::uc1_simulation::Uc1Config::default();
            apps::uc1_simulation::run_task_based(&rt, &cfg).and_then(|tb| {
                let hy = apps::uc1_simulation::run_hybrid(&rt, &cfg)?;
                println!(
                    "uc1: task-based {:.2}s, hybrid {:.2}s, gain {:.1}%",
                    tb.elapsed_s,
                    hy.elapsed_s,
                    apps::uc1_simulation::gain(tb.elapsed_s, hy.elapsed_s) * 100.0
                );
                Ok(())
            })
        }
        "uc2" => {
            let cfg = apps::uc2_sweep::Uc2Config::default();
            apps::uc2_sweep::run_task_based(&rt, &cfg).and_then(|tb| {
                let hy = apps::uc2_sweep::run_hybrid(&rt, &cfg)?;
                println!(
                    "uc2: task-based {:.2}s, hybrid {:.2}s, gain {:.1}%",
                    tb.elapsed_s,
                    hy.elapsed_s,
                    (tb.elapsed_s - hy.elapsed_s) / tb.elapsed_s * 100.0
                );
                Ok(())
            })
        }
        "uc3" => apps::uc3_sensor::run(&rt, &apps::uc3_sensor::Uc3Config::default()).map(|r| {
            println!("uc3: {:.2}s, per-filter {:?}", r.elapsed_s, r.per_filter);
        }),
        "uc4" => apps::uc4_nested::run(&rt, &apps::uc4_nested::Uc4Config::default()).map(|r| {
            println!("uc4: {:.2}s, {} batches", r.elapsed_s, r.batches);
        }),
        other => {
            eprintln!("unknown use case {other:?}");
            return 2;
        }
    };
    let code = match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("run failed: {e}");
            1
        }
    };
    rt.shutdown().ok();
    code
}

fn cmd_worker(raw: &[String]) -> i32 {
    let spec = ArgSpec::new("serve as a remote worker process")
        .opt("listen", Some("127.0.0.1:7070"), "address to listen on")
        .opt("slots", Some("4"), "core slots");
    let a = parse_or_exit(spec, raw);
    let listener = match TcpListener::bind(a.str("listen")) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("bind {}: {e}", a.str("listen"));
            return 1;
        }
    };
    match serve_worker(listener, a.usize("slots")) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("worker failed: {e}");
            1
        }
    }
}

fn cmd_broker(raw: &[String]) -> i32 {
    let spec = ArgSpec::new("stream-broker server (standalone or cluster member)")
        .opt("listen", Some("127.0.0.1:9092"), "address to listen on")
        .opt("data-dir", None, "durable topics: segmented logs + offset journal under this dir")
        .opt("segment-mb", Some("8"), "segment size in MiB (disk mode)")
        .opt("retention-mb", Some("0"), "drop oldest sealed segments past this many MiB (0 = keep)")
        .opt(
            "retention-min",
            Some("0"),
            "drop sealed segments older than this many minutes (0 = keep)",
        )
        .opt(
            "cluster-seed",
            None,
            "join a sharded cluster: comma list of ALL member addresses \
             (give every member the same list; this broker serves only the \
             partitions the placement function assigns to it)",
        )
        .opt(
            "join",
            None,
            "join a RUNNING cluster live (PR 10): the address of any current \
             member; this broker fetches the spec, pulls its rendezvous \
             share under fenced migration, then flips the epoch-bumped \
             membership everywhere (mutually exclusive with --cluster-seed)",
        )
        .opt(
            "advertise",
            None,
            "the address clients reach this member under (default: --listen); \
             must appear in --cluster-seed verbatim (with --join it is the \
             address gossiped to the cluster instead)",
        )
        .opt(
            "replication-factor",
            Some("1"),
            "replicas per partition (leader + followers, clamped to the \
             member count); above 1 the leader streams every append to its \
             followers and clients fail over on leader death",
        )
        .opt(
            "acks",
            Some("leader"),
            "publish acknowledgement level: 'leader' (ack on leader append) \
             or 'quorum' (hold acks until every in-sync follower confirms)",
        )
        .opt(
            "metrics-addr",
            None,
            "also serve this process's metrics as Prometheus text exposition \
             on this address (e.g. 127.0.0.1:9400); the same listener \
             answers /healthz liveness probes",
        )
        .opt(
            "trace-sample",
            Some("0"),
            "tracing plane (PR 9): probability [0,1] that a request starting \
             here opens a new trace (0 still records spans for sampled \
             contexts arriving over the wire)",
        )
        .opt(
            "trace-slow-ms",
            Some("0"),
            "log any finished root span slower than this many ms with its \
             full child breakdown (0 = off)",
        )
        .opt("trace-seed", Some("0"), "seed for the trace-id generator (reproducible runs)");
    let a = parse_or_exit(spec, raw);
    let trace_sample = a.f64("trace-sample");
    let trace_slow_ms = a.u64("trace-slow-ms");
    if trace_sample > 0.0 || trace_slow_ms > 0 {
        hybridws::util::trace::install(trace_sample, a.u64("trace-seed"));
        hybridws::util::trace::set_slow_ms(trace_slow_ms);
        println!("tracing: sample {trace_sample}, slow threshold {trace_slow_ms}ms");
    }
    let core = match a.get("data-dir") {
        None => BrokerCore::new(),
        Some(dir) => {
            let mut retention = Retention::keep_forever();
            if a.u64("retention-mb") > 0 {
                retention = retention.max_bytes(a.u64("retention-mb") * 1024 * 1024);
            }
            if a.u64("retention-min") > 0 {
                retention = retention.max_age_ms(a.u64("retention-min") * 60_000);
            }
            let mode = StorageMode::disk(dir)
                .segment_bytes(a.u64("segment-mb").max(1) * 1024 * 1024)
                .retention(retention);
            match BrokerCore::with_config(BrokerConfig::memory().default_mode(mode)) {
                Ok(core) => {
                    let recovered: u64 = core
                        .topic_names()
                        .iter()
                        .filter_map(|t| core.topic_stats(t).ok())
                        .map(|s| s.recovered_records)
                        .sum();
                    println!(
                        "durable broker: data-dir {dir}, {} topics recovered ({recovered} records)",
                        core.topic_names().len()
                    );
                    core
                }
                Err(e) => {
                    eprintln!("broker storage recovery failed: {e}");
                    return 1;
                }
            }
        }
    };
    let listen = a.str("listen");
    let acks = match a.str("acks") {
        "leader" => hybridws::broker::protocol::ACKS_LEADER,
        "quorum" => hybridws::broker::protocol::ACKS_QUORUM,
        other => {
            eprintln!("--acks must be 'leader' or 'quorum', got {other:?}");
            return 2;
        }
    };
    if a.get("join").is_some() && a.get("cluster-seed").is_some() {
        eprintln!("--join and --cluster-seed are mutually exclusive: --cluster-seed boots a \
                   static cluster, --join enters a running one");
        return 2;
    }
    let server = if let Some(seed) = a.get("join") {
        // Live join (PR 10): fetch the running cluster's spec from any
        // member, start serving as a *joining* view (owning nothing, so no
        // routed traffic arrives early), then pull our rendezvous share
        // under fenced migration and flip the epoch-bumped spec everywhere.
        let advertise = a.get("advertise").unwrap_or(listen).to_string();
        let wire = match hybridws::broker::BrokerClient::connect(seed)
            .and_then(|c| c.cluster_meta())
        {
            Ok(w) => w,
            Err(e) => {
                eprintln!("join: seed {seed} unreachable: {e}");
                return 1;
            }
        };
        if wire.members.is_empty() {
            eprintln!("join: seed {seed} is not running in cluster mode");
            return 2;
        }
        let cur = ClusterSpec::from_wire(&wire);
        println!(
            "joining cluster {:?} (epoch {}) via {seed} as {advertise}",
            cur.members(),
            cur.epoch
        );
        match TcpListener::bind(listen) {
            Ok(listener) => BrokerServer::start_cluster(
                core,
                listener,
                ClusterView::new_joining(cur, advertise).with_default_acks(acks),
            )
            .map(|server| {
                let view = server.cluster_view().expect("cluster server carries a view");
                match hybridws::broker::cluster::migrate::join(&server.core(), view, seed) {
                    Ok((spec, moved)) => println!(
                        "joined at epoch {}: pulled {moved} partitions, {} members",
                        spec.epoch,
                        spec.len()
                    ),
                    // The server keeps running: a failed join leaves the
                    // old spec intact everywhere and the CLI can re-run
                    // the (idempotent) join against another seed.
                    Err(e) => eprintln!("join incomplete (retry with --join): {e}"),
                }
                server
            }),
            Err(e) => Err(e),
        }
    } else {
        match a.get("cluster-seed") {
            None => BrokerServer::start(core, listen),
            Some(seeds) => {
                let replication = a.usize("replication-factor").max(1);
                let spec = ClusterSpec::new(
                    seeds.split(',').filter(|s| !s.is_empty()).map(str::to_string),
                )
                .with_replication(replication);
                let advertise = a.get("advertise").unwrap_or(listen).to_string();
                if !spec.contains(&advertise) {
                    eprintln!(
                        "--advertise {advertise:?} is not in --cluster-seed {:?} — every member \
                         must appear in the shared seed list verbatim",
                        spec.members()
                    );
                    return 2;
                }
                println!(
                    "cluster member {advertise} of {:?} (owner-routed sharding, \
                     replication {}, acks={})",
                    spec.members(),
                    spec.replication(),
                    a.str("acks"),
                );
                match TcpListener::bind(listen) {
                    Ok(listener) => BrokerServer::start_cluster(
                        core,
                        listener,
                        ClusterView::new(spec, advertise).with_default_acks(acks),
                    ),
                    Err(e) => Err(e),
                }
            }
        }
    };
    match server {
        Ok(server) => {
            println!("broker listening on {}", server.addr);
            // Exported spans carry the broker's address as their node
            // label; /healthz reports the same identity plus the start
            // epoch so probes can detect restarts.
            hybridws::util::trace::set_node(&server.addr.to_string());
            let started = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0);
            hybridws::util::obs::set_identity(&format!(
                "broker {} epoch {started}",
                server.addr
            ));
            // Held for the process lifetime: dropping it would stop the
            // exposition listener.
            let _metrics_http = match a.get("metrics-addr") {
                None => None,
                Some(addr) => match hybridws::util::obs::serve_http(addr) {
                    Ok(h) => {
                        println!("metrics (Prometheus) on http://{}/metrics", h.local_addr());
                        Some(h)
                    }
                    Err(e) => {
                        eprintln!("metrics listener on {addr} failed: {e}");
                        return 1;
                    }
                },
            };
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("broker failed: {e}");
            1
        }
    }
}

/// `hybridws drain <addr>` — decommission one cluster member (PR 10): the
/// broker at `addr` hands every partition it owns to that partition's next
/// rendezvous owner under the fenced migration state machine, installs the
/// epoch-bumped spec without itself and gossips it. The process keeps
/// serving (it answers redirects and `SpecSync`) until killed.
fn cmd_drain(raw: &[String]) -> i32 {
    let spec = ArgSpec::new("decommission a cluster member via fenced live migration")
        .positional("addr", "the advertised address of the member to drain");
    let a = parse_or_exit(spec, raw);
    let Some(addr) = a.positional(0) else {
        eprintln!("drain: the member address is required (e.g. `hybridws drain 127.0.0.1:9093`)");
        return 2;
    };
    // An empty member means "drain yourself" — the broker substitutes its
    // own advertised address, so the CLI needs no spelling agreement.
    match hybridws::broker::BrokerClient::connect(addr).and_then(|c| c.drain_member("")) {
        Ok(moved) => {
            println!("drained {addr}: {moved} partitions handed off");
            0
        }
        Err(e) => {
            eprintln!("drain {addr} failed: {e}");
            1
        }
    }
}

fn cmd_dstream(raw: &[String]) -> i32 {
    let spec = ArgSpec::new("standalone DistroStream Server")
        .opt("listen", Some("127.0.0.1:9990"), "address to listen on");
    let a = parse_or_exit(spec, raw);
    match DistroStreamServer::start(a.str("listen")) {
        Ok(server) => {
            println!("DistroStream Server listening on {}", server.addr);
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("dstream-server failed: {e}");
            1
        }
    }
}

fn cmd_stats(raw: &[String]) -> i32 {
    let spec = ArgSpec::new("scrape broker metrics into one cluster-wide snapshot")
        .opt(
            "brokers",
            Some("127.0.0.1:9092"),
            "comma list of broker addresses to scrape (each one answers with \
             its process-wide registry; the snapshots are merged)",
        )
        .opt("interval-ms", Some("1000"), "refresh period with --watch")
        .flag("watch", "re-scrape and re-render every --interval-ms until killed")
        .flag("prometheus", "render Prometheus text exposition instead of the table");
    let a = parse_or_exit(spec, raw);
    let brokers: Vec<String> =
        a.str("brokers").split(',').filter(|s| !s.is_empty()).map(str::to_string).collect();
    if brokers.is_empty() {
        eprintln!("--brokers must name at least one address");
        return 2;
    }
    let watch = a.flag("watch");
    let interval = std::time::Duration::from_millis(a.u64("interval-ms").max(50));
    // Watch mode renders per-second deltas against the previous scrape
    // (counters and histogram counts as rates, gauges absolute); the
    // first iteration has no baseline and renders the absolute table.
    let mut prev: Option<(hybridws::util::obs::Snapshot, std::time::Instant)> = None;
    loop {
        let mut merged = hybridws::util::obs::Snapshot::default();
        let mut scraped = 0usize;
        for addr in &brokers {
            match hybridws::broker::BrokerClient::connect(addr).and_then(|c| c.metrics()) {
                Ok(snap) => {
                    merged.merge(&snap);
                    scraped += 1;
                }
                Err(e) => eprintln!("scrape {addr}: {e}"),
            }
        }
        if scraped == 0 {
            eprintln!("no broker answered");
            return 1;
        }
        if a.flag("prometheus") {
            print!("{}", merged.render_prometheus());
        } else {
            println!("== {scraped}/{} brokers ==", brokers.len());
            match &prev {
                Some((snap, at)) if watch => {
                    print!("{}", merged.render_text_delta(snap, at.elapsed().as_secs_f64()));
                }
                _ => print!("{}", merged.render_text()),
            }
        }
        if !watch {
            return 0;
        }
        prev = Some((merged, std::time::Instant::now()));
        std::thread::sleep(interval);
    }
}

/// `hybridws trace` — the stitched-timeline CLI (PR 9): drain every
/// broker's span flight recorder, merge, and render causally-linked
/// trees. `--self-test` additionally runs one fully-sampled publish +
/// poll through the first broker and renders the resulting trace — the
/// client-side spans live in *this* process's ring and are merged in.
fn cmd_trace(raw: &[String]) -> i32 {
    use hybridws::broker::BrokerClient;
    use hybridws::util::trace;

    let spec = ArgSpec::new("merge broker span rings into stitched trace timelines")
        .opt(
            "brokers",
            Some("127.0.0.1:9092"),
            "comma list of broker addresses whose span rings to merge",
        )
        .opt("trace-id", Some("0"), "render only this trace (decimal or 0x-prefixed hex; 0 = all)")
        .opt("slow-ms", Some("0"), "render only traces whose root span took at least this many ms")
        .flag(
            "self-test",
            "publish + poll one fully-traced record through the first broker \
             and render its stitched tree (exit 1 if the tree is incomplete)",
        );
    let a = parse_or_exit(spec, raw);
    let brokers: Vec<String> =
        a.str("brokers").split(',').filter(|s| !s.is_empty()).map(str::to_string).collect();
    if brokers.is_empty() {
        eprintln!("--brokers must name at least one address");
        return 2;
    }
    let raw_id = a.str("trace-id");
    let Some(mut trace_id) = parse_trace_id(raw_id) else {
        eprintln!("--trace-id must be decimal or 0x-prefixed hex, got {raw_id:?}");
        return 2;
    };
    let slow_us = a.u64("slow-ms") * 1000;

    let mut spans: Vec<trace::Span> = Vec::new();
    let self_test = a.flag("self-test");
    if self_test {
        trace::install(1.0, 0x7ace);
        trace::set_node("trace-cli");
        let topic = "trace-selftest";
        let group = "trace-selftest-g";
        let res = BrokerClient::connect(&brokers[0]).and_then(|client| {
            client.ensure_topic(topic, 1)?;
            client.join_group(group, topic, "m0", hybridws::broker::AssignmentMode::Shared)?;
            client.publish(topic, hybridws::broker::record::ProducerRecord::new(
                b"trace self-test".to_vec(),
            ))?;
            client.fetch_many_wait(group, topic, "m0", 16, usize::MAX, 2_000)
        });
        if let Err(e) = res {
            eprintln!("self-test workload failed: {e}");
            return 1;
        }
        // The client.publish root ran in this process — its ring seeds the
        // merge and pins the trace id to render.
        let local = trace::snapshot_wire(0);
        if trace_id == 0 {
            trace_id = local
                .iter()
                .find(|s| s.name == "client.publish")
                .map(|s| s.trace_id)
                .unwrap_or(0);
        }
        spans.extend(local);
    }

    let mut answered = 0usize;
    for addr in &brokers {
        match BrokerClient::connect(addr).and_then(|c| c.spans(trace_id)) {
            Ok(remote) => {
                spans.extend(remote);
                answered += 1;
            }
            Err(e) => eprintln!("spans {addr}: {e}"),
        }
    }
    if answered == 0 && !self_test {
        eprintln!("no broker answered");
        return 1;
    }
    if trace_id != 0 {
        spans.retain(|s| s.trace_id == trace_id);
    }
    print!("{}", trace::render_traces(&spans, slow_us));
    if self_test {
        // A complete self-test tree spans both processes: the client root
        // plus at least one broker-side span under the same trace id.
        let client_side = spans.iter().any(|s| s.name == "client.publish");
        let broker_side = spans.iter().any(|s| s.node != "trace-cli");
        if !(client_side && broker_side) {
            eprintln!(
                "self-test: incomplete trace (client span: {client_side}, \
                 broker spans: {broker_side}) — is the broker running with \
                 --trace-sample or --trace-slow-ms?"
            );
            return 1;
        }
        println!("self-test: stitched trace 0x{trace_id:016x} spans both processes");
    }
    0
}

/// Parse a trace id as decimal or `0x`-prefixed hex.
fn parse_trace_id(s: &str) -> Option<u64> {
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse().ok(),
    }
}

fn cmd_info() -> i32 {
    println!("hybridws {}", hybridws::version());
    println!("\nregistered task functions:");
    for name in hybridws::coordinator::executor::registered_names() {
        println!("  {name}");
    }
    match hybridws::runtime::find_artifacts_dir() {
        Some(dir) => match hybridws::runtime::ModelZoo::load(&dir) {
            Ok(zoo) => {
                println!("\nAOT models ({dir:?}):");
                for s in zoo.specs() {
                    println!("  {:<14} {:?} -> {:?}", s.name, s.inputs, s.output);
                }
            }
            Err(e) => println!("\nartifacts at {dir:?} failed to load: {e}"),
        },
        None => println!("\nno artifacts found (run `make artifacts`)"),
    }
    0
}
