//! The **Task Scheduler**: core-slot resource accounting + locality- and
//! stream-aware placement (paper §4.5).
//!
//! Policies (each individually switchable — benchmarked in
//! `benches/ablations.rs`):
//!
//! - **Data locality** (COMPSs default): a ready task prefers the worker
//!   already holding most of its input bytes.
//! - **Producer priority**: ready stream-producer tasks are placed before
//!   stream-consumer tasks "to avoid wasting resources when a consumer task
//!   is waiting for data to be produced by a non-running producer task".
//! - **Stream locality**: workers that run (or have run) producer tasks of
//!   a stream count as data locations for its consumers.

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, HashMap, HashSet};

use crate::dstream::api::StreamId;

use super::analyser::{TaskId, TaskRecord};
use super::data::{DataRegistry, Key, WorkerId};

/// Scheduler policy switches.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    pub locality: bool,
    pub producer_priority: bool,
    pub stream_locality: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { locality: true, producer_priority: true, stream_locality: true }
    }
}

/// Live slot accounting for one worker.
#[derive(Debug, Clone)]
pub struct WorkerSlots {
    pub id: WorkerId,
    pub total: usize,
    pub free: usize,
    pub alive: bool,
}

#[derive(Debug, Clone)]
struct PendingTask {
    id: TaskId,
    cores: usize,
    producer: bool,
    consumer: bool,
    explicit_priority: bool,
    input_keys: Vec<Key>,
    consumes: Vec<StreamId>,
    /// FIFO tiebreaker.
    seq: u64,
}

/// Min-heap entry ordered by (priority class, FIFO seq) — smallest first.
#[derive(Debug)]
struct ReadyEntry {
    class: u8,
    task: PendingTask,
}

impl PartialEq for ReadyEntry {
    fn eq(&self, other: &Self) -> bool {
        self.class == other.class && self.task.seq == other.task.seq
    }
}
impl Eq for ReadyEntry {}
impl PartialOrd for ReadyEntry {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for ReadyEntry {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // Reversed: BinaryHeap is a max-heap; we pop the smallest key.
        (other.class, other.task.seq).cmp(&(self.class, self.task.seq))
    }
}

/// One placement decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub task: TaskId,
    pub worker: WorkerId,
}

/// The scheduler: ready pool + worker slots + stream locations.
#[derive(Debug)]
pub struct TaskScheduler {
    cfg: SchedulerConfig,
    workers: Vec<WorkerSlots>,
    /// Ready pool: a priority heap (class, FIFO) — O(log n) per placement
    /// instead of a per-pass sort (the §Perf iteration-3 fix).
    ready: BinaryHeap<ReadyEntry>,
    /// Tasks popped but unplaceable right now (no worker has enough free
    /// slots); re-injected at the start of the next pass.
    overflow: Vec<ReadyEntry>,
    running: HashMap<TaskId, (WorkerId, usize)>,
    /// Workers that run (or ran) producers, per stream.
    stream_locations: HashMap<StreamId, HashSet<WorkerId>>,
    seq: u64,
}

impl TaskScheduler {
    /// `slots[i]` = core count of worker `i`.
    pub fn new(slots: &[usize], cfg: SchedulerConfig) -> Self {
        Self {
            cfg,
            workers: slots
                .iter()
                .enumerate()
                .map(|(id, &total)| WorkerSlots { id, total, free: total, alive: true })
                .collect(),
            ready: BinaryHeap::new(),
            overflow: Vec::new(),
            running: HashMap::new(),
            stream_locations: HashMap::new(),
            seq: 0,
        }
    }

    pub fn workers(&self) -> &[WorkerSlots] {
        &self.workers
    }

    pub fn ready_count(&self) -> usize {
        self.ready.len() + self.overflow.len()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// Where a task is currently running.
    pub fn location_of(&self, task: TaskId) -> Option<WorkerId> {
        self.running.get(&task).map(|&(w, _)| w)
    }

    /// Add a ready task to the pool.
    pub fn enqueue(&mut self, rec: &TaskRecord) {
        self.seq += 1;
        let task = PendingTask {
            id: rec.id,
            cores: rec.cores,
            producer: rec.is_stream_producer(),
            consumer: rec.is_stream_consumer(),
            explicit_priority: rec.explicit_priority,
            input_keys: rec.input_keys(),
            consumes: rec.consumes.clone(),
            seq: self.seq,
        };
        let class = self.class(&task);
        self.ready.push(ReadyEntry { class, task });
    }

    /// Priority class: lower sorts first. Producers (and explicit-priority
    /// tasks) precede plain tasks, which precede pure consumers.
    fn class(&self, t: &PendingTask) -> u8 {
        if t.explicit_priority || (self.cfg.producer_priority && t.producer) {
            0
        } else if self.cfg.producer_priority && t.consumer {
            2
        } else {
            1
        }
    }

    /// Locality score of placing `t` on `w` (higher is better).
    fn score(&self, t: &PendingTask, w: WorkerId, data: &DataRegistry) -> u64 {
        let mut s = 0;
        if self.cfg.locality {
            for k in &t.input_keys {
                if data.locations(*k).contains(&w) {
                    s += 1;
                }
            }
        }
        if self.cfg.stream_locality {
            for st in &t.consumes {
                if self.stream_locations.get(st).is_some_and(|ws| ws.contains(&w)) {
                    s += 1;
                }
            }
        }
        s
    }

    /// Greedy scheduling pass: place ready tasks (priority class, then
    /// FIFO) while free slots remain. O(placed × workers + log n).
    pub fn schedule(&mut self, data: &DataRegistry) -> Vec<Assignment> {
        let mut out = Vec::new();
        if self.free_slots() == 0 {
            return out;
        }
        // Re-inject tasks that were unplaceable last pass.
        for e in self.overflow.drain(..) {
            self.ready.push(e);
        }
        let mut stash: Vec<ReadyEntry> = Vec::new();
        while self.free_slots() > 0 {
            let Some(entry) = self.ready.pop() else { break };
            let t = &entry.task;
            // Best-scoring worker with enough free slots.
            let mut best: Option<(u64, WorkerId)> = None;
            for w in &self.workers {
                if !w.alive || w.free < t.cores {
                    continue;
                }
                let s = self.score(t, w.id, data);
                match best {
                    Some((bs, _)) if bs >= s => {}
                    _ => best = Some((s, w.id)),
                }
            }
            match best {
                Some((_, w)) => {
                    self.workers[w].free -= t.cores;
                    self.running.insert(t.id, (w, t.cores));
                    out.push(Assignment { task: t.id, worker: w });
                }
                // Doesn't fit anywhere right now (multi-core task): keep
                // scanning lower-priority tasks, retry next pass.
                None => stash.push(entry),
            }
        }
        self.overflow.extend(stash);
        out
    }

    /// Record that a scheduled producer task started on `worker` — its
    /// worker becomes a data location for its streams.
    pub fn note_producer_location(&mut self, streams: &[StreamId], worker: WorkerId) {
        self.note_producer_locations(streams.iter().map(|&s| (s, worker)));
    }

    /// Batched variant: apply a whole scheduling pass's stream-location
    /// updates in one call (the dispatcher collects them per pass).
    pub fn note_producer_locations(
        &mut self,
        updates: impl IntoIterator<Item = (StreamId, WorkerId)>,
    ) {
        for (s, w) in updates {
            self.stream_locations.entry(s).or_default().insert(w);
        }
    }

    /// Task finished (or was aborted): release its slots.
    pub fn release(&mut self, task: TaskId) {
        if let Some((w, cores)) = self.running.remove(&task) {
            if let Some(ws) = self.workers.get_mut(w) {
                ws.free = (ws.free + cores).min(ws.total);
            }
        }
    }

    /// Mark a worker dead; returns the tasks that were running there
    /// (to be resubmitted by the dispatcher).
    pub fn worker_down(&mut self, worker: WorkerId) -> Vec<TaskId> {
        if let Some(w) = self.workers.get_mut(worker) {
            w.alive = false;
            w.free = 0;
        }
        let lost: Vec<TaskId> = self
            .running
            .iter()
            .filter(|&(_, &(w, _))| w == worker)
            .map(|(&t, _)| t)
            .collect();
        for t in &lost {
            self.running.remove(t);
        }
        for ws in self.stream_locations.values_mut() {
            ws.remove(&worker);
        }
        lost
    }

    /// Bring a (new or restarted) worker online.
    pub fn worker_up(&mut self, worker: WorkerId, slots: usize) {
        if let Some(w) = self.workers.get_mut(worker) {
            w.alive = true;
            w.total = slots;
            w.free = slots;
        } else {
            debug_assert_eq!(worker, self.workers.len());
            self.workers.push(WorkerSlots { id: worker, total: slots, free: slots, alive: true });
        }
    }

    /// Total free slots across live workers.
    pub fn free_slots(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).map(|w| w.free).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::analyser::{ResolvedArg, TaskRecord};
    use crate::dstream::{BatchPolicy, ConsumerMode, StreamHandle, StreamType};

    fn rec(id: TaskId, cores: usize) -> TaskRecord {
        TaskRecord {
            id,
            name: format!("t{id}"),
            cores,
            explicit_priority: false,
            args: vec![],
            produces: vec![],
            consumes: vec![],
            attempts_left: 1,
        }
    }

    fn handle(id: StreamId) -> StreamHandle {
        StreamHandle {
            id,
            alias: None,
            stype: StreamType::Object,
            partitions: 1,
            base_dir: None,
            mode: ConsumerMode::ExactlyOnce,
            batch: BatchPolicy::default(),
        }
    }

    fn producer(id: TaskId, stream: StreamId) -> TaskRecord {
        let mut r = rec(id, 1);
        r.produces = vec![stream];
        r.args = vec![ResolvedArg::StreamOut(handle(stream))];
        r
    }

    fn consumer(id: TaskId, stream: StreamId) -> TaskRecord {
        let mut r = rec(id, 1);
        r.consumes = vec![stream];
        r.args = vec![ResolvedArg::StreamIn(handle(stream))];
        r
    }

    #[test]
    fn never_exceeds_slots() {
        let mut s = TaskScheduler::new(&[2, 1], SchedulerConfig::default());
        let data = DataRegistry::new();
        for i in 0..10 {
            s.enqueue(&rec(i, 1));
        }
        let placed = s.schedule(&data);
        assert_eq!(placed.len(), 3, "only 3 slots exist");
        assert_eq!(s.free_slots(), 0);
        assert_eq!(s.ready_count(), 7);
        // Releasing one slot lets one more run.
        s.release(placed[0].task);
        assert_eq!(s.schedule(&data).len(), 1);
    }

    #[test]
    fn multi_core_tasks_fit_only_where_room() {
        let mut s = TaskScheduler::new(&[4, 2], SchedulerConfig::default());
        let data = DataRegistry::new();
        s.enqueue(&rec(0, 3));
        let placed = s.schedule(&data);
        assert_eq!(placed, vec![Assignment { task: 0, worker: 0 }]);
        // A 3-core task cannot fit anywhere now.
        s.enqueue(&rec(1, 3));
        assert!(s.schedule(&data).is_empty());
    }

    #[test]
    fn producer_priority_orders_queue() {
        let mut s = TaskScheduler::new(&[1], SchedulerConfig::default());
        let data = DataRegistry::new();
        s.enqueue(&consumer(0, 9)); // submitted first
        s.enqueue(&producer(1, 9));
        let placed = s.schedule(&data);
        assert_eq!(placed[0].task, 1, "producer must be placed before consumer");
    }

    #[test]
    fn producer_priority_can_be_disabled() {
        let cfg = SchedulerConfig { producer_priority: false, ..Default::default() };
        let mut s = TaskScheduler::new(&[1], cfg);
        let data = DataRegistry::new();
        s.enqueue(&consumer(0, 9));
        s.enqueue(&producer(1, 9));
        assert_eq!(s.schedule(&data)[0].task, 0, "FIFO without producer priority");
    }

    #[test]
    fn data_locality_prefers_holding_worker() {
        let mut s = TaskScheduler::new(&[4, 4], SchedulerConfig::default());
        let mut data = DataRegistry::new();
        let d = data.register_value(vec![0; 8]);
        data.add_location((d, 0), 1); // replica on worker 1
        let mut r = rec(0, 1);
        r.args = vec![ResolvedArg::ObjIn((d, 0))];
        s.enqueue(&r);
        let placed = s.schedule(&data);
        assert_eq!(placed[0].worker, 1);
    }

    #[test]
    fn stream_locality_attracts_consumers() {
        let mut s = TaskScheduler::new(&[4, 4], SchedulerConfig::default());
        let data = DataRegistry::new();
        s.note_producer_location(&[9], 1);
        s.enqueue(&consumer(0, 9));
        let placed = s.schedule(&data);
        assert_eq!(placed[0].worker, 1, "consumer should co-locate with producer");
    }

    #[test]
    fn worker_down_reclaims_and_reports() {
        let mut s = TaskScheduler::new(&[2, 2], SchedulerConfig::default());
        let data = DataRegistry::new();
        for i in 0..4 {
            s.enqueue(&rec(i, 1));
        }
        let placed = s.schedule(&data);
        assert_eq!(placed.len(), 4);
        let victim = placed[0].worker;
        let lost = s.worker_down(victim);
        assert_eq!(lost.len(), 2);
        assert_eq!(s.free_slots(), 0, "dead worker contributes nothing");
        s.worker_up(victim, 2);
        assert_eq!(s.free_slots(), 2);
    }

    #[test]
    fn release_is_idempotent_and_capped() {
        let mut s = TaskScheduler::new(&[1], SchedulerConfig::default());
        let data = DataRegistry::new();
        s.enqueue(&rec(0, 1));
        let placed = s.schedule(&data);
        s.release(placed[0].task);
        s.release(placed[0].task); // double release must not overflow
        assert_eq!(s.free_slots(), 1);
    }
}
