//! In-process workers: a core-slot thread pool + local object store + the
//! task execution path (transfer → run → report).
//!
//! Each worker models one node of the paper's testbed: `slots` core slots
//! (the scheduler never over-commits them), a local replica store (data
//! locality), its own DistroStream hub identity (consumer-group member) and
//! a shared PJRT model zoo. Input objects not present locally are
//! *transferred* — a real byte copy, plus an optional bandwidth-model delay
//! — so Fig 23/24's size-dependent costs are physical, not simulated.
//!
//! The [`WorkerHandle`] trait abstracts placement targets: the dispatcher
//! drives [`LocalWorker`]s (threads in this process) and
//! [`super::remote::RemoteWorker`]s (TCP processes) identically.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use log::debug;

use crate::dstream::DistroStreamHub;
use crate::runtime::ModelZoo;
use crate::util::threadpool::ThreadPool;
use crate::util::timeutil::TimeScale;

use super::analyser::{ResolvedArg, TaskRecord};
use super::data::{Key, WorkerId};
use super::dispatcher::Event;
use super::executor::{lookup_task_fn, CtxArg, TaskCtx};
use super::metrics::MetricsRegistry;
use super::tracing::TraceLog;

/// A placement target the dispatcher can run jobs on.
pub trait WorkerHandle: Send + Sync {
    fn wid(&self) -> WorkerId;
    fn slot_count(&self) -> usize;
    /// Enqueue a job (must return promptly; execution is asynchronous).
    fn submit_job(&self, job: Job);
    /// Node-death simulation: silently drop all current and future jobs.
    fn mark_killed(&self);
    /// Orderly shutdown notification (remote workers close their session).
    fn disconnect(&self) {}
}

/// Network model for input transfers (on top of the physical byte copy).
#[derive(Debug, Clone, Copy, Default)]
pub struct TransferModel {
    /// Simulated link bandwidth; `None` = only the memcpy cost.
    pub bandwidth_mbps: Option<f64>,
}

impl TransferModel {
    fn delay(&self, bytes: usize) -> Option<std::time::Duration> {
        self.bandwidth_mbps
            .map(|mbps| std::time::Duration::from_secs_f64(bytes as f64 / (mbps * 1e6)))
    }
}

/// Scheduled failure injection: task name → remaining forced failures.
#[derive(Debug, Default)]
pub struct FailPlan {
    counts: Mutex<HashMap<String, u32>>,
}

impl FailPlan {
    /// Force the next `n` attempts of `name` to fail.
    pub fn fail_next(&self, name: &str, n: u32) {
        *self.counts.lock().unwrap().entry(name.to_string()).or_insert(0) += n;
    }

    /// Consume one scheduled failure for `name`.
    pub fn should_fail(&self, name: &str) -> bool {
        let mut counts = self.counts.lock().unwrap();
        match counts.get_mut(name) {
            Some(n) if *n > 0 => {
                *n -= 1;
                true
            }
            _ => false,
        }
    }
}

/// A dispatched task: the record plus the input values to transfer.
pub struct Job {
    pub record: TaskRecord,
    /// Values for inputs not already local to the worker.
    pub inputs: Vec<(Key, Arc<Vec<u8>>)>,
    pub attempt: u32,
}

/// Cheaply-cloneable execution context shared by a worker's pool threads.
#[derive(Clone)]
struct WorkerCore {
    id: WorkerId,
    store: Arc<Mutex<HashMap<Key, Arc<Vec<u8>>>>>,
    hub: Arc<DistroStreamHub>,
    zoo: Option<Arc<ModelZoo>>,
    trace: Arc<TraceLog>,
    metrics: Arc<MetricsRegistry>,
    events: mpsc::Sender<Event>,
    scale: TimeScale,
    transfer: TransferModel,
    fail_plan: Arc<FailPlan>,
    killed: Arc<AtomicBool>,
}

/// One in-process worker node.
pub struct LocalWorker {
    pub id: WorkerId,
    pub slots: usize,
    core: WorkerCore,
    pool: ThreadPool,
}

impl LocalWorker {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: WorkerId,
        slots: usize,
        hub: Arc<DistroStreamHub>,
        zoo: Option<Arc<ModelZoo>>,
        trace: Arc<TraceLog>,
        metrics: Arc<MetricsRegistry>,
        events: mpsc::Sender<Event>,
        scale: TimeScale,
        transfer: TransferModel,
        fail_plan: Arc<FailPlan>,
    ) -> Arc<Self> {
        Arc::new(Self {
            id,
            slots,
            core: WorkerCore {
                id,
                store: Arc::new(Mutex::new(HashMap::new())),
                hub,
                zoo,
                trace,
                metrics,
                events,
                scale,
                transfer,
                fail_plan,
                killed: Arc::new(AtomicBool::new(false)),
            },
            pool: ThreadPool::new(&format!("worker{id}"), slots.max(1)),
        })
    }

    /// Simulate node death: running/queued jobs produce no events.
    pub fn kill(&self) {
        self.core.killed.store(true, Ordering::SeqCst);
        self.core.store.lock().unwrap().clear();
    }

    pub fn revive(&self) {
        self.core.killed.store(false, Ordering::SeqCst);
    }

    pub fn fail_plan(&self) -> &Arc<FailPlan> {
        &self.core.fail_plan
    }

    /// Replicas currently held (diagnostics).
    pub fn store_len(&self) -> usize {
        self.core.store.lock().unwrap().len()
    }

    /// Enqueue a job on the worker's pool (returns immediately).
    pub fn execute(&self, job: Job) {
        let core = self.core.clone();
        self.pool.execute(move || core.run_job(job));
    }

    /// Block until all queued jobs drained (tests).
    pub fn wait_idle(&self) {
        self.pool.wait_idle();
    }
}

impl WorkerHandle for LocalWorker {
    fn wid(&self) -> WorkerId {
        self.id
    }
    fn slot_count(&self) -> usize {
        self.slots
    }
    fn submit_job(&self, job: Job) {
        self.execute(job);
    }
    fn mark_killed(&self) {
        self.kill();
    }
}

impl WorkerCore {
    fn run_job(&self, job: Job) {
        let task_id = job.record.id;
        let name = job.record.name.clone();

        // ---- transfer phase: localise inputs --------------------------------
        let t_transfer = Instant::now();
        for (key, value) in &job.inputs {
            let mut store = self.store.lock().unwrap();
            if !store.contains_key(key) {
                // The physical "transfer": one byte copy (serialisation) +
                // the optional bandwidth-model delay.
                let copied = value.as_ref().clone();
                if let Some(d) = self.transfer.delay(copied.len()) {
                    drop(store);
                    std::thread::sleep(d);
                    store = self.store.lock().unwrap();
                }
                store.insert(*key, Arc::new(copied));
            }
        }
        self.metrics.on_transfer(task_id, t_transfer.elapsed());

        // ---- failure injection ------------------------------------------------
        if self.fail_plan.should_fail(&name) {
            debug!("worker{} task {task_id} ({name}): injected failure", self.id);
            // Count the attempt even though the body never ran.
            self.metrics.on_exec(task_id, self.id, std::time::Duration::ZERO);
            self.finish(task_id, Vec::new(), Some(format!("injected failure in {name}")));
            return;
        }

        // ---- build the context -------------------------------------------------
        let mut out_keys: Vec<(usize, Key)> = Vec::new();
        let mut args = Vec::with_capacity(job.record.args.len());
        for (i, arg) in job.record.args.iter().enumerate() {
            match arg {
                ResolvedArg::ObjIn(k) => {
                    let Some(v) = self.store.lock().unwrap().get(k).cloned() else {
                        self.finish(task_id, Vec::new(), Some(format!("input {k:?} missing")));
                        return;
                    };
                    args.push(CtxArg::ObjIn(v));
                }
                ResolvedArg::ObjOut(k) => {
                    out_keys.push((i, *k));
                    args.push(CtxArg::ObjOut(None));
                }
                ResolvedArg::ObjInOut { read, write } => {
                    let Some(v) = self.store.lock().unwrap().get(read).cloned() else {
                        self.finish(task_id, Vec::new(), Some(format!("input {read:?} missing")));
                        return;
                    };
                    out_keys.push((i, *write));
                    args.push(CtxArg::ObjInOut { input: v, output: None });
                }
                ResolvedArg::FileIn(p) | ResolvedArg::FileOut(p) | ResolvedArg::FileInOut(p) => {
                    args.push(CtxArg::File(p.clone()));
                }
                ResolvedArg::StreamIn(h) | ResolvedArg::StreamOut(h) => {
                    args.push(CtxArg::Stream(h.clone()));
                }
                ResolvedArg::Scalar(v) => args.push(CtxArg::Scalar(v.clone())),
            }
        }

        let Some(f) = lookup_task_fn(&name) else {
            self.finish(task_id, Vec::new(), Some(format!("no task function registered: {name}")));
            return;
        };

        let mut ctx = TaskCtx {
            task_id,
            worker_id: self.id,
            cores: job.record.cores,
            attempt: job.attempt,
            args,
            hub: Arc::clone(&self.hub),
            zoo: self.zoo.clone(),
            scale: self.scale,
        };

        // ---- run ------------------------------------------------------------------
        let start_s = self.trace.now();
        let t_exec = Instant::now();
        let result = f(&mut ctx);
        let exec_dur = t_exec.elapsed();
        let end_s = self.trace.now();
        self.trace.record(self.id, task_id, &name, start_s, end_s);
        self.metrics.on_exec(task_id, self.id, exec_dur);

        match result {
            Ok(()) => match ctx.take_outputs() {
                Ok(outs) => {
                    let mut keyed = Vec::with_capacity(outs.len());
                    for (idx, bytes) in outs {
                        let key = out_keys
                            .iter()
                            .find(|&&(i, _)| i == idx)
                            .map(|&(_, k)| k)
                            .expect("output index mismatch");
                        let value = Arc::new(bytes);
                        self.store.lock().unwrap().insert(key, Arc::clone(&value));
                        keyed.push((key, value));
                    }
                    self.finish(task_id, keyed, None);
                }
                Err(e) => self.finish(task_id, Vec::new(), Some(e.to_string())),
            },
            Err(e) => {
                debug!("worker{} task {task_id} ({name}) failed: {e}", self.id);
                self.finish(task_id, Vec::new(), Some(e.to_string()));
            }
        }
    }

    fn finish(&self, task: u64, outputs: Vec<(Key, Arc<Vec<u8>>)>, error: Option<String>) {
        if self.killed.load(Ordering::SeqCst) {
            return; // dead workers don't talk
        }
        let _ = self.events.send(Event::Finished { task, worker: self.id, outputs, error });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::analyser::TaskRecord;
    use crate::coordinator::executor::register_task_fn;
    use crate::util::wire::Wire;

    fn record(id: u64, name: &str, args: Vec<ResolvedArg>) -> TaskRecord {
        TaskRecord {
            id,
            name: name.into(),
            cores: 1,
            explicit_priority: false,
            args,
            produces: vec![],
            consumes: vec![],
            attempts_left: 1,
        }
    }

    fn worker(events: mpsc::Sender<Event>) -> Arc<LocalWorker> {
        let (hub, _, _) = DistroStreamHub::embedded("w0");
        LocalWorker::new(
            0,
            2,
            hub,
            None,
            Arc::new(TraceLog::new()),
            Arc::new(MetricsRegistry::new()),
            events,
            TimeScale::IDENTITY,
            TransferModel::default(),
            Arc::new(FailPlan::default()),
        )
    }

    #[test]
    fn executes_and_reports_outputs() {
        register_task_fn("double", |ctx| {
            let v: u64 = ctx.obj_in_as(0)?;
            ctx.set_output_as(1, &(v * 2));
            Ok(())
        });
        let (tx, rx) = mpsc::channel();
        let w = worker(tx);
        w.execute(Job {
            record: record(
                1,
                "double",
                vec![ResolvedArg::ObjIn((0, 0)), ResolvedArg::ObjOut((1, 1))],
            ),
            inputs: vec![((0, 0), Arc::new(21u64.encode_vec()))],
            attempt: 1,
        });
        match rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap() {
            Event::Finished { task, outputs, error, .. } => {
                assert_eq!(task, 1);
                assert!(error.is_none(), "{error:?}");
                assert_eq!(outputs.len(), 1);
                assert_eq!(u64::decode_exact(&outputs[0].1).unwrap(), 42);
            }
            other => panic!("unexpected event {other:?}"),
        }
        assert_eq!(w.store_len(), 2, "input + output replicas retained");
    }

    #[test]
    fn missing_function_reports_error() {
        let (tx, rx) = mpsc::channel();
        let w = worker(tx);
        w.execute(Job { record: record(2, "not-registered", vec![]), inputs: vec![], attempt: 1 });
        match rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap() {
            Event::Finished { error: Some(e), .. } => assert!(e.contains("not-registered")),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn injected_failure_consumed_once() {
        register_task_fn("flaky", |_| Ok(()));
        let (tx, rx) = mpsc::channel();
        let w = worker(tx);
        w.fail_plan().fail_next("flaky", 1);
        for attempt in 1..=2 {
            w.execute(Job { record: record(attempt, "flaky", vec![]), inputs: vec![], attempt: 1 });
        }
        let mut errors = 0;
        for _ in 0..2 {
            if let Event::Finished { error, .. } =
                rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap()
            {
                errors += error.is_some() as u32;
            }
        }
        assert_eq!(errors, 1, "exactly one injected failure");
    }

    #[test]
    fn killed_worker_is_silent() {
        register_task_fn("noop", |_| Ok(()));
        let (tx, rx) = mpsc::channel();
        let w = worker(tx);
        w.kill();
        w.execute(Job { record: record(3, "noop", vec![]), inputs: vec![], attempt: 1 });
        w.wait_idle();
        assert!(rx.try_recv().is_err(), "killed worker must not emit events");
        assert_eq!(w.store_len(), 0, "kill clears the replica store");
    }

    #[test]
    fn task_error_propagates_message() {
        register_task_fn("boom", |_| anyhow::bail!("kaboom"));
        let (tx, rx) = mpsc::channel();
        let w = worker(tx);
        w.execute(Job { record: record(4, "boom", vec![]), inputs: vec![], attempt: 1 });
        match rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap() {
            Event::Finished { error: Some(e), .. } => assert!(e.contains("kaboom")),
            other => panic!("unexpected {other:?}"),
        }
    }
}
