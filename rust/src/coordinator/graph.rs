//! The task dependency graph (DAG): pending counts, successor lists,
//! readiness tracking and completion release.

use std::collections::{HashMap, HashSet};

use super::analyser::TaskId;

/// Lifecycle state of a task in the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// Waiting for dependencies.
    Blocked,
    /// Dependencies satisfied; queued for scheduling.
    Ready,
    /// Dispatched to a worker.
    Running,
    /// Finished successfully.
    Completed,
    /// Failed permanently (out of retries).
    Failed,
}

#[derive(Debug)]
struct Node {
    state: TaskState,
    pending: usize,
    successors: Vec<TaskId>,
}

/// The DAG. Nodes are added on analysis and removed only when completed
/// (COMPSs deletes tasks after completion; we keep terminal states for
/// diagnostics until [`TaskGraph::prune`]).
#[derive(Debug, Default)]
pub struct TaskGraph {
    nodes: HashMap<TaskId, Node>,
}

impl TaskGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a task with its dependency set; returns true if immediately
    /// ready. Dependencies on already-terminal (or unknown, i.e. pruned)
    /// tasks are ignored.
    pub fn add_task(&mut self, id: TaskId, deps: &HashSet<TaskId>) -> bool {
        let mut pending = 0;
        for &d in deps {
            let live = match self.nodes.get(&d) {
                Some(n) => !matches!(n.state, TaskState::Completed | TaskState::Failed),
                None => false,
            };
            if live {
                self.nodes.get_mut(&d).unwrap().successors.push(id);
                pending += 1;
            }
        }
        let ready = pending == 0;
        self.nodes.insert(
            id,
            Node {
                state: if ready { TaskState::Ready } else { TaskState::Blocked },
                pending,
                successors: Vec::new(),
            },
        );
        ready
    }

    pub fn state(&self, id: TaskId) -> Option<TaskState> {
        self.nodes.get(&id).map(|n| n.state)
    }

    pub fn set_running(&mut self, id: TaskId) {
        if let Some(n) = self.nodes.get_mut(&id) {
            debug_assert_eq!(n.state, TaskState::Ready, "task {id} not ready");
            n.state = TaskState::Running;
        }
    }

    /// Put a running task back to ready (resubmission after failure).
    pub fn set_ready(&mut self, id: TaskId) {
        if let Some(n) = self.nodes.get_mut(&id) {
            n.state = TaskState::Ready;
        }
    }

    /// Complete a task; returns the successors that became ready.
    pub fn complete(&mut self, id: TaskId) -> Vec<TaskId> {
        let successors = match self.nodes.get_mut(&id) {
            Some(n) => {
                n.state = TaskState::Completed;
                std::mem::take(&mut n.successors)
            }
            None => return Vec::new(),
        };
        let mut released = Vec::new();
        for s in successors {
            if let Some(n) = self.nodes.get_mut(&s) {
                n.pending -= 1;
                if n.pending == 0 && n.state == TaskState::Blocked {
                    n.state = TaskState::Ready;
                    released.push(s);
                }
            }
        }
        released
    }

    /// Mark a task permanently failed; returns the transitive closure of
    /// tasks that can now never run (cascaded failure).
    pub fn fail(&mut self, id: TaskId) -> Vec<TaskId> {
        let mut doomed = Vec::new();
        let mut stack = vec![id];
        while let Some(t) = stack.pop() {
            let successors = match self.nodes.get_mut(&t) {
                Some(n) if n.state != TaskState::Failed => {
                    n.state = TaskState::Failed;
                    if t != id {
                        doomed.push(t);
                    }
                    n.successors.clone()
                }
                _ => continue,
            };
            stack.extend(successors);
        }
        doomed
    }

    /// Count of tasks not yet terminal.
    pub fn active_count(&self) -> usize {
        self.nodes
            .values()
            .filter(|n| !matches!(n.state, TaskState::Completed | TaskState::Failed))
            .count()
    }

    /// Total nodes retained.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Drop terminal nodes (bounded memory for long-running apps).
    pub fn prune(&mut self) -> usize {
        let before = self.nodes.len();
        self.nodes.retain(|_, n| !matches!(n.state, TaskState::Completed | TaskState::Failed));
        before - self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::{check, ensure};
    use crate::util::rng::Rng;

    fn deps(ids: &[TaskId]) -> HashSet<TaskId> {
        ids.iter().copied().collect()
    }

    #[test]
    fn fan_out_release() {
        let mut g = TaskGraph::new();
        assert!(g.add_task(0, &deps(&[])));
        for i in 1..=5 {
            assert!(!g.add_task(i, &deps(&[0])));
        }
        g.set_running(0);
        let released = g.complete(0);
        assert_eq!(released.len(), 5);
        assert!(released.iter().all(|&t| g.state(t) == Some(TaskState::Ready)));
    }

    #[test]
    fn diamond_releases_only_when_all_deps_done() {
        let mut g = TaskGraph::new();
        g.add_task(0, &deps(&[]));
        g.add_task(1, &deps(&[0]));
        g.add_task(2, &deps(&[0]));
        g.add_task(3, &deps(&[1, 2]));
        g.complete(0);
        assert!(g.complete(1).is_empty(), "3 still waits on 2");
        assert_eq!(g.complete(2), vec![3]);
    }

    #[test]
    fn dep_on_completed_task_is_ignored() {
        let mut g = TaskGraph::new();
        g.add_task(0, &deps(&[]));
        g.complete(0);
        assert!(g.add_task(1, &deps(&[0])), "dep already completed → ready now");
    }

    #[test]
    fn dep_on_pruned_task_is_ignored() {
        let mut g = TaskGraph::new();
        g.add_task(0, &deps(&[]));
        g.complete(0);
        assert_eq!(g.prune(), 1);
        assert!(g.add_task(1, &deps(&[0])));
    }

    #[test]
    fn failure_cascades() {
        let mut g = TaskGraph::new();
        g.add_task(0, &deps(&[]));
        g.add_task(1, &deps(&[0]));
        g.add_task(2, &deps(&[1]));
        g.add_task(3, &deps(&[]));
        let doomed = g.fail(0);
        assert_eq!(doomed.len(), 2);
        assert_eq!(g.state(3), Some(TaskState::Ready), "independent task unaffected");
        assert_eq!(g.active_count(), 1);
    }

    #[test]
    fn resubmission_roundtrip() {
        let mut g = TaskGraph::new();
        g.add_task(0, &deps(&[]));
        g.set_running(0);
        g.set_ready(0); // retry
        assert_eq!(g.state(0), Some(TaskState::Ready));
        g.set_running(0);
        g.complete(0);
        assert_eq!(g.state(0), Some(TaskState::Completed));
    }

    #[test]
    fn prop_random_dag_executes_fully() {
        // Random DAGs (edges only to lower ids) always drain completely.
        check("random dag drains", |r: &mut Rng| {
            let n = r.range(1, 30);
            let mut edges: Vec<(u64, u64)> = Vec::new();
            for t in 1..n as u64 {
                for d in 0..t {
                    if r.chance(0.3) {
                        edges.push((t, d));
                    }
                }
            }
            edges
        }, |edges| {
            let n = edges.iter().map(|&(t, _)| t + 1).max().unwrap_or(1).max(1);
            let mut g = TaskGraph::new();
            let mut ready: Vec<TaskId> = Vec::new();
            for t in 0..n {
                let d: HashSet<TaskId> =
                    edges.iter().filter(|&&(x, _)| x == t).map(|&(_, y)| y).collect();
                if g.add_task(t, &d) {
                    ready.push(t);
                }
            }
            let mut done = 0;
            while let Some(t) = ready.pop() {
                g.set_running(t);
                ready.extend(g.complete(t));
                done += 1;
            }
            ensure(done == n as usize, "dag did not drain")?;
            ensure(g.active_count() == 0, "active tasks remain")
        });
    }
}
