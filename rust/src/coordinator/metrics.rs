//! Per-task lifecycle metrics — the instrumentation behind Fig 21–24 —
//! plus per-stream data-plane counters behind the Fig 19–20 batch-
//! efficiency reports.
//!
//! For every task we record the time spent in each runtime phase:
//! **analysis** (Task Analyser registration), **scheduling** (placement
//! decision), **transfer** (localising input parameters on the worker) and
//! **execution** (running the task body). Aggregations feed the overhead
//! benches and the live `runtime_stats` report.
//!
//! For every stream we record records / batches / bytes in each direction
//! (fed from the hubs' [`crate::dstream::StreamCounters`] via
//! `CometRuntime::stream_metrics`), so benches can report how many records
//! the batched data plane moves per broker round trip.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::dstream::api::StreamId;
use crate::util::trace::{self, TraceCtx};

use super::analyser::TaskId;

/// One task's phase timings (microseconds).
#[derive(Debug, Default, Clone)]
pub struct TaskMetrics {
    pub name: String,
    pub analysis_us: f64,
    pub schedule_us: f64,
    pub queue_us: f64,
    pub transfer_us: f64,
    pub exec_us: f64,
    pub total_us: f64,
    pub attempts: u32,
    pub worker: Option<usize>,
}

/// One stream's data-plane counters (records / batches / bytes, both
/// directions), aggregated across every hub of the deployment. The same
/// shape each hub collects — see [`crate::dstream::StreamCounters`] for
/// the fields and the `records_per_poll` / `records_per_publish`
/// batch-efficiency helpers.
pub type StreamStats = crate::dstream::StreamCounters;

/// Thread-safe metrics store.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    tasks: Mutex<HashMap<TaskId, TaskMetrics>>,
    streams: Mutex<HashMap<StreamId, StreamStats>>,
    /// Task-level trace roots (PR 9): opened at analysis, closed at
    /// completion, with each phase duration filed as a child span — so a
    /// task's lifecycle shows up in `hybridws trace` next to the broker
    /// spans its data plane produced.
    trace_roots: Mutex<HashMap<TaskId, TraceCtx>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// File one already-timed phase as a child span of the task's trace
    /// root (no-op for untraced tasks). The phase ended "now"; its start
    /// is back-dated by the measured duration.
    fn trace_phase(&self, id: TaskId, name: &'static str, d: Duration) {
        if !trace::enabled() {
            return;
        }
        let root =
            self.trace_roots.lock().unwrap().get(&id).copied().unwrap_or(TraceCtx::NONE);
        if root.sampled() {
            let d_us = (d.as_secs_f64() * 1e6) as u64;
            trace::record_at(root, name, trace::now_us().saturating_sub(d_us), d_us);
        }
    }

    pub fn on_analysis(&self, id: TaskId, name: &str, d: Duration) {
        crate::obs_hist!("task.analysis_us").observe(d);
        // A task's trace starts at analysis: one sampling draw decides
        // whether this task's whole lifecycle is recorded.
        if trace::enabled() {
            let root = trace::start_trace();
            if root.sampled() {
                self.trace_roots.lock().unwrap().insert(id, root);
            }
        }
        self.trace_phase(id, "task.analysis", d);
        let mut t = self.tasks.lock().unwrap();
        let m = t.entry(id).or_default();
        m.name = name.to_string();
        m.analysis_us = d.as_secs_f64() * 1e6;
    }

    pub fn on_schedule(&self, id: TaskId, d: Duration) {
        crate::obs_hist!("task.schedule_us").observe(d);
        self.trace_phase(id, "task.schedule", d);
        let mut t = self.tasks.lock().unwrap();
        t.entry(id).or_default().schedule_us += d.as_secs_f64() * 1e6;
    }

    pub fn on_queue(&self, id: TaskId, d: Duration) {
        crate::obs_hist!("task.queue_us").observe(d);
        self.trace_phase(id, "task.queue", d);
        let mut t = self.tasks.lock().unwrap();
        t.entry(id).or_default().queue_us = d.as_secs_f64() * 1e6;
    }

    pub fn on_transfer(&self, id: TaskId, d: Duration) {
        crate::obs_hist!("task.transfer_us").observe(d);
        self.trace_phase(id, "task.transfer", d);
        let mut t = self.tasks.lock().unwrap();
        t.entry(id).or_default().transfer_us += d.as_secs_f64() * 1e6;
    }

    pub fn on_exec(&self, id: TaskId, worker: usize, d: Duration) {
        crate::obs_hist!("task.exec_us").observe(d);
        self.trace_phase(id, "task.exec", d);
        let mut t = self.tasks.lock().unwrap();
        let m = t.entry(id).or_default();
        m.exec_us += d.as_secs_f64() * 1e6;
        m.worker = Some(worker);
        m.attempts += 1;
    }

    pub fn on_total(&self, id: TaskId, d: Duration) {
        crate::obs_hist!("task.total_us").observe(d);
        crate::obs_counter!("task.completed").inc();
        // Close the task's trace: the root span covers the whole
        // lifecycle and triggers the slow-request log when over budget.
        if let Some(root) = self.trace_roots.lock().unwrap().remove(&id) {
            let d_us = (d.as_secs_f64() * 1e6) as u64;
            trace::record_root_at(root, "task", trace::now_us().saturating_sub(d_us), d_us);
        }
        let mut t = self.tasks.lock().unwrap();
        t.entry(id).or_default().total_us = d.as_secs_f64() * 1e6;
    }

    /// Snapshot one task.
    pub fn task(&self, id: TaskId) -> Option<TaskMetrics> {
        self.tasks.lock().unwrap().get(&id).cloned()
    }

    /// Snapshot all tasks (sorted by id).
    pub fn all(&self) -> Vec<(TaskId, TaskMetrics)> {
        let t = self.tasks.lock().unwrap();
        let mut v: Vec<_> = t.iter().map(|(&k, m)| (k, m.clone())).collect();
        v.sort_by_key(|&(k, _)| k);
        v
    }

    /// Mean of one phase over tasks whose name matches `filter` (all when
    /// empty). Used directly by the Fig 21-23 benches.
    pub fn mean_phase(&self, phase: Phase, filter: &str) -> f64 {
        let t = self.tasks.lock().unwrap();
        let xs: Vec<f64> = t
            .values()
            .filter(|m| filter.is_empty() || m.name == filter)
            .map(|m| phase.get(m))
            .collect();
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }

    // ---- streams ---------------------------------------------------------

    /// Replace the recorded stats of one stream (callers aggregate across
    /// hubs first; see `CometRuntime::stream_metrics`).
    pub fn set_stream(&self, id: StreamId, stats: StreamStats) {
        self.streams.lock().unwrap().insert(id, stats);
    }

    /// Snapshot one stream's stats.
    pub fn stream(&self, id: StreamId) -> Option<StreamStats> {
        self.streams.lock().unwrap().get(&id).copied()
    }

    /// Snapshot all stream stats (sorted by stream id).
    pub fn streams(&self) -> Vec<(StreamId, StreamStats)> {
        let s = self.streams.lock().unwrap();
        let mut v: Vec<_> = s.iter().map(|(&k, &st)| (k, st)).collect();
        v.sort_by_key(|&(k, _)| k);
        v
    }

    pub fn clear(&self) {
        self.tasks.lock().unwrap().clear();
        self.streams.lock().unwrap().clear();
        self.trace_roots.lock().unwrap().clear();
    }

    pub fn len(&self) -> usize {
        self.tasks.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Runtime phase selector for aggregation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Analysis,
    Schedule,
    Queue,
    Transfer,
    Exec,
    Total,
}

impl Phase {
    pub fn get(&self, m: &TaskMetrics) -> f64 {
        match self {
            Phase::Analysis => m.analysis_us,
            Phase::Schedule => m.schedule_us,
            Phase::Queue => m.queue_us,
            Phase::Transfer => m.transfer_us,
            Phase::Exec => m.exec_us,
            Phase::Total => m.total_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate() {
        let m = MetricsRegistry::new();
        m.on_analysis(1, "t", Duration::from_micros(10));
        m.on_schedule(1, Duration::from_micros(20));
        m.on_schedule(1, Duration::from_micros(5)); // resubmission adds
        m.on_exec(1, 0, Duration::from_micros(100));
        let t = m.task(1).unwrap();
        assert_eq!(t.name, "t");
        assert!((t.analysis_us - 10.0).abs() < 1.0);
        assert!((t.schedule_us - 25.0).abs() < 1.0);
        assert_eq!(t.attempts, 1);
        assert_eq!(t.worker, Some(0));
    }

    #[test]
    fn mean_phase_filters_by_name() {
        let m = MetricsRegistry::new();
        m.on_analysis(1, "a", Duration::from_micros(10));
        m.on_analysis(2, "b", Duration::from_micros(30));
        assert!((m.mean_phase(Phase::Analysis, "a") - 10.0).abs() < 1.0);
        assert!((m.mean_phase(Phase::Analysis, "") - 20.0).abs() < 1.0);
        assert_eq!(m.mean_phase(Phase::Exec, "zzz"), 0.0);
    }

    #[test]
    fn clear_resets() {
        let m = MetricsRegistry::new();
        m.on_analysis(1, "a", Duration::from_micros(1));
        assert_eq!(m.len(), 1);
        m.clear();
        assert!(m.is_empty());
    }

    #[test]
    fn stream_stats_roundtrip_and_efficiency() {
        let m = MetricsRegistry::new();
        assert!(m.stream(7).is_none());
        let s = StreamStats {
            records_out: 100,
            batches_out: 10,
            bytes_out: 2400,
            records_in: 100,
            batches_in: 4,
            bytes_in: 2400,
            fetches: 4,
            bytes_on_disk: 1024,
            segments: 2,
            recovered_records: 0,
        };
        m.set_stream(7, s);
        let got = m.stream(7).unwrap();
        assert_eq!(got, s);
        assert!((got.records_per_poll() - 25.0).abs() < 1e-9);
        assert!((got.records_per_publish() - 10.0).abs() < 1e-9);
        assert_eq!(m.streams(), vec![(7, s)]);
        assert_eq!(StreamStats::default().records_per_poll(), 0.0);
        m.clear();
        assert!(m.stream(7).is_none());
    }
}
