//! The **Task Analyser**: registers tasks and derives data dependencies
//! from parameter annotations (paper §4.5).
//!
//! Object accesses use COMPSs-style renaming: every write allocates a new
//! version, so only true RAW dependencies create edges. File accesses
//! serialise on the last writer of the path. **Stream accesses create no
//! dependency edges** — the producer/consumer relation is recorded instead
//! and handed to the scheduler for producer-priority and stream locality.

use std::collections::{HashMap, HashSet};

use crate::dstream::api::StreamId;
use crate::dstream::StreamHandle;

use super::annotations::{Arg, TaskSpec};
use super::data::{DataRegistry, Key};

/// Task identifier (dense, assigned at submit order).
pub type TaskId = u64;

/// An argument with data versions resolved (what executors consume).
#[derive(Debug, Clone, PartialEq)]
pub enum ResolvedArg {
    ObjIn(Key),
    ObjOut(Key),
    ObjInOut { read: Key, write: Key },
    FileIn(String),
    FileOut(String),
    FileInOut(String),
    StreamIn(StreamHandle),
    StreamOut(StreamHandle),
    Scalar(Vec<u8>),
}

/// A fully analysed task, ready for the graph/scheduler.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub id: TaskId,
    pub name: String,
    pub cores: usize,
    pub explicit_priority: bool,
    pub args: Vec<ResolvedArg>,
    /// Streams this task publishes to.
    pub produces: Vec<StreamId>,
    /// Streams this task consumes from.
    pub consumes: Vec<StreamId>,
    /// Remaining execution attempts (fault tolerance).
    pub attempts_left: u32,
}

impl TaskRecord {
    /// Keys this task must read (inputs to localise before execution).
    pub fn input_keys(&self) -> Vec<Key> {
        let mut keys = Vec::new();
        for a in &self.args {
            match a {
                ResolvedArg::ObjIn(k) => keys.push(*k),
                ResolvedArg::ObjInOut { read, .. } => keys.push(*read),
                _ => {}
            }
        }
        keys
    }

    /// Keys this task will produce.
    pub fn output_keys(&self) -> Vec<Key> {
        let mut keys = Vec::new();
        for a in &self.args {
            match a {
                ResolvedArg::ObjOut(k) => keys.push(*k),
                ResolvedArg::ObjInOut { write, .. } => keys.push(*write),
                _ => {}
            }
        }
        keys
    }

    pub fn is_stream_producer(&self) -> bool {
        !self.produces.is_empty()
    }

    pub fn is_stream_consumer(&self) -> bool {
        !self.consumes.is_empty()
    }
}

/// Producer/consumer relations per stream (scheduler input).
#[derive(Debug, Default)]
pub struct StreamRelations {
    pub producers: HashMap<StreamId, HashSet<TaskId>>,
    pub consumers: HashMap<StreamId, HashSet<TaskId>>,
}

/// The analyser: owns the data registry and stream relations.
#[derive(Debug, Default)]
pub struct TaskAnalyser {
    pub data: DataRegistry,
    pub streams: StreamRelations,
    next_task: TaskId,
}

impl TaskAnalyser {
    pub fn new() -> Self {
        Self::default()
    }

    /// Next task id without consuming it (diagnostics).
    pub fn peek_next_id(&self) -> TaskId {
        self.next_task
    }

    /// Analyse a submission: resolve argument versions, derive the
    /// dependency set, record stream relations.
    pub fn analyse(&mut self, spec: TaskSpec, max_retries: u32) -> (TaskRecord, HashSet<TaskId>) {
        let id = self.next_task;
        self.analyse_with_id(id, spec, max_retries)
    }

    /// [`TaskAnalyser::analyse`] with a caller-assigned id (the runtime
    /// pre-allocates ids so `submit` needs no dispatcher round-trip).
    /// Ids must arrive in submission order.
    pub fn analyse_with_id(
        &mut self,
        id: TaskId,
        spec: TaskSpec,
        max_retries: u32,
    ) -> (TaskRecord, HashSet<TaskId>) {
        debug_assert!(id >= self.next_task, "task ids must be monotonic");
        self.next_task = id + 1;

        let mut deps: HashSet<TaskId> = HashSet::new();
        let mut args = Vec::with_capacity(spec.args.len());
        let mut produces = Vec::new();
        let mut consumes = Vec::new();

        for arg in spec.args {
            match arg {
                Arg::In(d) => {
                    let key = (d, self.data.latest(d));
                    if let Some(w) = self.data.writer(key) {
                        deps.insert(w);
                    }
                    args.push(ResolvedArg::ObjIn(key));
                }
                Arg::Out(d) => {
                    let v = self.data.new_version(d, id);
                    args.push(ResolvedArg::ObjOut((d, v)));
                }
                Arg::InOut(d) => {
                    let read = (d, self.data.latest(d));
                    if let Some(w) = self.data.writer(read) {
                        deps.insert(w);
                    }
                    let v = self.data.new_version(d, id);
                    args.push(ResolvedArg::ObjInOut { read, write: (d, v) });
                }
                Arg::FileIn(p) => {
                    if let Some(w) = self.data.file_writer(&p) {
                        deps.insert(w);
                    }
                    args.push(ResolvedArg::FileIn(p));
                }
                Arg::FileOut(p) => {
                    // Serialise WAW on the same path.
                    if let Some(prev) = self.data.file_write(&p, id) {
                        deps.insert(prev);
                    }
                    args.push(ResolvedArg::FileOut(p));
                }
                Arg::FileInOut(p) => {
                    if let Some(prev) = self.data.file_write(&p, id) {
                        deps.insert(prev);
                    }
                    args.push(ResolvedArg::FileInOut(p));
                }
                Arg::StreamIn(h) => {
                    // No dependency edge — record the relation only.
                    self.streams.consumers.entry(h.id).or_default().insert(id);
                    consumes.push(h.id);
                    args.push(ResolvedArg::StreamIn(h));
                }
                Arg::StreamOut(h) => {
                    self.streams.producers.entry(h.id).or_default().insert(id);
                    produces.push(h.id);
                    args.push(ResolvedArg::StreamOut(h));
                }
                Arg::Scalar(v) => args.push(ResolvedArg::Scalar(v)),
            }
        }
        // A task never depends on itself (InOut after Out of same datum).
        deps.remove(&id);

        let record = TaskRecord {
            id,
            name: spec.name,
            cores: spec.cores,
            explicit_priority: spec.priority,
            args,
            produces,
            consumes,
            attempts_left: max_retries + 1,
        };
        (record, deps)
    }

    /// Forget a finished task from the stream relations (the scheduler no
    /// longer needs it once completed).
    pub fn retire_task(&mut self, task: TaskId) {
        for set in self.streams.producers.values_mut() {
            set.remove(&task);
        }
        for set in self.streams.consumers.values_mut() {
            set.remove(&task);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dstream::{BatchPolicy, ConsumerMode, StreamType};

    fn handle(id: StreamId) -> StreamHandle {
        StreamHandle {
            id,
            alias: None,
            stype: StreamType::Object,
            partitions: 1,
            base_dir: None,
            mode: ConsumerMode::ExactlyOnce,
            batch: BatchPolicy::default(),
        }
    }

    fn analyse(a: &mut TaskAnalyser, spec: TaskSpec) -> (TaskRecord, HashSet<TaskId>) {
        a.analyse(spec, 0)
    }

    #[test]
    fn raw_dependency_via_object() {
        let mut a = TaskAnalyser::new();
        let d = a.data.new_data();
        let (producer, deps0) = analyse(&mut a, TaskSpec::new("w").arg(Arg::Out(d)));
        assert!(deps0.is_empty());
        let (_reader, deps1) = analyse(&mut a, TaskSpec::new("r").arg(Arg::In(d)));
        assert_eq!(deps1.into_iter().collect::<Vec<_>>(), vec![producer.id]);
    }

    #[test]
    fn renaming_breaks_waw_for_objects() {
        let mut a = TaskAnalyser::new();
        let d = a.data.new_data();
        let (_w1, _) = analyse(&mut a, TaskSpec::new("w1").arg(Arg::Out(d)));
        let (w2, deps) = analyse(&mut a, TaskSpec::new("w2").arg(Arg::Out(d)));
        assert!(deps.is_empty(), "second writer gets a fresh version, no WAW edge");
        // But a reader now depends on the *latest* writer only.
        let (_r, deps) = analyse(&mut a, TaskSpec::new("r").arg(Arg::In(d)));
        assert_eq!(deps.into_iter().collect::<Vec<_>>(), vec![w2.id]);
    }

    #[test]
    fn inout_chains_serialise() {
        let mut a = TaskAnalyser::new();
        let d = a.data.new_data();
        let (t1, _) = analyse(&mut a, TaskSpec::new("t1").arg(Arg::InOut(d)));
        let (t2, deps2) = analyse(&mut a, TaskSpec::new("t2").arg(Arg::InOut(d)));
        assert_eq!(deps2, HashSet::from([t1.id]));
        let (_t3, deps3) = analyse(&mut a, TaskSpec::new("t3").arg(Arg::InOut(d)));
        assert_eq!(deps3, HashSet::from([t2.id]));
    }

    #[test]
    fn file_dependencies_serialise_on_path() {
        let mut a = TaskAnalyser::new();
        let (w, _) = analyse(&mut a, TaskSpec::new("w").arg(Arg::FileOut("/f".into())));
        let (r, deps) = analyse(&mut a, TaskSpec::new("r").arg(Arg::FileIn("/f".into())));
        assert_eq!(deps, HashSet::from([w.id]));
        // Writer after reader serialises on previous writer (WAW).
        let (_w2, deps) = analyse(&mut a, TaskSpec::new("w2").arg(Arg::FileOut("/f".into())));
        assert_eq!(deps, HashSet::from([w.id]));
        let _ = r;
    }

    #[test]
    fn streams_create_no_edges_but_record_relations() {
        let mut a = TaskAnalyser::new();
        let h = handle(9);
        let (p, deps_p) = analyse(&mut a, TaskSpec::new("prod").arg(Arg::StreamOut(h.clone())));
        let (c, deps_c) = analyse(&mut a, TaskSpec::new("cons").arg(Arg::StreamIn(h)));
        assert!(deps_p.is_empty());
        assert!(deps_c.is_empty(), "stream params must not create dependencies");
        assert!(a.streams.producers[&9].contains(&p.id));
        assert!(a.streams.consumers[&9].contains(&c.id));
        assert!(p.is_stream_producer());
        assert!(c.is_stream_consumer());
    }

    #[test]
    fn mixed_stream_and_file_params() {
        // Paper Listing 7: one task with a stream and a file parameter.
        let mut a = TaskAnalyser::new();
        let (w, _) = analyse(&mut a, TaskSpec::new("w").arg(Arg::FileOut("/data".into())));
        let (t, deps) = analyse(
            &mut a,
            TaskSpec::new("hybrid")
                .arg(Arg::StreamOut(handle(1)))
                .arg(Arg::FileIn("/data".into())),
        );
        assert_eq!(deps, HashSet::from([w.id]));
        assert!(t.is_stream_producer());
    }

    #[test]
    fn input_output_keys() {
        let mut a = TaskAnalyser::new();
        let d1 = a.data.new_data();
        let d2 = a.data.new_data();
        let (t, _) = analyse(
            &mut a,
            TaskSpec::new("t").arg(Arg::In(d1)).arg(Arg::Out(d2)).arg(Arg::InOut(d1)),
        );
        assert_eq!(t.input_keys(), vec![(d1, 0), (d1, 0)]);
        assert_eq!(t.output_keys(), vec![(d2, 1), (d1, 1)]);
    }

    #[test]
    fn retire_cleans_relations() {
        let mut a = TaskAnalyser::new();
        let (p, _) = analyse(&mut a, TaskSpec::new("p").arg(Arg::StreamOut(handle(1))));
        a.retire_task(p.id);
        assert!(!a.streams.producers[&1].contains(&p.id));
    }
}
