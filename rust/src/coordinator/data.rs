//! The data registry: versioned objects/files, values and locations.
//!
//! COMPSs renames every written datum so independent versions coexist
//! (write-after-write never blocks readers of older versions). A datum is
//! identified by `(DataId, Version)`; the registry tracks, per version:
//! the producing task, the concrete value (once available) and the set of
//! workers holding a replica (the locality information the scheduler uses).

use std::collections::HashMap;
use std::sync::Arc;

use super::annotations::DataId;

/// Monotonic version of a datum (bumped by every Out/InOut access).
pub type Version = u32;

/// Worker identifier (index into the runtime's worker table; the master is
/// [`MASTER`]).
pub type WorkerId = usize;

/// Location id of the master process.
pub const MASTER: WorkerId = usize::MAX;

/// A concrete datum version key.
pub type Key = (DataId, Version);

#[derive(Debug, Default)]
struct Datum {
    /// Latest version number allocated.
    latest: Version,
    /// Task that produces each version (None = registered by main code).
    writer: HashMap<Version, Option<u64>>,
    /// Values by version, once produced.
    values: HashMap<Version, Arc<Vec<u8>>>,
    /// Replica locations by version.
    locations: HashMap<Version, Vec<WorkerId>>,
}

/// Registry of all runtime-managed data.
#[derive(Debug, Default)]
pub struct DataRegistry {
    next_id: DataId,
    data: HashMap<DataId, Datum>,
    /// Last writer task per file path (file dependency analysis).
    file_writers: HashMap<String, u64>,
}

impl DataRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a fresh datum id (version 0, no value yet).
    pub fn new_data(&mut self) -> DataId {
        let id = self.next_id;
        self.next_id += 1;
        self.data.insert(id, Datum::default());
        id
    }

    /// Register a main-code value for a fresh datum (version 0 at master).
    pub fn register_value(&mut self, value: Vec<u8>) -> DataId {
        let id = self.new_data();
        let d = self.data.get_mut(&id).unwrap();
        d.writer.insert(0, None);
        d.values.insert(0, Arc::new(value));
        d.locations.insert(0, vec![MASTER]);
        id
    }

    /// Latest version of `id` (0 if untouched).
    pub fn latest(&self, id: DataId) -> Version {
        self.data.get(&id).map(|d| d.latest).unwrap_or(0)
    }

    /// Bump the version for a write by `task`; returns the new version.
    pub fn new_version(&mut self, id: DataId, task: u64) -> Version {
        let d = self.data.entry(id).or_default();
        d.latest += 1;
        let v = d.latest;
        d.writer.insert(v, Some(task));
        v
    }

    /// The task writing `key` (None for main-code data or unknown keys).
    pub fn writer(&self, key: Key) -> Option<u64> {
        self.data.get(&key.0).and_then(|d| d.writer.get(&key.1)).copied().flatten()
    }

    /// Store a produced value (at `location`).
    pub fn put_value(&mut self, key: Key, value: Arc<Vec<u8>>, location: WorkerId) {
        let d = self.data.entry(key.0).or_default();
        d.values.insert(key.1, value);
        d.locations.entry(key.1).or_default().push(location);
    }

    /// Add a replica location (a worker received the value for a task).
    pub fn add_location(&mut self, key: Key, location: WorkerId) {
        let d = self.data.entry(key.0).or_default();
        let locs = d.locations.entry(key.1).or_default();
        if !locs.contains(&location) {
            locs.push(location);
        }
    }

    /// Forget every replica hosted by `worker` (worker death).
    pub fn drop_worker(&mut self, worker: WorkerId) {
        for d in self.data.values_mut() {
            for locs in d.locations.values_mut() {
                locs.retain(|&w| w != worker);
            }
        }
    }

    /// Value of `key`, if produced.
    pub fn value(&self, key: Key) -> Option<Arc<Vec<u8>>> {
        self.data.get(&key.0).and_then(|d| d.values.get(&key.1)).cloned()
    }

    /// Replica locations of `key`.
    pub fn locations(&self, key: Key) -> &[WorkerId] {
        self.data
            .get(&key.0)
            .and_then(|d| d.locations.get(&key.1))
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Is `key` available somewhere?
    pub fn is_available(&self, key: Key) -> bool {
        self.data.get(&key.0).map(|d| d.values.contains_key(&key.1)).unwrap_or(false)
    }

    /// Drop all versions of `id` except the latest (garbage collection after
    /// task completion, mirroring COMPSs's data clean-up).
    pub fn gc_old_versions(&mut self, id: DataId) -> usize {
        let Some(d) = self.data.get_mut(&id) else { return 0 };
        let latest = d.latest;
        let before = d.values.len();
        d.values.retain(|&v, _| v == latest);
        d.locations.retain(|&v, _| v == latest);
        d.writer.retain(|&v, _| v == latest);
        before.saturating_sub(d.values.len())
    }

    // ---- files -----------------------------------------------------------

    /// Record `task` as the last writer of `path`; returns the previous
    /// writer (the dependency for readers/writers of the same path).
    pub fn file_write(&mut self, path: &str, task: u64) -> Option<u64> {
        self.file_writers.insert(path.to_string(), task)
    }

    /// Current last writer of `path`.
    pub fn file_writer(&self, path: &str) -> Option<u64> {
        self.file_writers.get(path).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versions_are_monotonic_per_datum() {
        let mut r = DataRegistry::new();
        let id = r.new_data();
        assert_eq!(r.latest(id), 0);
        assert_eq!(r.new_version(id, 1), 1);
        assert_eq!(r.new_version(id, 2), 2);
        assert_eq!(r.latest(id), 2);
        assert_eq!(r.writer((id, 1)), Some(1));
        assert_eq!(r.writer((id, 2)), Some(2));
    }

    #[test]
    fn register_value_is_at_master() {
        let mut r = DataRegistry::new();
        let id = r.register_value(vec![1, 2, 3]);
        assert!(r.is_available((id, 0)));
        assert_eq!(r.locations((id, 0)), &[MASTER]);
        assert_eq!(r.writer((id, 0)), None);
        assert_eq!(*r.value((id, 0)).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn locations_dedupe_and_drop() {
        let mut r = DataRegistry::new();
        let id = r.register_value(vec![0]);
        r.add_location((id, 0), 1);
        r.add_location((id, 0), 1);
        r.add_location((id, 0), 2);
        assert_eq!(r.locations((id, 0)), &[MASTER, 1, 2]);
        r.drop_worker(1);
        assert_eq!(r.locations((id, 0)), &[MASTER, 2]);
    }

    #[test]
    fn gc_keeps_only_latest() {
        let mut r = DataRegistry::new();
        let id = r.register_value(vec![0]);
        let v1 = r.new_version(id, 7);
        r.put_value((id, v1), Arc::new(vec![1]), 0);
        let v2 = r.new_version(id, 8);
        r.put_value((id, v2), Arc::new(vec![2]), 0);
        let dropped = r.gc_old_versions(id);
        assert_eq!(dropped, 2);
        assert!(!r.is_available((id, 0)));
        assert!(!r.is_available((id, v1)));
        assert!(r.is_available((id, v2)));
    }

    #[test]
    fn file_writer_chain() {
        let mut r = DataRegistry::new();
        assert_eq!(r.file_write("/f", 1), None);
        assert_eq!(r.file_write("/f", 2), Some(1));
        assert_eq!(r.file_writer("/f"), Some(2));
        assert_eq!(r.file_writer("/other"), None);
    }
}
