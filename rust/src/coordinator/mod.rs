//! The task-based runtime (COMPSs-like) with Hybrid-Workflow extensions.
//!
//! Architecture mirrors the paper's Fig 7 pipeline:
//!
//! ```text
//!  app (main code) ──submit──▶ Task Analyser ──▶ Task Graph ──▶ Task
//!        ▲                       (deps from        (DAG)       Scheduler
//!        │ wait_on/barrier        param annots)                  │
//!        └────────────── Task Dispatcher ◀──────────────────────┘
//!                              │  ▲
//!                     execute  ▼  │ finished
//!                           Workers (core slots, object store, hub, PJRT)
//! ```
//!
//! The Hybrid-Workflow extensions (paper §4.4–4.5) are:
//!
//! - the `Stream` parameter kind ([`annotations::Arg::StreamIn`] /
//!   [`annotations::Arg::StreamOut`]) which creates **no** dependency edge —
//!   producer and consumer run concurrently;
//! - **producer priority**: ready producer tasks are scheduled before
//!   consumer tasks of the same stream, so consumers never hold cores
//!   waiting for data no one is producing;
//! - **stream locality**: workers that run (or ran) producer tasks count as
//!   data locations of the stream when scoring consumer placements.
//!
//! Module map: [`annotations`] (task/parameter model), [`data`] (registry +
//! versions + locations), [`analyser`], [`graph`], [`scheduler`],
//! [`dispatcher`] (event loop + fault tolerance), [`executor`] (task fn
//! registry + `TaskCtx`), [`worker`] (in-process core-slot workers),
//! [`remote`] (TCP worker processes), [`metrics`] (per-task lifecycle
//! times — the Fig 21-24 instrumentation), [`tracing`] (Paraver-like task
//! traces — Fig 14), [`api`] (the `CometRuntime` facade).

pub mod annotations;
pub mod analyser;
pub mod api;
pub mod data;
pub mod dispatcher;
pub mod executor;
pub mod graph;
pub mod metrics;
pub mod remote;
pub mod scheduler;
pub mod tracing;
pub mod worker;

/// One-stop imports for applications.
pub mod prelude {
    pub use super::annotations::{Arg, Direction, TaskSpec};
    pub use super::api::{CometBuilder, CometRuntime, DataRef};
    pub use super::executor::{register_task_fn, TaskCtx};
    pub use crate::dstream::{BatchPolicy, ConsumerMode, StreamHandle, StreamType};
}
