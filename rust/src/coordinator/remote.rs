//! Remote worker processes over TCP — the distributed face of the runtime.
//!
//! The paper's COMPSs master talks NIO to workers on other nodes; here the
//! master serialises [`Job`]s over framed TCP to `hybridws worker`
//! processes (same binary ⇒ same task-function registry). Remote workers
//! reach the DistroStream Server and the broker through their TCP
//! endpoints, which the master exposes via [`super::api`]'s networked mode.
//!
//! Protocol: master sends [`MasterMsg::Hello`] once, then `Run` frames;
//! the worker replies with [`WorkerMsg::Done`] frames (any order).

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use log::{debug, info, warn};

use crate::dstream::DistroStreamHub;
use crate::runtime::ModelZoo;
use crate::util::bytes::{ByteReader, ByteWriter, DecodeError};
use crate::util::threadpool::ThreadPool;
use crate::util::timeutil::TimeScale;
use crate::util::wire::{recv_msg, send_msg, Blob, Wire};

use super::analyser::{ResolvedArg, TaskRecord};
use super::data::Key;
use super::dispatcher::Event;
use super::executor::{lookup_task_fn, CtxArg, TaskCtx};
use super::worker::Job;

// ---- wire impls for the task model -----------------------------------------

impl Wire for ResolvedArg {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            ResolvedArg::ObjIn(k) => {
                w.put_u8(0);
                k.encode(w);
            }
            ResolvedArg::ObjOut(k) => {
                w.put_u8(1);
                k.encode(w);
            }
            ResolvedArg::ObjInOut { read, write } => {
                w.put_u8(2);
                read.encode(w);
                write.encode(w);
            }
            ResolvedArg::FileIn(p) => {
                w.put_u8(3);
                p.encode(w);
            }
            ResolvedArg::FileOut(p) => {
                w.put_u8(4);
                p.encode(w);
            }
            ResolvedArg::FileInOut(p) => {
                w.put_u8(5);
                p.encode(w);
            }
            ResolvedArg::StreamIn(h) => {
                w.put_u8(6);
                h.encode(w);
            }
            ResolvedArg::StreamOut(h) => {
                w.put_u8(7);
                h.encode(w);
            }
            ResolvedArg::Scalar(v) => {
                w.put_u8(8);
                v.encode(w);
            }
        }
    }
    fn decode(r: &mut ByteReader) -> std::result::Result<Self, DecodeError> {
        let at = r.position();
        Ok(match r.get_u8()? {
            0 => ResolvedArg::ObjIn(Wire::decode(r)?),
            1 => ResolvedArg::ObjOut(Wire::decode(r)?),
            2 => ResolvedArg::ObjInOut { read: Wire::decode(r)?, write: Wire::decode(r)? },
            3 => ResolvedArg::FileIn(Wire::decode(r)?),
            4 => ResolvedArg::FileOut(Wire::decode(r)?),
            5 => ResolvedArg::FileInOut(Wire::decode(r)?),
            6 => ResolvedArg::StreamIn(Wire::decode(r)?),
            7 => ResolvedArg::StreamOut(Wire::decode(r)?),
            8 => ResolvedArg::Scalar(Wire::decode(r)?),
            tag => return Err(DecodeError::BadTag { at, tag: tag as u32, ty: "ResolvedArg" }),
        })
    }
}

impl Wire for TaskRecord {
    fn encode(&self, w: &mut ByteWriter) {
        self.id.encode(w);
        self.name.encode(w);
        self.cores.encode(w);
        self.explicit_priority.encode(w);
        self.args.encode(w);
        self.produces.encode(w);
        self.consumes.encode(w);
        self.attempts_left.encode(w);
    }
    fn decode(r: &mut ByteReader) -> std::result::Result<Self, DecodeError> {
        Ok(TaskRecord {
            id: Wire::decode(r)?,
            name: Wire::decode(r)?,
            cores: Wire::decode(r)?,
            explicit_priority: Wire::decode(r)?,
            args: Wire::decode(r)?,
            produces: Wire::decode(r)?,
            consumes: Wire::decode(r)?,
            attempts_left: Wire::decode(r)?,
        })
    }
}

// ---- protocol -----------------------------------------------------------------

/// Master → remote worker.
#[derive(Debug, Clone)]
pub enum MasterMsg {
    /// Connection setup: service endpoints + identity + time scale.
    Hello {
        worker_name: String,
        ds_addr: String,
        broker_addr: String,
        scale_factor: f64,
        load_models: bool,
    },
    Run { record: TaskRecord, inputs: Vec<(Key, Blob)>, attempt: u32 },
    Bye,
}

impl Wire for MasterMsg {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            MasterMsg::Hello { worker_name, ds_addr, broker_addr, scale_factor, load_models } => {
                w.put_u8(0);
                worker_name.encode(w);
                ds_addr.encode(w);
                broker_addr.encode(w);
                scale_factor.encode(w);
                load_models.encode(w);
            }
            MasterMsg::Run { record, inputs, attempt } => {
                w.put_u8(1);
                record.encode(w);
                inputs.encode(w);
                attempt.encode(w);
            }
            MasterMsg::Bye => w.put_u8(2),
        }
    }
    fn decode(r: &mut ByteReader) -> std::result::Result<Self, DecodeError> {
        let at = r.position();
        Ok(match r.get_u8()? {
            0 => MasterMsg::Hello {
                worker_name: Wire::decode(r)?,
                ds_addr: Wire::decode(r)?,
                broker_addr: Wire::decode(r)?,
                scale_factor: Wire::decode(r)?,
                load_models: Wire::decode(r)?,
            },
            1 => MasterMsg::Run {
                record: Wire::decode(r)?,
                inputs: Wire::decode(r)?,
                attempt: Wire::decode(r)?,
            },
            2 => MasterMsg::Bye,
            tag => return Err(DecodeError::BadTag { at, tag: tag as u32, ty: "MasterMsg" }),
        })
    }
}

/// Remote worker → master.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkerMsg {
    Ready,
    Done { task: u64, outputs: Vec<(Key, Blob)>, error: Option<String> },
}

impl Wire for WorkerMsg {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            WorkerMsg::Ready => w.put_u8(0),
            WorkerMsg::Done { task, outputs, error } => {
                w.put_u8(1);
                task.encode(w);
                outputs.encode(w);
                error.encode(w);
            }
        }
    }
    fn decode(r: &mut ByteReader) -> std::result::Result<Self, DecodeError> {
        let at = r.position();
        Ok(match r.get_u8()? {
            0 => WorkerMsg::Ready,
            1 => WorkerMsg::Done {
                task: Wire::decode(r)?,
                outputs: Wire::decode(r)?,
                error: Wire::decode(r)?,
            },
            tag => return Err(DecodeError::BadTag { at, tag: tag as u32, ty: "WorkerMsg" }),
        })
    }
}

// ---- master-side handle ----------------------------------------------------------

/// Master-side proxy for one remote worker.
pub struct RemoteWorker {
    pub id: usize,
    pub slots: usize,
    writer: Mutex<TcpStream>,
    killed: Arc<AtomicBool>,
}

impl RemoteWorker {
    /// Connect to a remote worker and hand its completions to `events`.
    #[allow(clippy::too_many_arguments)]
    pub fn connect(
        id: usize,
        slots: usize,
        addr: &str,
        ds_addr: &str,
        broker_addr: &str,
        scale: TimeScale,
        load_models: bool,
        events: mpsc::Sender<Event>,
    ) -> anyhow::Result<Arc<Self>> {
        let mut sock = TcpStream::connect(addr)?;
        sock.set_nodelay(true).ok();
        send_msg(
            &mut sock,
            &MasterMsg::Hello {
                worker_name: format!("remote-worker{id}"),
                ds_addr: ds_addr.to_string(),
                broker_addr: broker_addr.to_string(),
                scale_factor: scale.factor,
                load_models,
            },
        )?;
        let ready: Option<WorkerMsg> = recv_msg(&mut sock)?;
        anyhow::ensure!(matches!(ready, Some(WorkerMsg::Ready)), "worker did not report ready");

        let killed = Arc::new(AtomicBool::new(false));
        let reader = sock.try_clone()?;
        let reader_killed = Arc::clone(&killed);
        std::thread::Builder::new().name(format!("remote{id}-rx")).spawn(move || {
            let mut reader = reader;
            loop {
                match recv_msg::<_, WorkerMsg>(&mut reader) {
                    Ok(Some(WorkerMsg::Done { task, outputs, error })) => {
                        if reader_killed.load(Ordering::SeqCst) {
                            continue;
                        }
                        // `to_arc` copies frame-view payloads out of their
                        // wire frame so the registry never pins a whole
                        // received frame for one output.
                        let outputs = outputs
                            .into_iter()
                            .map(|(k, b)| (k, b.0.to_arc()))
                            .collect();
                        let finished = Event::Finished { task, worker: id, outputs, error };
                        if events.send(finished).is_err() {
                            break;
                        }
                    }
                    Ok(Some(WorkerMsg::Ready)) => {}
                    Ok(None) => break,
                    Err(e) => {
                        debug!("remote worker {id} reader: {e}");
                        break;
                    }
                }
            }
        })?;
        Ok(Arc::new(Self { id, slots, writer: Mutex::new(sock), killed }))
    }

    pub fn send_job(&self, job: &Job) {
        // The worker store keeps `Arc<Vec<u8>>`: hand the same allocation
        // to the wire encoder (the encode into the frame is the one copy).
        let inputs: Vec<(Key, Blob)> =
            job.inputs.iter().map(|(k, v)| (*k, Blob::from_arc(Arc::clone(v)))).collect();
        let msg = MasterMsg::Run { record: job.record.clone(), inputs, attempt: job.attempt };
        if let Err(e) = send_msg(&mut *self.writer.lock().unwrap(), &msg) {
            warn!("remote worker {} send failed: {e}", self.id);
        }
    }

    pub fn kill(&self) {
        self.killed.store(true, Ordering::SeqCst);
        let _ = send_msg(&mut *self.writer.lock().unwrap(), &MasterMsg::Bye);
    }
}

impl super::worker::WorkerHandle for RemoteWorker {
    fn wid(&self) -> usize {
        self.id
    }
    fn slot_count(&self) -> usize {
        self.slots
    }
    fn submit_job(&self, job: Job) {
        self.send_job(&job);
    }
    fn mark_killed(&self) {
        self.kill();
    }
    fn disconnect(&self) {
        let _ = send_msg(&mut *self.writer.lock().unwrap(), &MasterMsg::Bye);
    }
}

// ---- worker-process side -------------------------------------------------------------

/// Serve one master connection on `listener` (the `hybridws worker`
/// entrypoint). Returns when the master says `Bye` or disconnects.
pub fn serve_worker(listener: TcpListener, slots: usize) -> anyhow::Result<()> {
    info!("remote worker listening on {} ({slots} slots)", listener.local_addr()?);
    let (mut sock, peer) = listener.accept()?;
    sock.set_nodelay(true).ok();
    info!("master connected from {peer}");

    let hello: MasterMsg = recv_msg(&mut sock)?.ok_or_else(|| anyhow::anyhow!("no hello"))?;
    let MasterMsg::Hello { worker_name, ds_addr, broker_addr, scale_factor, load_models } = hello
    else {
        anyhow::bail!("expected Hello, got {hello:?}");
    };

    let hub = DistroStreamHub::connect(&worker_name, &ds_addr, &broker_addr)
        .map_err(|e| anyhow::anyhow!("hub connect: {e}"))?;
    let zoo = if load_models {
        let dir = crate::runtime::find_artifacts_dir()
            .ok_or_else(|| anyhow::anyhow!("artifacts not found on worker"))?;
        Some(Arc::new(ModelZoo::load(&dir)?))
    } else {
        None
    };
    let scale = TimeScale::new(scale_factor);

    let writer = Arc::new(Mutex::new(sock.try_clone()?));
    send_msg(&mut *writer.lock().unwrap(), &WorkerMsg::Ready)?;

    let pool = ThreadPool::new("remote-exec", slots.max(1));
    let store: Arc<Mutex<std::collections::HashMap<Key, Arc<Vec<u8>>>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));

    loop {
        let msg: MasterMsg = match recv_msg(&mut sock) {
            Ok(Some(m)) => m,
            Ok(None) => break,
            Err(e) => {
                warn!("worker read error: {e}");
                break;
            }
        };
        match msg {
            MasterMsg::Bye => break,
            MasterMsg::Hello { .. } => warn!("unexpected second Hello"),
            MasterMsg::Run { record, inputs, attempt } => {
                let writer = Arc::clone(&writer);
                let store = Arc::clone(&store);
                let hub = Arc::clone(&hub);
                let zoo = zoo.clone();
                pool.execute(move || {
                    let result = run_remote_job(&record, inputs, attempt, &store, hub, zoo, scale);
                    let msg = match result {
                        Ok(outputs) => WorkerMsg::Done { task: record.id, outputs, error: None },
                        Err(e) => WorkerMsg::Done {
                            task: record.id,
                            outputs: Vec::new(),
                            error: Some(e.to_string()),
                        },
                    };
                    let _ = send_msg(&mut *writer.lock().unwrap(), &msg);
                });
            }
        }
    }
    pool.shutdown();
    info!("remote worker exiting");
    Ok(())
}

fn run_remote_job(
    record: &TaskRecord,
    inputs: Vec<(Key, Blob)>,
    attempt: u32,
    store: &Arc<Mutex<std::collections::HashMap<Key, Arc<Vec<u8>>>>>,
    hub: Arc<DistroStreamHub>,
    zoo: Option<Arc<ModelZoo>>,
    scale: TimeScale,
) -> anyhow::Result<Vec<(Key, Blob)>> {
    for (k, b) in inputs {
        store.lock().unwrap().entry(k).or_insert_with(|| b.0.to_arc());
    }
    let mut out_keys: Vec<(usize, Key)> = Vec::new();
    let mut args = Vec::with_capacity(record.args.len());
    for (i, arg) in record.args.iter().enumerate() {
        match arg {
            ResolvedArg::ObjIn(k) => {
                let v = store
                    .lock()
                    .unwrap()
                    .get(k)
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("input {k:?} missing"))?;
                args.push(CtxArg::ObjIn(v));
            }
            ResolvedArg::ObjOut(k) => {
                out_keys.push((i, *k));
                args.push(CtxArg::ObjOut(None));
            }
            ResolvedArg::ObjInOut { read, write } => {
                let v = store
                    .lock()
                    .unwrap()
                    .get(read)
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("input {read:?} missing"))?;
                out_keys.push((i, *write));
                args.push(CtxArg::ObjInOut { input: v, output: None });
            }
            ResolvedArg::FileIn(p) | ResolvedArg::FileOut(p) | ResolvedArg::FileInOut(p) => {
                args.push(CtxArg::File(p.clone()));
            }
            ResolvedArg::StreamIn(h) | ResolvedArg::StreamOut(h) => {
                args.push(CtxArg::Stream(h.clone()));
            }
            ResolvedArg::Scalar(v) => args.push(CtxArg::Scalar(v.clone())),
        }
    }
    let f = lookup_task_fn(&record.name)
        .ok_or_else(|| anyhow::anyhow!("no task function registered: {}", record.name))?;
    let mut ctx = TaskCtx {
        task_id: record.id,
        worker_id: usize::MAX, // remote workers have no master-side index here
        cores: record.cores,
        attempt,
        args,
        hub,
        zoo,
        scale,
    };
    f(&mut ctx)?;
    let outs = ctx.take_outputs()?;
    let mut keyed = Vec::with_capacity(outs.len());
    for (idx, bytes) in outs {
        let key = out_keys
            .iter()
            .find(|&&(i, _)| i == idx)
            .map(|&(_, k)| k)
            .ok_or_else(|| anyhow::anyhow!("output index mismatch"))?;
        // One allocation serves both the local store and the reply frame
        // (`to_arc` on a whole-buffer view is an Arc clone, not a copy).
        let blob = Blob::new(bytes);
        store.lock().unwrap().insert(key, blob.0.to_arc());
        keyed.push((key, blob));
    }
    Ok(keyed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::wire::Wire;

    #[test]
    fn protocol_roundtrip() {
        let rec = TaskRecord {
            id: 1,
            name: "t".into(),
            cores: 2,
            explicit_priority: false,
            args: vec![ResolvedArg::ObjIn((0, 0)), ResolvedArg::Scalar(vec![1])],
            produces: vec![3],
            consumes: vec![],
            attempts_left: 2,
        };
        let msgs = vec![
            MasterMsg::Hello {
                worker_name: "w".into(),
                ds_addr: "a:1".into(),
                broker_addr: "b:2".into(),
                scale_factor: 0.01,
                load_models: false,
            },
            MasterMsg::Run { record: rec, inputs: vec![((0, 0), Blob::new(vec![9]))], attempt: 1 },
            MasterMsg::Bye,
        ];
        for m in msgs {
            let back = MasterMsg::decode_exact(&m.encode_vec()).unwrap();
            assert_eq!(back.encode_vec(), m.encode_vec(), "roundtrip changed bytes");
        }
        let replies = vec![
            WorkerMsg::Ready,
            WorkerMsg::Done { task: 1, outputs: vec![((1, 1), Blob::new(vec![2]))], error: None },
            WorkerMsg::Done { task: 2, outputs: vec![], error: Some("x".into()) },
        ];
        for m in replies {
            assert_eq!(WorkerMsg::decode_exact(&m.encode_vec()).unwrap(), m);
        }
    }
}
