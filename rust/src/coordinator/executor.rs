//! Task execution: the task-function registry and the `TaskCtx` handed to
//! task bodies.
//!
//! COMPSs invokes annotated methods; here applications register named
//! functions once per process ([`register_task_fn`]) and submit
//! [`super::annotations::TaskSpec`]s referring to them. The same registry
//! is used by in-process workers and by remote worker processes (same
//! binary ⇒ same registrations), so specs are location-transparent.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use once_cell::sync::Lazy;

use crate::dstream::{
    BatchPolicy, DistroStreamHub, FileDistroStream, ObjectDistroStream, StreamHandle, StreamItem,
};
use crate::runtime::ModelZoo;
use crate::util::timeutil::TimeScale;
use crate::util::wire::Wire;

/// A task body. Returns `Err` to trigger fault tolerance (resubmission).
pub type TaskFn = Arc<dyn Fn(&mut TaskCtx) -> anyhow::Result<()> + Send + Sync>;

static REGISTRY: Lazy<RwLock<HashMap<String, TaskFn>>> = Lazy::new(Default::default);

/// Register a task function under `name` (overwrites earlier entries, so
/// tests can stub app tasks).
pub fn register_task_fn<F>(name: &str, f: F)
where
    F: Fn(&mut TaskCtx) -> anyhow::Result<()> + Send + Sync + 'static,
{
    REGISTRY.write().unwrap().insert(name.to_string(), Arc::new(f));
}

/// Look up a registered task function.
pub fn lookup_task_fn(name: &str) -> Option<TaskFn> {
    REGISTRY.read().unwrap().get(name).cloned()
}

/// Registered names (diagnostics).
pub fn registered_names() -> Vec<String> {
    let mut v: Vec<String> = REGISTRY.read().unwrap().keys().cloned().collect();
    v.sort();
    v
}

/// One materialised argument inside a running task.
#[derive(Debug)]
pub enum CtxArg {
    ObjIn(Arc<Vec<u8>>),
    ObjOut(Option<Vec<u8>>),
    ObjInOut { input: Arc<Vec<u8>>, output: Option<Vec<u8>> },
    File(String),
    Stream(StreamHandle),
    Scalar(Vec<u8>),
}

/// Execution context of one task attempt.
pub struct TaskCtx {
    pub task_id: u64,
    pub worker_id: usize,
    pub cores: usize,
    pub attempt: u32,
    pub args: Vec<CtxArg>,
    /// Stream access for this process.
    pub hub: Arc<DistroStreamHub>,
    /// AOT-compiled models (PJRT), when the runtime was built with them.
    pub zoo: Option<Arc<ModelZoo>>,
    /// Paper-time scaling for simulated compute.
    pub scale: TimeScale,
}

impl TaskCtx {
    // ---- objects ---------------------------------------------------------

    /// Bytes of the `idx`-th argument (In or InOut).
    pub fn obj_in(&self, idx: usize) -> &[u8] {
        match &self.args[idx] {
            CtxArg::ObjIn(v) => v,
            CtxArg::ObjInOut { input, .. } => input,
            other => panic!("arg {idx} is not an object input: {other:?}"),
        }
    }

    /// Decode the `idx`-th input object as a `Wire` value.
    pub fn obj_in_as<T: Wire>(&self, idx: usize) -> anyhow::Result<T> {
        T::decode_exact(self.obj_in(idx)).map_err(|e| anyhow::anyhow!("arg {idx}: {e}"))
    }

    /// Set the output bytes of the `idx`-th argument (Out or InOut).
    pub fn set_output(&mut self, idx: usize, bytes: Vec<u8>) {
        match &mut self.args[idx] {
            CtxArg::ObjOut(slot) => *slot = Some(bytes),
            CtxArg::ObjInOut { output, .. } => *output = Some(bytes),
            other => panic!("arg {idx} is not an object output: {other:?}"),
        }
    }

    /// Encode + set an output object.
    pub fn set_output_as<T: Wire>(&mut self, idx: usize, v: &T) {
        self.set_output(idx, v.encode_vec());
    }

    // ---- scalars / files ---------------------------------------------------

    /// Decode the `idx`-th scalar argument.
    pub fn scalar<T: Wire>(&self, idx: usize) -> anyhow::Result<T> {
        match &self.args[idx] {
            CtxArg::Scalar(v) => {
                T::decode_exact(v).map_err(|e| anyhow::anyhow!("scalar {idx}: {e}"))
            }
            other => Err(anyhow::anyhow!("arg {idx} is not a scalar: {other:?}")),
        }
    }

    /// Path of the `idx`-th file argument.
    pub fn file_path(&self, idx: usize) -> &str {
        match &self.args[idx] {
            CtxArg::File(p) => p,
            other => panic!("arg {idx} is not a file: {other:?}"),
        }
    }

    // ---- streams -----------------------------------------------------------

    /// Raw handle of the `idx`-th stream argument.
    pub fn stream_handle(&self, idx: usize) -> &StreamHandle {
        match &self.args[idx] {
            CtxArg::Stream(h) => h,
            other => panic!("arg {idx} is not a stream: {other:?}"),
        }
    }

    /// Materialise the `idx`-th argument as a typed object stream. The
    /// stream identity is per-task, so concurrent tasks on one worker are
    /// distinct producers/consumers (close semantics, group membership).
    /// The stream inherits the [`BatchPolicy`] carried by the handle, so
    /// batching tuned at creation time follows the stream into tasks.
    pub fn object_stream<T: StreamItem>(&self, idx: usize) -> ObjectDistroStream<T> {
        let identity = format!("{}#t{}", self.hub.process(), self.task_id);
        ObjectDistroStream::attach_as(
            self.stream_handle(idx).clone(),
            Arc::clone(&self.hub),
            identity,
        )
    }

    /// [`TaskCtx::object_stream`] with a task-local [`BatchPolicy`]
    /// override (e.g. a consumer task that wants smaller, fairer polls
    /// than the stream-wide default).
    pub fn object_stream_batched<T: StreamItem>(
        &self,
        idx: usize,
        batch: BatchPolicy,
    ) -> ObjectDistroStream<T> {
        let identity = format!("{}#t{}", self.hub.process(), self.task_id);
        let handle = self.stream_handle(idx).clone().with_batch(batch);
        ObjectDistroStream::attach_as(handle, Arc::clone(&self.hub), identity)
    }

    /// Materialise the `idx`-th argument as a file stream (per-task
    /// identity, see [`TaskCtx::object_stream`]).
    pub fn file_stream(&self, idx: usize) -> FileDistroStream {
        let identity = format!("{}#t{}", self.hub.process(), self.task_id);
        FileDistroStream::attach_as(
            self.stream_handle(idx).clone(),
            Arc::clone(&self.hub),
            identity,
        )
    }

    // ---- compute helpers ------------------------------------------------------

    /// Sleep for `ms` *paper milliseconds* (scaled) — how workload benches
    /// model the paper's fixed-duration task bodies.
    pub fn sleep_paper_ms(&self, ms: u64) {
        std::thread::sleep(self.scale.paper_ms(ms));
    }

    /// The PJRT model zoo; errors if the runtime was built without one.
    pub fn models(&self) -> anyhow::Result<&Arc<ModelZoo>> {
        self.zoo.as_ref().ok_or_else(|| anyhow::anyhow!("runtime built without PJRT models"))
    }

    /// Collect produced outputs by arg index (runtime-internal).
    pub(crate) fn take_outputs(&mut self) -> anyhow::Result<Vec<(usize, Vec<u8>)>> {
        let mut out = Vec::new();
        for (i, a) in self.args.iter_mut().enumerate() {
            match a {
                CtxArg::ObjOut(slot) | CtxArg::ObjInOut { output: slot, .. } => match slot.take() {
                    Some(v) => out.push((i, v)),
                    None => anyhow::bail!("task did not set output argument {i}"),
                },
                _ => {}
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dstream::DistroStreamHub;

    fn ctx(args: Vec<CtxArg>) -> TaskCtx {
        let (hub, _, _) = DistroStreamHub::embedded("test");
        TaskCtx {
            task_id: 0,
            worker_id: 0,
            cores: 1,
            attempt: 1,
            args,
            hub,
            zoo: None,
            scale: TimeScale::IDENTITY,
        }
    }

    #[test]
    fn registry_register_lookup() {
        register_task_fn("unit-test-task", |_ctx| Ok(()));
        assert!(lookup_task_fn("unit-test-task").is_some());
        assert!(lookup_task_fn("missing-task").is_none());
        assert!(registered_names().contains(&"unit-test-task".to_string()));
    }

    #[test]
    fn object_in_out_roundtrip() {
        let mut c = ctx(vec![
            CtxArg::ObjIn(Arc::new(7u64.encode_vec())),
            CtxArg::ObjOut(None),
        ]);
        let v: u64 = c.obj_in_as(0).unwrap();
        c.set_output_as(1, &(v * 2));
        let outs = c.take_outputs().unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(u64::decode_exact(&outs[0].1).unwrap(), 14);
    }

    #[test]
    fn missing_output_is_error() {
        let mut c = ctx(vec![CtxArg::ObjOut(None)]);
        assert!(c.take_outputs().is_err());
    }

    #[test]
    fn inout_exposes_input_and_takes_output() {
        let mut c = ctx(vec![CtxArg::ObjInOut { input: Arc::new(vec![1, 2]), output: None }]);
        assert_eq!(c.obj_in(0), &[1, 2]);
        c.set_output(0, vec![3]);
        assert_eq!(c.take_outputs().unwrap(), vec![(0, vec![3])]);
    }

    #[test]
    fn scalar_decoding() {
        let c = ctx(vec![CtxArg::Scalar(42u64.encode_vec())]);
        assert_eq!(c.scalar::<u64>(0).unwrap(), 42);
        assert!(c.scalar::<String>(0).is_err());
    }

    #[test]
    #[should_panic(expected = "not an object input")]
    fn wrong_arg_kind_panics() {
        let c = ctx(vec![CtxArg::Scalar(vec![])]);
        let _ = c.obj_in(0);
    }
}
