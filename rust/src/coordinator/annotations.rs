//! The task & parameter model — the programming-model surface.
//!
//! COMPSs declares tasks via Method/Parameter annotations (§3.1); here a
//! [`TaskSpec`] plays that role: it names a registered task function and
//! lists [`Arg`]s whose kind+direction drive dependency analysis, exactly
//! like the paper's `Type.OBJECT/FILE/STREAM` × `Direction.IN/OUT/INOUT`
//! (§4.4, Listing 6-7).

use crate::dstream::StreamHandle;
use crate::util::bytes::{ByteReader, ByteWriter, DecodeError};
use crate::util::wire::Wire;

/// Data access direction (paper §3.1 Parameter Annotations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Direction {
    In,
    Out,
    InOut,
}

impl Wire for Direction {
    fn encode(&self, w: &mut ByteWriter) {
        w.put_u8(match self {
            Direction::In => 0,
            Direction::Out => 1,
            Direction::InOut => 2,
        });
    }
    fn decode(r: &mut ByteReader) -> std::result::Result<Self, DecodeError> {
        let at = r.position();
        match r.get_u8()? {
            0 => Ok(Direction::In),
            1 => Ok(Direction::Out),
            2 => Ok(Direction::InOut),
            tag => Err(DecodeError::BadTag { at, tag: tag as u32, ty: "Direction" }),
        }
    }
}

/// Identifier of a runtime-managed datum (object). Allocated by
/// [`super::api::CometRuntime::new_object`].
pub type DataId = u64;

/// One task argument. Objects/files carry dependency semantics; streams do
/// not (the Hybrid-Workflow extension); scalars are immediate values.
#[derive(Debug, Clone, PartialEq)]
pub enum Arg {
    /// Read an object produced earlier (or registered from the main code).
    In(DataId),
    /// Produce a new object.
    Out(DataId),
    /// Read-modify-write an object (new version).
    InOut(DataId),
    /// Read a file path (dependency on its last writer task, if any).
    FileIn(String),
    /// Write a file path.
    FileOut(String),
    /// Read-modify-write a file.
    FileInOut(String),
    /// Consume from a stream — **no dependency edge** (paper §4.5).
    StreamIn(StreamHandle),
    /// Produce into a stream — **no dependency edge**.
    StreamOut(StreamHandle),
    /// Immediate value (wire-encoded), copied into the task.
    Scalar(Vec<u8>),
}

impl Arg {
    /// Scalar helper: encode any `Wire` value.
    pub fn scalar<T: Wire>(v: &T) -> Arg {
        Arg::Scalar(v.encode_vec())
    }

    /// Is this a stream parameter?
    pub fn is_stream(&self) -> bool {
        matches!(self, Arg::StreamIn(_) | Arg::StreamOut(_))
    }

    /// Direction of the argument.
    pub fn direction(&self) -> Direction {
        match self {
            Arg::In(_) | Arg::FileIn(_) | Arg::StreamIn(_) | Arg::Scalar(_) => Direction::In,
            Arg::Out(_) | Arg::FileOut(_) | Arg::StreamOut(_) => Direction::Out,
            Arg::InOut(_) | Arg::FileInOut(_) => Direction::InOut,
        }
    }
}

impl Wire for Arg {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            Arg::In(d) => {
                w.put_u8(0);
                d.encode(w);
            }
            Arg::Out(d) => {
                w.put_u8(1);
                d.encode(w);
            }
            Arg::InOut(d) => {
                w.put_u8(2);
                d.encode(w);
            }
            Arg::FileIn(p) => {
                w.put_u8(3);
                p.encode(w);
            }
            Arg::FileOut(p) => {
                w.put_u8(4);
                p.encode(w);
            }
            Arg::FileInOut(p) => {
                w.put_u8(5);
                p.encode(w);
            }
            Arg::StreamIn(h) => {
                w.put_u8(6);
                h.encode(w);
            }
            Arg::StreamOut(h) => {
                w.put_u8(7);
                h.encode(w);
            }
            Arg::Scalar(v) => {
                w.put_u8(8);
                v.encode(w);
            }
        }
    }
    fn decode(r: &mut ByteReader) -> std::result::Result<Self, DecodeError> {
        let at = r.position();
        Ok(match r.get_u8()? {
            0 => Arg::In(Wire::decode(r)?),
            1 => Arg::Out(Wire::decode(r)?),
            2 => Arg::InOut(Wire::decode(r)?),
            3 => Arg::FileIn(Wire::decode(r)?),
            4 => Arg::FileOut(Wire::decode(r)?),
            5 => Arg::FileInOut(Wire::decode(r)?),
            6 => Arg::StreamIn(Wire::decode(r)?),
            7 => Arg::StreamOut(Wire::decode(r)?),
            8 => Arg::Scalar(Wire::decode(r)?),
            tag => return Err(DecodeError::BadTag { at, tag: tag as u32, ty: "Arg" }),
        })
    }
}

/// A task invocation: registered function name + arguments + constraints.
///
/// The `cores` constraint mirrors the paper's
/// `@constraint(computing_units=...)` (Listing 8).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    pub name: String,
    pub args: Vec<Arg>,
    /// Core slots the task occupies on its worker.
    pub cores: usize,
    /// Optional explicit priority bump (producer priority is automatic).
    pub priority: bool,
}

impl TaskSpec {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), args: Vec::new(), cores: 1, priority: false }
    }

    pub fn arg(mut self, a: Arg) -> Self {
        self.args.push(a);
        self
    }

    pub fn args(mut self, args: impl IntoIterator<Item = Arg>) -> Self {
        self.args.extend(args);
        self
    }

    pub fn cores(mut self, n: usize) -> Self {
        assert!(n > 0, "a task needs at least one core");
        self.cores = n;
        self
    }

    pub fn priority(mut self) -> Self {
        self.priority = true;
        self
    }

    /// Does this task produce into any stream? (⇒ producer priority)
    pub fn is_stream_producer(&self) -> bool {
        self.args.iter().any(|a| matches!(a, Arg::StreamOut(_)))
    }

    /// Does this task consume from any stream?
    pub fn is_stream_consumer(&self) -> bool {
        self.args.iter().any(|a| matches!(a, Arg::StreamIn(_)))
    }
}

impl Wire for TaskSpec {
    fn encode(&self, w: &mut ByteWriter) {
        self.name.encode(w);
        self.args.encode(w);
        self.cores.encode(w);
        self.priority.encode(w);
    }
    fn decode(r: &mut ByteReader) -> std::result::Result<Self, DecodeError> {
        Ok(TaskSpec {
            name: Wire::decode(r)?,
            args: Wire::decode(r)?,
            cores: Wire::decode(r)?,
            priority: Wire::decode(r)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dstream::{BatchPolicy, ConsumerMode, StreamType};

    fn handle() -> StreamHandle {
        StreamHandle {
            id: 3,
            alias: None,
            stype: StreamType::Object,
            partitions: 2,
            base_dir: None,
            mode: ConsumerMode::ExactlyOnce,
            batch: BatchPolicy::default(),
        }
    }

    #[test]
    fn spec_builder_and_flags() {
        let spec = TaskSpec::new("simulation")
            .arg(Arg::StreamOut(handle()))
            .arg(Arg::scalar(&5u64))
            .cores(48);
        assert!(spec.is_stream_producer());
        assert!(!spec.is_stream_consumer());
        assert_eq!(spec.cores, 48);
        assert_eq!(spec.args.len(), 2);
    }

    #[test]
    fn arg_directions() {
        assert_eq!(Arg::In(1).direction(), Direction::In);
        assert_eq!(Arg::Out(1).direction(), Direction::Out);
        assert_eq!(Arg::InOut(1).direction(), Direction::InOut);
        assert_eq!(Arg::StreamOut(handle()).direction(), Direction::Out);
        assert_eq!(Arg::Scalar(vec![]).direction(), Direction::In);
        assert!(Arg::StreamIn(handle()).is_stream());
        assert!(!Arg::FileIn("x".into()).is_stream());
    }

    #[test]
    fn spec_wire_roundtrip() {
        let spec = TaskSpec::new("t")
            .arg(Arg::In(1))
            .arg(Arg::FileOut("/tmp/f".into()))
            .arg(Arg::StreamIn(handle()))
            .cores(2);
        assert_eq!(TaskSpec::decode_exact(&spec.encode_vec()).unwrap(), spec);
    }

    #[test]
    #[should_panic(expected = "at least one core")]
    fn zero_cores_rejected() {
        TaskSpec::new("t").cores(0);
    }
}
