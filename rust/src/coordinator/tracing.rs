//! Paraver-like execution traces (the Fig 14 instrumentation).
//!
//! Workers record one [`Span`] per task execution (worker, task name,
//! start/end). The log renders an ASCII gantt (one line per core-slot
//! group), computes the producer/consumer **overlap fraction** — the
//! quantity Fig 14 visualises — and dumps CSV for offline plotting.

use std::sync::Mutex;
use std::time::Instant;

/// One executed task span.
#[derive(Debug, Clone)]
pub struct Span {
    pub worker: usize,
    pub task: u64,
    pub name: String,
    /// Seconds since trace start.
    pub start_s: f64,
    pub end_s: f64,
}

/// Thread-safe trace collector.
#[derive(Debug)]
pub struct TraceLog {
    origin: Instant,
    spans: Mutex<Vec<Span>>,
}

impl Default for TraceLog {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceLog {
    pub fn new() -> Self {
        Self { origin: Instant::now(), spans: Mutex::new(Vec::new()) }
    }

    /// Timestamp (seconds since trace start).
    pub fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    pub fn record(&self, worker: usize, task: u64, name: &str, start_s: f64, end_s: f64) {
        self.spans.lock().unwrap().push(Span {
            worker,
            task,
            name: name.to_string(),
            start_s,
            end_s,
        });
    }

    pub fn spans(&self) -> Vec<Span> {
        self.spans.lock().unwrap().clone()
    }

    pub fn clear(&self) {
        self.spans.lock().unwrap().clear();
    }

    /// Makespan: last end minus first start (0 when empty).
    pub fn makespan(&self) -> f64 {
        let spans = self.spans.lock().unwrap();
        let start = spans.iter().map(|s| s.start_s).fold(f64::INFINITY, f64::min);
        let end = spans.iter().map(|s| s.end_s).fold(0.0, f64::max);
        if spans.is_empty() {
            0.0
        } else {
            end - start
        }
    }

    /// Fraction of `consumer_name` task time that overlaps any
    /// `producer_name` span — Fig 14's "processing while simulating".
    pub fn overlap_fraction(&self, producer_name: &str, consumer_name: &str) -> f64 {
        let spans = self.spans.lock().unwrap();
        let producers: Vec<(f64, f64)> = spans
            .iter()
            .filter(|s| s.name == producer_name)
            .map(|s| (s.start_s, s.end_s))
            .collect();
        let mut total = 0.0;
        let mut overlapped = 0.0;
        for s in spans.iter().filter(|s| s.name == consumer_name) {
            total += s.end_s - s.start_s;
            for &(ps, pe) in &producers {
                let lo = s.start_s.max(ps);
                let hi = s.end_s.min(pe);
                if hi > lo {
                    overlapped += hi - lo;
                }
            }
        }
        if total > 0.0 {
            (overlapped / total).min(1.0)
        } else {
            0.0
        }
    }

    /// CSV dump: `worker,task,name,start_s,end_s`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("worker,task,name,start_s,end_s\n");
        for s in self.spans.lock().unwrap().iter() {
            out.push_str(&format!(
                "{},{},{},{:.6},{:.6}\n",
                s.worker, s.task, s.name, s.start_s, s.end_s
            ));
        }
        out
    }

    /// ASCII gantt, one row per worker, `width` character columns.
    /// Task names map to letters (first letter, uppercased by worker row).
    pub fn ascii_gantt(&self, width: usize) -> String {
        let spans = self.spans.lock().unwrap();
        if spans.is_empty() {
            return String::from("(empty trace)\n");
        }
        let t0 = spans.iter().map(|s| s.start_s).fold(f64::INFINITY, f64::min);
        let t1 = spans.iter().map(|s| s.end_s).fold(0.0, f64::max);
        let dur = (t1 - t0).max(1e-9);
        let n_workers = spans.iter().map(|s| s.worker).max().unwrap_or(0) + 1;
        let mut rows = vec![vec![b'.'; width]; n_workers];
        // Later spans overwrite earlier ones — visually fine for a summary.
        for s in spans.iter() {
            let a = (((s.start_s - t0) / dur) * width as f64) as usize;
            let b = ((((s.end_s - t0) / dur) * width as f64).ceil() as usize).min(width);
            let ch = s.name.bytes().next().unwrap_or(b'?');
            for c in &mut rows[s.worker][a.min(width.saturating_sub(1))..b] {
                *c = ch;
            }
        }
        let mut out = String::new();
        out.push_str(&format!("gantt {:.3}s .. {:.3}s ({} spans)\n", t0, t1, spans.len()));
        for (w, row) in rows.iter().enumerate() {
            out.push_str(&format!("w{w:<2} |{}|\n", String::from_utf8_lossy(row)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_fraction_computes() {
        let t = TraceLog::new();
        // producer 0..10, consumers 5..7 (inside) and 12..14 (outside).
        t.record(0, 0, "sim", 0.0, 10.0);
        t.record(1, 1, "proc", 5.0, 7.0);
        t.record(1, 2, "proc", 12.0, 14.0);
        let f = t.overlap_fraction("sim", "proc");
        assert!((f - 0.5).abs() < 1e-9, "2 of 4 consumer seconds overlap, got {f}");
    }

    #[test]
    fn makespan_spans_everything() {
        let t = TraceLog::new();
        t.record(0, 0, "a", 1.0, 2.0);
        t.record(1, 1, "b", 0.5, 3.0);
        assert!((t.makespan() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn csv_and_gantt_render() {
        let t = TraceLog::new();
        t.record(0, 0, "sim", 0.0, 1.0);
        t.record(1, 1, "proc", 0.5, 1.0);
        let csv = t.to_csv();
        assert!(csv.contains("sim"));
        assert_eq!(csv.lines().count(), 3);
        let g = t.ascii_gantt(40);
        assert!(g.contains("w0"));
        assert!(g.contains('s'));
        assert!(g.contains('p'));
    }

    #[test]
    fn empty_trace_is_benign() {
        let t = TraceLog::new();
        assert_eq!(t.makespan(), 0.0);
        assert_eq!(t.overlap_fraction("a", "b"), 0.0);
        assert!(t.ascii_gantt(10).contains("empty"));
    }
}
