//! `CometRuntime`: the public facade of the task-based runtime — the role
//! COMPSs's master process plays in the paper.
//!
//! Building a runtime spawns the dispatcher thread, the in-process workers
//! (each with its own DistroStream identity), the embedded DistroStream
//! Server + broker (Fig 8's deployment, collapsed into one process) and —
//! optionally — the PJRT model zoo shared by all workers.
//!
//! ```no_run
//! use hybridws::coordinator::prelude::*;
//!
//! register_task_fn("hello", |ctx| {
//!     ctx.set_output(0, b"hi".to_vec());
//!     Ok(())
//! });
//! let rt = CometRuntime::builder().workers(&[4]).build().unwrap();
//! let out = rt.new_object();
//! rt.submit(TaskSpec::new("hello").arg(Arg::Out(out.id()))).unwrap();
//! assert_eq!(rt.wait_on(&out).unwrap().as_slice(), b"hi");
//! rt.shutdown().unwrap();
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::broker::{BrokerCore, ClusterClient, StreamBroker};
use crate::dstream::api::StreamId;
use crate::dstream::{
    BatchPolicy, ConsumerMode, DistroStreamHub, FileDistroStream, ObjectDistroStream,
    StreamCounters, StreamItem, StreamRegistry,
};
use crate::runtime::{find_artifacts_dir, ModelZoo};
use crate::util::timeutil::TimeScale;

use super::analyser::TaskId;
use super::annotations::{DataId, TaskSpec};
use super::data::WorkerId;
use super::dispatcher::{self, DispatcherConfig, Event, RuntimeStats};
use super::metrics::{MetricsRegistry, StreamStats};
use super::scheduler::SchedulerConfig;
use super::tracing::TraceLog;
use super::remote::RemoteWorker;
use super::worker::{FailPlan, LocalWorker, TransferModel, WorkerHandle};
use crate::broker::server::BrokerServer;
use crate::dstream::server::DistroStreamServer;

/// Handle to a runtime-managed object.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DataRef(DataId);

impl DataRef {
    pub fn id(&self) -> DataId {
        self.0
    }
}

/// Builder for [`CometRuntime`].
pub struct CometBuilder {
    worker_slots: Vec<usize>,
    scheduler: SchedulerConfig,
    max_retries: u32,
    scale: TimeScale,
    transfer: TransferModel,
    load_models: bool,
    name: String,
    /// Remote worker endpoints: (addr, slots).
    remote_workers: Vec<(String, usize)>,
    /// Broker storage configuration (default: everything in memory).
    broker: crate::broker::BrokerConfig,
    /// Cluster seed addresses: non-empty switches the runtime's streaming
    /// back-end from the embedded broker to a sharded cluster.
    cluster_seeds: Vec<String>,
}

impl Default for CometBuilder {
    fn default() -> Self {
        Self {
            worker_slots: vec![4],
            scheduler: SchedulerConfig::default(),
            max_retries: 2,
            scale: TimeScale::from_env(),
            transfer: TransferModel::default(),
            load_models: false,
            name: "comet".into(),
            remote_workers: Vec::new(),
            broker: crate::broker::BrokerConfig::memory(),
            cluster_seeds: Vec::new(),
        }
    }
}

impl CometBuilder {
    /// Core slots per worker, e.g. `&[36, 48]` for the paper's §6.2 layout.
    pub fn workers(mut self, slots: &[usize]) -> Self {
        assert!(!slots.is_empty(), "need at least one worker");
        self.worker_slots = slots.to_vec();
        self
    }

    pub fn scheduler(mut self, cfg: SchedulerConfig) -> Self {
        self.scheduler = cfg;
        self
    }

    pub fn max_retries(mut self, n: u32) -> Self {
        self.max_retries = n;
        self
    }

    /// Paper-time scaling for `sleep_paper_ms` task bodies.
    pub fn scale(mut self, scale: TimeScale) -> Self {
        self.scale = scale;
        self
    }

    /// Simulated network bandwidth for input transfers.
    pub fn bandwidth_mbps(mut self, mbps: f64) -> Self {
        self.transfer = TransferModel { bandwidth_mbps: Some(mbps) };
        self
    }

    /// Load the AOT artifacts (PJRT) so tasks can call `ctx.models()`.
    pub fn with_models(mut self) -> Self {
        self.load_models = true;
        self
    }

    pub fn name(mut self, name: &str) -> Self {
        self.name = name.into();
        self
    }

    /// Attach a remote worker process (started with `hybridws worker`)
    /// listening at `addr` with `slots` core slots. When any remote worker
    /// is attached the builder also exposes the DistroStream Server and the
    /// broker over TCP so the remote side can reach them.
    pub fn remote_worker(mut self, addr: &str, slots: usize) -> Self {
        self.remote_workers.push((addr.to_string(), slots));
        self
    }

    /// Durable streams: flip the embedded broker to
    /// [`crate::broker::StorageMode::Disk`] under `dir`. Acked stream
    /// records and committed consumer-group offsets survive a broker
    /// restart; topics already persisted under `dir` are recovered when
    /// the runtime builds.
    pub fn data_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        // The runtime owns the dstream topic namespace, so it also opts in
        // to reaping stale anonymous-stream topics at boot (session-scoped
        // ids restart at 0 — recovering them would hand a fresh stream a
        // previous session's records).
        self.broker = crate::broker::BrokerConfig::disk(dir).reap_session_scoped(true);
        self
    }

    /// Full broker storage configuration (per-topic modes, segment sizes,
    /// retention). [`CometBuilder::data_dir`] is the common shorthand.
    pub fn broker_config(mut self, cfg: crate::broker::BrokerConfig) -> Self {
        self.broker = cfg;
        self
    }

    /// Scale-out streams: back every hub in this runtime with a **sharded
    /// broker cluster** instead of the embedded broker. `seeds` is the
    /// static member list of `hybridws broker --cluster-seed …` processes;
    /// topics shard across them by the rendezvous placement function and
    /// stream code is unchanged. Each member's durability is its own
    /// (`--data-dir` per broker process); a member that restarts recovers
    /// its shard and this runtime's consumers resume from their committed
    /// offsets.
    pub fn cluster<S: AsRef<str>>(mut self, seeds: &[S]) -> Self {
        self.cluster_seeds = seeds.iter().map(|s| s.as_ref().to_string()).collect();
        self
    }

    pub fn build(self) -> Result<CometRuntime> {
        crate::util::logging::init();
        // Deployment (paper Fig 8): master spawns the DistroStream Server
        // and the backend; every worker gets a client with its own
        // identity. The backend is the embedded broker by default, or a
        // sharded cluster when seeds were given — one trait object either
        // way, so everything downstream is identical.
        let (master_hub, registry, broker, cluster) = if self.cluster_seeds.is_empty() {
            let (hub, registry, core) = DistroStreamHub::embedded_with(
                &format!("{}-master", self.name),
                self.broker.clone(),
            )
            .map_err(|e| anyhow!("broker storage: {e}"))?;
            (hub, registry, Some(core), None)
        } else {
            if !self.remote_workers.is_empty() {
                // Remote workers receive one broker address today; routing
                // them through a cluster needs seed-list plumbing in the
                // worker handshake first.
                anyhow::bail!("cluster mode and remote workers cannot be combined yet");
            }
            let registry = Arc::new(Mutex::new(StreamRegistry::new()));
            let cc: Arc<ClusterClient> = Arc::new(
                ClusterClient::connect(&self.cluster_seeds)
                    .map_err(|e| anyhow!("cluster connect: {e}"))?,
            );
            let hub = DistroStreamHub::attach_with_broker(
                &format!("{}-master", self.name),
                &registry,
                Arc::<ClusterClient>::clone(&cc) as Arc<dyn StreamBroker>,
            );
            (hub, registry, None, Some(cc))
        };

        let zoo = if self.load_models {
            let dir = find_artifacts_dir()
                .ok_or_else(|| anyhow!("artifacts not found — run `make artifacts`"))?;
            Some(Arc::new(ModelZoo::load(&dir)?))
        } else {
            None
        };

        let metrics = Arc::new(MetricsRegistry::new());
        let trace = Arc::new(TraceLog::new());
        let fail_plan = Arc::new(FailPlan::default());
        let (tx, rx) = mpsc::channel::<Event>();

        let mut hubs: Vec<Arc<DistroStreamHub>> = vec![Arc::clone(&master_hub)];
        let workers: Vec<Arc<LocalWorker>> = self
            .worker_slots
            .iter()
            .enumerate()
            .map(|(i, &slots)| {
                let worker_name = format!("{}-worker{i}", self.name);
                let hub = match (&broker, &cluster) {
                    (Some(core), _) => {
                        DistroStreamHub::attach_embedded(&worker_name, &registry, core)
                    }
                    (None, Some(cc)) => DistroStreamHub::attach_with_broker(
                        &worker_name,
                        &registry,
                        Arc::<ClusterClient>::clone(cc) as Arc<dyn StreamBroker>,
                    ),
                    (None, None) => unreachable!("a backend (embedded or cluster) always exists"),
                };
                hubs.push(Arc::clone(&hub));
                LocalWorker::new(
                    i,
                    slots,
                    hub,
                    zoo.clone(),
                    Arc::clone(&trace),
                    Arc::clone(&metrics),
                    tx.clone(),
                    self.scale,
                    self.transfer,
                    Arc::clone(&fail_plan),
                )
            })
            .collect();

        // Remote workers: expose the control planes over TCP, then connect.
        let mut servers = Vec::new();
        let mut handles: Vec<Arc<dyn WorkerHandle>> =
            workers.iter().map(|w| Arc::clone(w) as Arc<dyn WorkerHandle>).collect();
        if !self.remote_workers.is_empty() {
            let core = broker
                .as_ref()
                .expect("cluster mode with remote workers is rejected above");
            let broker_srv = BrokerServer::start(Arc::clone(core), "127.0.0.1:0")?;
            let ds_srv = DistroStreamServer::start_with(Arc::clone(&registry), "127.0.0.1:0")?;
            let broker_addr = broker_srv.addr.to_string();
            let ds_addr = ds_srv.addr.to_string();
            for (addr, slots) in &self.remote_workers {
                let id = handles.len();
                let rw = RemoteWorker::connect(
                    id,
                    *slots,
                    addr,
                    &ds_addr,
                    &broker_addr,
                    self.scale,
                    self.load_models,
                    tx.clone(),
                )?;
                handles.push(rw as Arc<dyn WorkerHandle>);
            }
            servers.push(Servers { _broker: broker_srv, _ds: ds_srv });
        }

        let max_task_cores =
            handles.iter().map(|h| h.slot_count()).max().unwrap_or(0);
        let cfg = DispatcherConfig { scheduler: self.scheduler, max_retries: self.max_retries };
        let d_workers = handles;
        let d_metrics = Arc::clone(&metrics);
        let dispatcher = std::thread::Builder::new()
            .name("dispatcher".into())
            .spawn(move || dispatcher::run(rx, d_workers, d_metrics, cfg))?;

        Ok(CometRuntime {
            tx,
            next_task: AtomicU64::new(0),
            max_task_cores,
            dispatcher: Mutex::new(Some(dispatcher)),
            hub: master_hub,
            registry,
            broker,
            zoo,
            metrics,
            trace,
            fail_plan,
            workers,
            hubs,
            _servers: servers,
            scale: self.scale,
        })
    }
}

/// Keeps the TCP control planes alive for remote-worker deployments.
struct Servers {
    _broker: BrokerServer,
    _ds: DistroStreamServer,
}

/// The runtime handle used by application main code.
pub struct CometRuntime {
    tx: mpsc::Sender<Event>,
    /// Pre-allocated task ids (submit is fire-and-forget; the dispatcher's
    /// analyser consumes ids in submission order).
    next_task: AtomicU64,
    /// Largest worker slot count (local submit validation).
    max_task_cores: usize,
    dispatcher: Mutex<Option<JoinHandle<()>>>,
    hub: Arc<DistroStreamHub>,
    registry: Arc<Mutex<StreamRegistry>>,
    /// The embedded broker core (`None` when the runtime is backed by a
    /// cluster — the shards live in other processes).
    broker: Option<Arc<BrokerCore>>,
    zoo: Option<Arc<ModelZoo>>,
    metrics: Arc<MetricsRegistry>,
    trace: Arc<TraceLog>,
    fail_plan: Arc<FailPlan>,
    workers: Vec<Arc<LocalWorker>>,
    /// Every hub in this process (master + workers) — deployment-wide knobs.
    hubs: Vec<Arc<DistroStreamHub>>,
    _servers: Vec<Servers>,
    scale: TimeScale,
}

impl CometRuntime {
    pub fn builder() -> CometBuilder {
        CometBuilder::default()
    }

    fn rpc<T>(&self, make: impl FnOnce(mpsc::Sender<T>) -> Event) -> Result<T> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(make(tx)).map_err(|_| anyhow!("runtime is shut down"))?;
        rx.recv().map_err(|_| anyhow!("dispatcher dropped the reply"))
    }

    // ---- data ------------------------------------------------------------

    /// Allocate an object that a task will produce.
    pub fn new_object(&self) -> DataRef {
        DataRef(self.rpc(|reply| Event::NewData { reply }).expect("runtime alive"))
    }

    /// Register a main-code value as an object.
    pub fn register_object(&self, value: Vec<u8>) -> DataRef {
        DataRef(self.rpc(|reply| Event::RegisterData { value, reply }).expect("runtime alive"))
    }

    /// Typed variant of [`CometRuntime::register_object`].
    pub fn register_object_as<T: crate::util::wire::Wire>(&self, v: &T) -> DataRef {
        self.register_object(v.encode_vec())
    }

    // ---- tasks -------------------------------------------------------------

    /// Submit a task; returns its id immediately (execution is async,
    /// submission is fire-and-forget — no dispatcher round-trip).
    ///
    /// # Examples
    ///
    /// A hybrid submission: the task consumes a `STREAM` parameter while
    /// the main code keeps publishing (the batched `publish_list` ships
    /// the whole list as one broker request):
    ///
    /// ```
    /// # fn main() -> anyhow::Result<()> {
    /// use hybridws::coordinator::prelude::*;
    ///
    /// register_task_fn("doc.sum-stream", |ctx| {
    ///     let s = ctx.object_stream::<u64>(0); // STREAM_IN
    ///     let mut sum = 0u64;
    ///     loop {
    ///         let closed = s.is_closed();
    ///         // One blocking batched fetch: parks until data arrives
    ///         // (wakeup-driven — no sleep-spin), bounded so the close
    ///         // flag is re-checked.
    ///         let items = s.poll_timeout(std::time::Duration::from_millis(10))?;
    ///         sum += items.iter().sum::<u64>();
    ///         if items.is_empty() && closed {
    ///             break;
    ///         }
    ///     }
    ///     ctx.set_output_as(1, &sum);
    ///     Ok(())
    /// });
    ///
    /// let rt = CometRuntime::builder().workers(&[2]).build()?;
    /// let numbers = rt.object_stream::<u64>(Some("doc-numbers"))?;
    /// let out = rt.new_object();
    /// rt.submit(
    ///     TaskSpec::new("doc.sum-stream")
    ///         .arg(Arg::StreamIn(numbers.handle().clone()))
    ///         .arg(Arg::Out(out.id())),
    /// )?;
    /// numbers.publish_list(&[1, 2, 3, 4])?;
    /// numbers.close()?;
    /// assert_eq!(rt.wait_on_as::<u64>(&out)?, 10);
    /// rt.shutdown()?;
    /// # Ok(())
    /// # }
    /// ```
    pub fn submit(&self, spec: TaskSpec) -> Result<TaskId> {
        if spec.cores > self.max_task_cores {
            anyhow::bail!(
                "task {:?} needs {} cores but the largest worker has {}",
                spec.name,
                spec.cores,
                self.max_task_cores
            );
        }
        let id = self.next_task.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Event::Submit { id, spec }).map_err(|_| anyhow!("runtime is shut down"))?;
        Ok(id)
    }

    /// Wait for (and fetch) the latest version of an object — the paper's
    /// `compss_wait_on`.
    pub fn wait_on(&self, d: &DataRef) -> Result<Arc<Vec<u8>>> {
        self.rpc(|reply| Event::WaitData { data: d.0, reply })?.map_err(|e| anyhow!(e))
    }

    /// Typed variant of [`CometRuntime::wait_on`].
    pub fn wait_on_as<T: crate::util::wire::Wire>(&self, d: &DataRef) -> Result<T> {
        let bytes = self.wait_on(d)?;
        T::decode_exact(&bytes).map_err(|e| anyhow!("decode: {e}"))
    }

    /// Wait until the last writer task of `path` completed — the paper's
    /// `compss_wait_on_file` / `compss_open`.
    pub fn wait_on_file(&self, path: &str) -> Result<()> {
        self.rpc(|reply| Event::WaitFile { path: path.to_string(), reply })?
            .map_err(|e| anyhow!(e))
    }

    /// Wait for every submitted task — the paper's `compss_barrier`.
    pub fn barrier(&self) -> Result<()> {
        self.rpc(|reply| Event::Barrier { reply })
    }

    // ---- streams --------------------------------------------------------------

    /// The master's DistroStream hub.
    pub fn hub(&self) -> &Arc<DistroStreamHub> {
        &self.hub
    }

    /// Deployment-wide per-poll record cap (the §6.4 balanced-poll policy);
    /// applies to the master and every in-process worker hub.
    pub fn set_max_poll_records(&self, n: usize) {
        for h in &self.hubs {
            h.set_max_poll_records(n);
        }
    }

    /// Create an object stream from the main code.
    pub fn object_stream<T: StreamItem>(
        &self,
        alias: Option<&str>,
    ) -> Result<ObjectDistroStream<T>> {
        self.hub.object_stream(alias).map_err(|e| anyhow!(e.to_string()))
    }

    /// Create an object stream with explicit partitions and consumer mode.
    pub fn object_stream_with<T: StreamItem>(
        &self,
        alias: Option<&str>,
        partitions: usize,
        mode: ConsumerMode,
    ) -> Result<ObjectDistroStream<T>> {
        self.hub.object_stream_with(alias, partitions, mode).map_err(|e| anyhow!(e.to_string()))
    }

    /// Create an object stream with default partitions/mode and an
    /// explicit [`BatchPolicy`] — the policy travels inside the handle,
    /// so tasks receiving the stream as a `STREAM` parameter inherit the
    /// tuning.
    pub fn object_stream_batched<T: StreamItem>(
        &self,
        alias: Option<&str>,
        batch: BatchPolicy,
    ) -> Result<ObjectDistroStream<T>> {
        self.hub.object_stream_batched(alias, batch).map_err(|e| anyhow!(e.to_string()))
    }

    /// Create an object stream with explicit partitions, consumer mode
    /// and [`BatchPolicy`].
    pub fn object_stream_tuned<T: StreamItem>(
        &self,
        alias: Option<&str>,
        partitions: usize,
        mode: ConsumerMode,
        batch: BatchPolicy,
    ) -> Result<ObjectDistroStream<T>> {
        self.hub
            .object_stream_tuned(alias, partitions, mode, batch)
            .map_err(|e| anyhow!(e.to_string()))
    }

    /// Create a file stream over `base_dir` from the main code.
    pub fn file_stream(&self, alias: Option<&str>, base_dir: &str) -> Result<FileDistroStream> {
        self.hub.file_stream(alias, base_dir).map_err(|e| anyhow!(e.to_string()))
    }

    // ---- introspection -----------------------------------------------------------

    pub fn stats(&self) -> RuntimeStats {
        self.rpc(|reply| Event::Stats { reply }).unwrap_or_default()
    }

    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Per-stream data-plane counters (records / batches / bytes in-out),
    /// aggregated over the master and every in-process worker hub. Remote
    /// worker processes keep their own counters.
    ///
    /// This is a *snapshot*: each call re-aggregates the live hub
    /// counters and refreshes the mirror in [`CometRuntime::metrics`] —
    /// `metrics().stream(..)` / `metrics().streams()` return the state as
    /// of the most recent `stream_metrics()` call (and nothing before the
    /// first one).
    pub fn stream_metrics(&self) -> Vec<(StreamId, StreamStats)> {
        let mut agg: std::collections::BTreeMap<StreamId, StreamCounters> =
            std::collections::BTreeMap::new();
        for hub in &self.hubs {
            for (id, c) in hub.all_stream_counters() {
                agg.entry(id).or_default().merge(&c);
            }
        }
        // Join in the broker-side storage gauges (durable object streams;
        // file streams have no broker topic and keep zeros). Topic names
        // are alias-keyed when the stream has one (the restart-stable
        // durable name), id-keyed otherwise. One registry lock snapshots
        // every alias before the per-topic stats calls.
        let aliases: std::collections::BTreeMap<StreamId, Option<String>> = {
            let reg = self.registry.lock().unwrap();
            agg.keys().map(|&id| (id, reg.entry(id).and_then(|e| e.alias.clone()))).collect()
        };
        for (id, c) in agg.iter_mut() {
            let topic = match aliases.get(id).and_then(|a| a.as_deref()) {
                Some(a) => crate::dstream::api::topic_for_alias(a),
                None => crate::dstream::api::topic_for(*id),
            };
            // Through the hub's backend handle so cluster-backed runtimes
            // report merged per-shard storage gauges too.
            if let Ok(ts) = self.hub.broker().topic_stats(&topic) {
                c.bytes_on_disk = ts.bytes_on_disk;
                c.segments = ts.segments as u64;
                c.recovered_records = ts.recovered_records;
            }
        }
        // `StreamStats` is an alias of the hub-side `StreamCounters`, so
        // the aggregate passes through unchanged.
        let out: Vec<(StreamId, StreamStats)> = agg.into_iter().collect();
        for &(id, stats) in &out {
            self.metrics.set_stream(id, stats);
        }
        out
    }

    pub fn trace(&self) -> &Arc<TraceLog> {
        &self.trace
    }

    pub fn models(&self) -> Option<&Arc<ModelZoo>> {
        self.zoo.as_ref()
    }

    pub fn scale(&self) -> TimeScale {
        self.scale
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Shared embedded broker core (diagnostics in tests/benches); `None`
    /// when the runtime streams through a cluster.
    pub fn broker(&self) -> Option<&Arc<BrokerCore>> {
        self.broker.as_ref()
    }

    /// Shared stream registry (diagnostics in tests/benches).
    pub fn stream_registry(&self) -> &Arc<Mutex<StreamRegistry>> {
        &self.registry
    }

    // ---- fault injection -------------------------------------------------------

    /// Force the next `n` attempts of task `name` to fail.
    pub fn inject_failure(&self, name: &str, n: u32) {
        self.fail_plan.fail_next(name, n);
    }

    /// Simulate the death of worker `w` (its running tasks resubmit).
    pub fn kill_worker(&self, w: WorkerId) -> Result<()> {
        self.tx.send(Event::KillWorker { worker: w }).map_err(|_| anyhow!("runtime shut down"))
    }

    // ---- lifecycle ------------------------------------------------------------------

    /// Drain outstanding work and stop the dispatcher.
    pub fn shutdown(&self) -> Result<()> {
        self.barrier().ok();
        self.tx.send(Event::Shutdown).ok();
        if let Some(h) = self.dispatcher.lock().unwrap().take() {
            h.join().map_err(|_| anyhow!("dispatcher panicked"))?;
        }
        Ok(())
    }
}

impl Drop for CometRuntime {
    fn drop(&mut self) {
        self.tx.send(Event::Shutdown).ok();
        if let Some(h) = self.dispatcher.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::annotations::Arg;
    use crate::coordinator::executor::register_task_fn;

    fn rt() -> CometRuntime {
        CometRuntime::builder().workers(&[2, 2]).scale(TimeScale::IDENTITY).build().unwrap()
    }

    #[test]
    fn object_task_roundtrip() {
        register_task_fn("api-add", |ctx| {
            let a: u64 = ctx.obj_in_as(0)?;
            let b: u64 = ctx.scalar(1)?;
            ctx.set_output_as(2, &(a + b));
            Ok(())
        });
        let rt = rt();
        let a = rt.register_object_as(&40u64);
        let out = rt.new_object();
        rt.submit(
            TaskSpec::new("api-add")
                .arg(Arg::In(a.id()))
                .arg(Arg::scalar(&2u64))
                .arg(Arg::Out(out.id())),
        )
        .unwrap();
        let v: u64 = rt.wait_on_as(&out).unwrap();
        assert_eq!(v, 42);
        rt.shutdown().unwrap();
    }

    #[test]
    fn chain_of_tasks_respects_dependencies() {
        register_task_fn("api-inc", |ctx| {
            let v: u64 = ctx.obj_in_as(0)?;
            ctx.set_output_as(0, &(v + 1));
            Ok(())
        });
        let rt = rt();
        let d = rt.register_object_as(&0u64);
        for _ in 0..10 {
            rt.submit(TaskSpec::new("api-inc").arg(Arg::InOut(d.id()))).unwrap();
        }
        let v: u64 = rt.wait_on_as(&d).unwrap();
        assert_eq!(v, 10, "InOut chain must serialise");
        rt.shutdown().unwrap();
    }

    #[test]
    fn fan_out_runs_in_parallel() {
        register_task_fn("api-sleepy", |ctx| {
            std::thread::sleep(std::time::Duration::from_millis(30));
            ctx.set_output_as(0, &1u64);
            Ok(())
        });
        let rt = rt();
        let outs: Vec<DataRef> = (0..4).map(|_| rt.new_object()).collect();
        let t0 = std::time::Instant::now();
        for o in &outs {
            rt.submit(TaskSpec::new("api-sleepy").arg(Arg::Out(o.id()))).unwrap();
        }
        rt.barrier().unwrap();
        let elapsed = t0.elapsed();
        // 4 tasks × 30 ms on 4 total slots → ~30 ms, far below serial 120 ms.
        assert!(elapsed < std::time::Duration::from_millis(100), "took {elapsed:?}");
        rt.shutdown().unwrap();
    }

    #[test]
    fn retry_recovers_from_injected_failures() {
        register_task_fn("api-flaky", |ctx| {
            ctx.set_output_as(0, &7u64);
            Ok(())
        });
        let rt = CometRuntime::builder().workers(&[2]).max_retries(2).build().unwrap();
        rt.inject_failure("api-flaky", 2);
        let out = rt.new_object();
        rt.submit(TaskSpec::new("api-flaky").arg(Arg::Out(out.id()))).unwrap();
        let v: u64 = rt.wait_on_as(&out).unwrap();
        assert_eq!(v, 7);
        let m = rt.metrics().task(0).unwrap();
        assert_eq!(m.attempts, 3, "two failures + one success");
        rt.shutdown().unwrap();
    }

    #[test]
    fn permanent_failure_propagates_to_wait_on() {
        register_task_fn("api-doomed", |ctx| {
            ctx.set_output_as(0, &0u64);
            Ok(())
        });
        let rt = CometRuntime::builder().workers(&[2]).max_retries(0).build().unwrap();
        rt.inject_failure("api-doomed", 1);
        let out = rt.new_object();
        rt.submit(TaskSpec::new("api-doomed").arg(Arg::Out(out.id()))).unwrap();
        assert!(rt.wait_on(&out).is_err());
        let stats = rt.stats();
        assert_eq!(stats.failed, 1);
        rt.shutdown().unwrap();
    }

    #[test]
    fn oversized_task_is_rejected_cleanly() {
        let rt = rt();
        let err = rt.submit(TaskSpec::new("whatever").cores(99)).unwrap_err();
        assert!(err.to_string().contains("99"));
        rt.shutdown().unwrap();
    }

    #[test]
    fn worker_death_resubmits_tasks() {
        register_task_fn("api-slow", |ctx| {
            std::thread::sleep(std::time::Duration::from_millis(50));
            ctx.set_output_as(0, &ctx.worker_id.try_into().unwrap_or(0u64));
            Ok(())
        });
        let rt = rt();
        let outs: Vec<DataRef> = (0..4).map(|_| rt.new_object()).collect();
        for o in &outs {
            rt.submit(TaskSpec::new("api-slow").arg(Arg::Out(o.id()))).unwrap();
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        rt.kill_worker(0).unwrap();
        for o in &outs {
            let v: u64 = rt.wait_on_as(o).unwrap();
            assert_eq!(v, 1, "all tasks must end on the surviving worker");
        }
        rt.shutdown().unwrap();
    }

    #[test]
    fn stream_metrics_aggregate_worker_hubs() {
        register_task_fn("api-stream-consume", |ctx| {
            let s = ctx.object_stream::<u64>(0);
            let mut n = 0u64;
            loop {
                let closed = s.is_closed();
                let items = s.poll_timeout(std::time::Duration::from_millis(5))?;
                n += items.len() as u64;
                if items.is_empty() && closed {
                    break;
                }
            }
            ctx.set_output_as(1, &n);
            Ok(())
        });
        let rt = rt();
        let s = rt.object_stream::<u64>(Some("api-metrics")).unwrap();
        let out = rt.new_object();
        rt.submit(
            TaskSpec::new("api-stream-consume")
                .arg(Arg::StreamIn(s.handle().clone()))
                .arg(Arg::Out(out.id())),
        )
        .unwrap();
        s.publish_list(&[1, 2, 3, 4, 5]).unwrap();
        s.close().unwrap();
        assert_eq!(rt.wait_on_as::<u64>(&out).unwrap(), 5);
        let metrics = rt.stream_metrics();
        let (_, stats) = metrics
            .iter()
            .find(|&&(id, _)| id == s.id())
            .expect("stream must appear in metrics");
        // Publishing happened on the master hub, polling on a worker hub —
        // both must be visible in the aggregate.
        assert_eq!(stats.records_out, 5);
        assert_eq!(stats.batches_out, 1, "publish_list is one batch");
        assert_eq!(stats.records_in, 5);
        assert!(stats.records_per_publish() >= 5.0);
        // Mirrored into the metrics registry for later inspection.
        assert_eq!(rt.metrics().stream(s.id()).unwrap().records_in, 5);
        rt.shutdown().unwrap();
    }

    #[test]
    fn data_dir_runtime_reports_storage_gauges() {
        let dir =
            std::env::temp_dir().join(format!("hybridws-api-disk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let rt = CometRuntime::builder()
            .workers(&[2])
            .scale(TimeScale::IDENTITY)
            .data_dir(&dir)
            .build()
            .unwrap();
        let s = rt.object_stream::<u64>(Some("durable")).unwrap();
        s.publish_list(&[1, 2, 3]).unwrap();
        assert_eq!(s.poll().unwrap().len(), 3);
        let metrics = rt.stream_metrics();
        let (_, stats) =
            metrics.iter().find(|&&(id, _)| id == s.id()).expect("stream in metrics");
        assert!(stats.bytes_on_disk > 0, "disk-mode stream must report segment bytes");
        assert!(stats.segments >= 1);
        assert_eq!(stats.recovered_records, 0, "fresh dir: nothing to recover");
        rt.shutdown().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stats_reflect_lifecycle() {
        register_task_fn("api-quick", |ctx| {
            ctx.set_output_as(0, &1u64);
            Ok(())
        });
        let rt = rt();
        let o = rt.new_object();
        rt.submit(TaskSpec::new("api-quick").arg(Arg::Out(o.id()))).unwrap();
        rt.barrier().unwrap();
        let s = rt.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.active, 0);
        rt.shutdown().unwrap();
    }
}
