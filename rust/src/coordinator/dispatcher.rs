//! The **Task Dispatcher**: the single-threaded event loop that owns the
//! analyser, graph and scheduler, drives executions and implements fault
//! tolerance (paper §4.5 / Fig 7).
//!
//! Everything mutates inside one thread, so the per-phase timings recorded
//! here (analysis / scheduling) measure exactly the code the paper's Fig
//! 21-22 measures, with no lock noise.

use std::collections::HashMap;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use log::{debug, warn};

use crate::dstream::api::StreamId;

use super::analyser::{TaskAnalyser, TaskId, TaskRecord};
use super::annotations::{DataId, TaskSpec};
use super::data::{Key, WorkerId, MASTER};
use super::graph::TaskGraph;
use super::metrics::MetricsRegistry;
use super::scheduler::{SchedulerConfig, TaskScheduler};
use super::worker::{Job, WorkerHandle};

/// Events processed by the dispatcher loop.
pub enum Event {
    /// Main code submits a task (id pre-allocated by the runtime).
    Submit { id: TaskId, spec: TaskSpec },
    /// Allocate a fresh datum id.
    NewData { reply: mpsc::Sender<DataId> },
    /// Register a main-code value.
    RegisterData { value: Vec<u8>, reply: mpsc::Sender<DataId> },
    /// A worker finished (or failed) a task.
    Finished {
        task: TaskId,
        worker: WorkerId,
        outputs: Vec<(Key, Arc<Vec<u8>>)>,
        error: Option<String>,
    },
    /// Main code waits for the latest version of a datum.
    WaitData { data: DataId, reply: mpsc::Sender<Result<Arc<Vec<u8>>, String>> },
    /// Main code waits for the last writer of a file path.
    WaitFile { path: String, reply: mpsc::Sender<Result<(), String>> },
    /// Main code waits for all submitted tasks.
    Barrier { reply: mpsc::Sender<()> },
    /// Simulate a node death.
    KillWorker { worker: WorkerId },
    /// Runtime statistics snapshot.
    Stats { reply: mpsc::Sender<RuntimeStats> },
    Shutdown,
}

impl std::fmt::Debug for Event {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let name = match self {
            Event::Submit { .. } => "Submit",
            Event::NewData { .. } => "NewData",
            Event::RegisterData { .. } => "RegisterData",
            Event::Finished { .. } => "Finished",
            Event::WaitData { .. } => "WaitData",
            Event::WaitFile { .. } => "WaitFile",
            Event::Barrier { .. } => "Barrier",
            Event::KillWorker { .. } => "KillWorker",
            Event::Stats { .. } => "Stats",
            Event::Shutdown => "Shutdown",
        };
        write!(f, "Event::{name}")
    }
}

/// Live runtime counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RuntimeStats {
    pub submitted: usize,
    pub completed: usize,
    pub failed: usize,
    pub active: usize,
    pub ready: usize,
    pub running: usize,
    pub free_slots: usize,
}

/// Dispatcher configuration.
#[derive(Debug, Clone, Copy)]
pub struct DispatcherConfig {
    pub scheduler: SchedulerConfig,
    /// Extra attempts after the first failure.
    pub max_retries: u32,
}

impl Default for DispatcherConfig {
    fn default() -> Self {
        Self { scheduler: SchedulerConfig::default(), max_retries: 2 }
    }
}

struct State {
    analyser: TaskAnalyser,
    graph: TaskGraph,
    scheduler: TaskScheduler,
    records: HashMap<TaskId, TaskRecord>,
    enqueue_time: HashMap<TaskId, Instant>,
    workers: Vec<Arc<dyn WorkerHandle>>,
    dead_workers: Vec<bool>,
    metrics: Arc<MetricsRegistry>,
    cfg: DispatcherConfig,
    // Waiters.
    data_waiters: HashMap<Key, Vec<mpsc::Sender<Result<Arc<Vec<u8>>, String>>>>,
    file_waiters: HashMap<TaskId, Vec<mpsc::Sender<Result<(), String>>>>,
    barrier_waiters: Vec<mpsc::Sender<()>>,
    // Counters.
    submitted: usize,
    completed: usize,
    failed: usize,
    active: usize,
    submit_time: HashMap<TaskId, Instant>,
}

/// Run the dispatcher loop until `Shutdown`. Spawned by the runtime on a
/// dedicated thread.
pub fn run(
    rx: mpsc::Receiver<Event>,
    workers: Vec<Arc<dyn WorkerHandle>>,
    metrics: Arc<MetricsRegistry>,
    cfg: DispatcherConfig,
) {
    let slots: Vec<usize> = workers.iter().map(|w| w.slot_count()).collect();
    let mut st = State {
        analyser: TaskAnalyser::new(),
        graph: TaskGraph::new(),
        scheduler: TaskScheduler::new(&slots, cfg.scheduler),
        records: HashMap::new(),
        enqueue_time: HashMap::new(),
        dead_workers: vec![false; workers.len()],
        workers,
        metrics,
        cfg,
        data_waiters: HashMap::new(),
        file_waiters: HashMap::new(),
        barrier_waiters: Vec::new(),
        submitted: 0,
        completed: 0,
        failed: 0,
        active: 0,
        submit_time: HashMap::new(),
    };

    while let Ok(event) = rx.recv() {
        match event {
            Event::Shutdown => break,
            e => handle(&mut st, e),
        }
    }
    // Orderly disconnect (remote workers end their sessions).
    for w in &st.workers {
        w.disconnect();
    }
}

fn handle(st: &mut State, event: Event) {
    match event {
        Event::Submit { id, spec } => on_submit(st, id, spec),
        Event::NewData { reply } => {
            let _ = reply.send(st.analyser.data.new_data());
        }
        Event::RegisterData { value, reply } => {
            let _ = reply.send(st.analyser.data.register_value(value));
        }
        Event::Finished { task, worker, outputs, error } => {
            on_finished(st, task, worker, outputs, error)
        }
        Event::WaitData { data, reply } => on_wait_data(st, data, reply),
        Event::WaitFile { path, reply } => on_wait_file(st, &path, reply),
        Event::Barrier { reply } => {
            if st.active == 0 {
                let _ = reply.send(());
            } else {
                st.barrier_waiters.push(reply);
            }
        }
        Event::KillWorker { worker } => on_kill_worker(st, worker),
        Event::Stats { reply } => {
            let _ = reply.send(RuntimeStats {
                submitted: st.submitted,
                completed: st.completed,
                failed: st.failed,
                active: st.active,
                ready: st.scheduler.ready_count(),
                running: st.scheduler.running_count(),
                free_slots: st.scheduler.free_slots(),
            });
        }
        Event::Shutdown => unreachable!("handled by caller"),
    }
}

fn on_submit(st: &mut State, id: TaskId, spec: TaskSpec) {
    // ---- Task Analyser (Fig 21 timing) ----------------------------------
    let name = spec.name.clone();
    let t0 = Instant::now();
    let (record, deps) = st.analyser.analyse_with_id(id, spec, st.cfg.max_retries);
    let analysis = t0.elapsed();
    st.metrics.on_analysis(record.id, &name, analysis);

    st.submitted += 1;
    st.active += 1;
    st.submit_time.insert(id, Instant::now());
    let ready = st.graph.add_task(id, &deps);
    st.records.insert(id, record);

    if ready {
        enqueue(st, id);
        run_schedule(st);
    }
}

fn enqueue(st: &mut State, id: TaskId) {
    let rec = st.records.get(&id).expect("record for ready task");
    st.scheduler.enqueue(rec);
    st.enqueue_time.insert(id, Instant::now());
    crate::obs_gauge!("sched.queue_depth").set(st.scheduler.ready_count() as i64);
}

/// One scheduling pass (Fig 22 timing): place ready tasks, dispatch jobs.
fn run_schedule(st: &mut State) {
    let t0 = Instant::now();
    let assignments = st.scheduler.schedule(&st.analyser.data);
    let pass = t0.elapsed();
    if assignments.is_empty() {
        return;
    }
    // Attribute the pass cost evenly — a pass usually places one task
    // (submit-triggered) so this matches per-task scheduling time.
    let per_task = pass / assignments.len() as u32;

    // Producer workers become stream data locations (§4.5); collected
    // across the pass and applied in one batched scheduler update.
    let mut stream_updates: Vec<(StreamId, WorkerId)> = Vec::new();
    for a in &assignments {
        st.metrics.on_schedule(a.task, per_task);
        if let Some(t) = st.enqueue_time.remove(&a.task) {
            st.metrics.on_queue(a.task, t.elapsed());
        }
        let rec = st.records.get(&a.task).expect("record for scheduled task").clone();
        if !rec.produces.is_empty() {
            stream_updates.extend(rec.produces.iter().map(|&s| (s, a.worker)));
        }
        // Collect inputs that are not local to the chosen worker.
        let mut inputs = Vec::new();
        for key in rec.input_keys() {
            if !st.analyser.data.locations(key).contains(&a.worker) {
                match st.analyser.data.value(key) {
                    Some(v) => {
                        inputs.push((key, v));
                        st.analyser.data.add_location(key, a.worker);
                    }
                    None => warn!("task {} input {key:?} has no value yet", a.task),
                }
            }
        }
        st.graph.set_running(a.task);
        let attempt = {
            let r = st.records.get(&a.task).unwrap();
            st.cfg.max_retries + 2 - r.attempts_left
        };
        debug!("dispatch task {} ({}) -> worker {}", a.task, rec.name, a.worker);
        st.workers[a.worker].submit_job(Job { record: rec, inputs, attempt });
    }
    if !stream_updates.is_empty() {
        st.scheduler.note_producer_locations(stream_updates);
    }
    crate::obs_counter!("sched.dispatched").add(assignments.len() as u64);
    crate::obs_gauge!("sched.queue_depth").set(st.scheduler.ready_count() as i64);
}

fn on_finished(
    st: &mut State,
    task: TaskId,
    worker: WorkerId,
    outputs: Vec<(Key, Arc<Vec<u8>>)>,
    error: Option<String>,
) {
    // Ignore ghosts from killed workers (their tasks were resubmitted).
    if st.dead_workers.get(worker).copied().unwrap_or(false) {
        debug!("ignoring completion of task {task} from dead worker {worker}");
        return;
    }
    // Ignore duplicate completions (e.g. task finished while being failed).
    if !st.records.contains_key(&task) {
        return;
    }

    st.scheduler.release(task);

    match error {
        None => {
            // Record total time BEFORE waking any waiter: observers must see
            // complete metrics the moment wait_on returns.
            if let Some(t) = st.submit_time.remove(&task) {
                st.metrics.on_total(task, t.elapsed());
            }
            // Store outputs: value lives at the worker and (by Arc) master.
            for (key, value) in outputs {
                st.analyser.data.put_value(key, Arc::clone(&value), worker);
                st.analyser.data.add_location(key, MASTER);
                if let Some(waiters) = st.data_waiters.remove(&key) {
                    for w in waiters {
                        let _ = w.send(Ok(Arc::clone(&value)));
                    }
                }
            }
            if let Some(waiters) = st.file_waiters.remove(&task) {
                for w in waiters {
                    let _ = w.send(Ok(()));
                }
            }
            st.completed += 1;
            st.active -= 1;
            let released = st.graph.complete(task);
            st.analyser.retire_task(task);
            st.records.remove(&task);
            for r in released {
                enqueue(st, r);
            }
            run_schedule(st);
            check_barrier(st);
        }
        Some(err) => {
            let rec = st.records.get_mut(&task).expect("record for failed task");
            rec.attempts_left = rec.attempts_left.saturating_sub(1);
            if rec.attempts_left > 0 {
                warn!(
                    "task {task} ({}) failed ({err}); resubmitting ({} attempts left)",
                    rec.name, rec.attempts_left
                );
                st.graph.set_ready(task);
                enqueue(st, task);
                run_schedule(st);
            } else {
                warn!("task {task} ({}) failed permanently: {err}", rec.name);
                fail_task(st, task, &err);
                run_schedule(st);
                check_barrier(st);
            }
        }
    }
}

/// Permanently fail `task` and cascade to dependents.
fn fail_task(st: &mut State, task: TaskId, err: &str) {
    let doomed = st.graph.fail(task);
    st.failed += 1;
    st.active -= 1;
    notify_task_failure(st, task, err);
    for d in doomed {
        st.failed += 1;
        st.active -= 1;
        notify_task_failure(st, d, &format!("dependency failed: {err}"));
        st.analyser.retire_task(d);
        st.records.remove(&d);
    }
    st.analyser.retire_task(task);
    st.records.remove(&task);
}

/// Wake every waiter that can never be satisfied now.
fn notify_task_failure(st: &mut State, task: TaskId, err: &str) {
    if let Some(rec) = st.records.get(&task) {
        for key in rec.output_keys() {
            if let Some(waiters) = st.data_waiters.remove(&key) {
                for w in waiters {
                    let _ = w.send(Err(err.to_string()));
                }
            }
        }
    }
    if let Some(waiters) = st.file_waiters.remove(&task) {
        for w in waiters {
            let _ = w.send(Err(err.to_string()));
        }
    }
    st.submit_time.remove(&task);
    st.enqueue_time.remove(&task);
}

fn on_wait_data(st: &mut State, data: DataId, reply: mpsc::Sender<Result<Arc<Vec<u8>>, String>>) {
    let key = (data, st.analyser.data.latest(data));
    if let Some(v) = st.analyser.data.value(key) {
        let _ = reply.send(Ok(v));
        return;
    }
    // Is the writer permanently failed already?
    if let Some(writer) = st.analyser.data.writer(key) {
        if matches!(st.graph.state(writer), Some(super::graph::TaskState::Failed)) {
            let _ = reply.send(Err(format!("producer task {writer} failed")));
            return;
        }
    } else {
        let _ = reply.send(Err(format!("datum {data} has no value and no producer")));
        return;
    }
    st.data_waiters.entry(key).or_default().push(reply);
}

fn on_wait_file(st: &mut State, path: &str, reply: mpsc::Sender<Result<(), String>>) {
    match st.analyser.data.file_writer(path) {
        None => {
            let _ = reply.send(Ok(())); // nobody writes it — nothing to wait for
        }
        Some(writer) => match st.graph.state(writer) {
            Some(super::graph::TaskState::Completed) | None => {
                let _ = reply.send(Ok(()));
            }
            Some(super::graph::TaskState::Failed) => {
                let _ = reply.send(Err(format!("writer task {writer} failed")));
            }
            _ => st.file_waiters.entry(writer).or_default().push(reply),
        },
    }
}

fn on_kill_worker(st: &mut State, worker: WorkerId) {
    if worker >= st.workers.len() {
        return;
    }
    warn!("worker {worker} marked down");
    st.dead_workers[worker] = true;
    st.workers[worker].mark_killed();
    st.analyser.data.drop_worker(worker);
    let lost = st.scheduler.worker_down(worker);
    for task in lost {
        // Worker death does not consume a retry (paper: re-submission).
        st.graph.set_ready(task);
        enqueue(st, task);
    }
    run_schedule(st);
}

fn check_barrier(st: &mut State) {
    if st.active == 0 {
        for w in st.barrier_waiters.drain(..) {
            let _ = w.send(());
        }
    }
}
