//! Process-global observability registry: the PR 8 metrics plane.
//!
//! Atomic counters, gauges and fixed-bucket histograms registered under
//! stable hierarchical names (`broker.partition.append_records`,
//! `mux.inflight`, `replicate.lag_records{…}`, `fault.decisions{…}`).
//! Zero dependencies, lock-light: registration takes a registry mutex
//! once per site (hot paths cache the `&'static` handle via the
//! [`obs_counter!`]/[`obs_gauge!`]/[`obs_hist!`] macros), after which
//! every update is a relaxed atomic op. A process-wide enable flag
//! (default on) turns every record site into a no-op branch so the
//! instrumentation overhead is measurable — `benches/bench_obs.rs`
//! gates the enabled-vs-disabled publish-throughput delta.
//!
//! One [`snapshot`] covers every plane — tasks, streams, wire, storage,
//! replication, faults — and renders three ways: Prometheus text
//! exposition ([`Snapshot::render_prometheus`], served by
//! [`serve_http`]), a human table ([`Snapshot::render_text`], the
//! `hybridws stats` CLI), and the `Metrics` wire frame (`Snapshot` is
//! itself `Wire`, so any `BrokerClient` can scrape a remote broker).
//!
//! Naming schema: dot-separated hierarchy `plane.component.metric`;
//! dynamic-label series append `{label}` (e.g. `fault.decisions{mux.write}`,
//! `replicate.lag_records{addr/topic/p}`). Cardinality is bounded by
//! construction: labels are fault seams, follower addresses and topic
//! partitions, never per-record values.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

// ---- metric kinds ------------------------------------------------------

/// Monotonically increasing event count.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed level (window depth, queue length, lag).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        if enabled() {
            self.0.store(v, Ordering::Relaxed);
        }
    }

    pub fn add(&self, n: i64) {
        if enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn sub(&self, n: i64) {
        self.add(-n);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed power-of-two bucket count: bucket `i` holds observations with
/// value ≤ `2^i` µs, the last bucket is the overflow catch-all
/// (`2^31` µs ≈ 36 min — far beyond any latency this system produces).
pub const HIST_BUCKETS: usize = 32;

/// Fixed-bucket latency histogram (microsecond observations).
///
/// Power-of-two bounds mean bucketing is a leading-zeros computation and
/// quantile estimation is a cumulative walk with log-linear interpolation
/// inside the target bucket — no allocation, no sorting, safe to observe
/// from the publish hot path.
pub struct Histogram {
    count: AtomicU64,
    sum_us: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Index of the bucket that holds a `v` µs observation.
fn bucket_of(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        (64 - (v - 1).leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

/// Inclusive upper bound (µs) of bucket `i`.
fn bucket_bound(i: usize) -> u64 {
    1u64 << i
}

impl Histogram {
    /// Record one latency observation in microseconds.
    pub fn observe_us(&self, us: u64) {
        if !enabled() {
            return;
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a [`Duration`] observation.
    pub fn observe(&self, d: Duration) {
        self.observe_us(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Record a latency given the publish stamp and "now" in epoch ms
    /// (the cross-process end-to-end tracing path; clock skew between
    /// machines can make the difference negative — clamp to 0).
    pub fn observe_ms_span(&self, from_ms: u64, now_ms: u64) {
        self.observe_us(now_ms.saturating_sub(from_ms) * 1000);
    }

    fn snap(&self, name: &str) -> HistSnapshot {
        HistSnapshot {
            name: name.to_string(),
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }
}

// ---- registry ----------------------------------------------------------

/// Global enable flag: when off, every record site is a relaxed load + a
/// not-taken branch (the "uninstrumented" arm of `bench_obs`).
static ENABLED: AtomicBool = AtomicBool::new(true);

/// True when the registry is recording (the default).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on/off process-wide (benchmarks and tests).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

#[derive(Default)]
struct Registry {
    counters: HashMap<String, &'static Counter>,
    gauges: HashMap<String, &'static Gauge>,
    hists: HashMap<String, &'static Histogram>,
}

fn registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Registry::default()))
}

/// Get-or-register the counter `name`. The handle is `'static` (metrics
/// live for the process) — hot paths cache it via [`obs_counter!`].
pub fn counter(name: &str) -> &'static Counter {
    let mut reg = registry().lock().unwrap();
    if let Some(c) = reg.counters.get(name) {
        return c;
    }
    let c: &'static Counter = Box::leak(Box::default());
    reg.counters.insert(name.to_string(), c);
    c
}

/// Get-or-register the gauge `name`.
pub fn gauge(name: &str) -> &'static Gauge {
    let mut reg = registry().lock().unwrap();
    if let Some(g) = reg.gauges.get(name) {
        return g;
    }
    let g: &'static Gauge = Box::leak(Box::default());
    reg.gauges.insert(name.to_string(), g);
    g
}

/// Get-or-register the histogram `name`.
pub fn histogram(name: &str) -> &'static Histogram {
    let mut reg = registry().lock().unwrap();
    if let Some(h) = reg.hists.get(name) {
        return h;
    }
    let h: &'static Histogram = Box::leak(Box::default());
    reg.hists.insert(name.to_string(), h);
    h
}

/// Cache a `&'static Counter` in a per-site `OnceLock` so the steady-state
/// hot path never touches the registry mutex.
#[macro_export]
macro_rules! obs_counter {
    ($name:expr) => {{
        static SITE: ::std::sync::OnceLock<&'static $crate::util::obs::Counter> =
            ::std::sync::OnceLock::new();
        *SITE.get_or_init(|| $crate::util::obs::counter($name))
    }};
}

/// Per-site cached gauge handle (see [`obs_counter!`]).
#[macro_export]
macro_rules! obs_gauge {
    ($name:expr) => {{
        static SITE: ::std::sync::OnceLock<&'static $crate::util::obs::Gauge> =
            ::std::sync::OnceLock::new();
        *SITE.get_or_init(|| $crate::util::obs::gauge($name))
    }};
}

/// Per-site cached histogram handle (see [`obs_counter!`]).
#[macro_export]
macro_rules! obs_hist {
    ($name:expr) => {{
        static SITE: ::std::sync::OnceLock<&'static $crate::util::obs::Histogram> =
            ::std::sync::OnceLock::new();
        *SITE.get_or_init(|| $crate::util::obs::histogram($name))
    }};
}

// ---- snapshot ----------------------------------------------------------

/// Point-in-time copy of one histogram (wire-encodable).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistSnapshot {
    pub name: String,
    pub count: u64,
    pub sum_us: u64,
    /// Per-bucket observation counts; bucket `i` bound is `2^i` µs.
    pub buckets: Vec<u64>,
}

crate::wire_struct!(HistSnapshot { name: String, count: u64, sum_us: u64, buckets: Vec<u64> });

impl HistSnapshot {
    /// Estimated quantile in µs (`q` in `[0, 1]`): cumulative bucket walk
    /// with log-linear interpolation inside the target bucket. Returns 0
    /// for an empty histogram.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            if seen + n >= rank {
                let lower = if i == 0 { 0 } else { bucket_bound(i - 1) };
                let upper = bucket_bound(i);
                let frac = (rank - seen) as f64 / n as f64;
                return lower + ((upper - lower) as f64 * frac) as u64;
            }
            seen += n;
        }
        bucket_bound(self.buckets.len().saturating_sub(1))
    }

    pub fn p50_us(&self) -> u64 {
        self.quantile_us(0.50)
    }

    pub fn p99_us(&self) -> u64 {
        self.quantile_us(0.99)
    }

    pub fn p999_us(&self) -> u64 {
        self.quantile_us(0.999)
    }

    /// Mean observation in µs (0 when empty).
    pub fn mean_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum_us / self.count
        }
    }
}

/// Point-in-time copy of the whole registry, sorted by metric name.
/// `Wire`-encodable: this is the payload of the `Metrics` response frame.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub hists: Vec<HistSnapshot>,
}

crate::wire_struct!(Snapshot {
    counters: Vec<(String, u64)>,
    gauges: Vec<(String, i64)>,
    hists: Vec<HistSnapshot>,
});

/// Snapshot every registered metric (sorted by name for stable output).
pub fn snapshot() -> Snapshot {
    let reg = registry().lock().unwrap();
    let mut counters: Vec<(String, u64)> =
        reg.counters.iter().map(|(k, c)| (k.clone(), c.get())).collect();
    let mut gauges: Vec<(String, i64)> =
        reg.gauges.iter().map(|(k, g)| (k.clone(), g.get())).collect();
    let mut hists: Vec<HistSnapshot> =
        reg.hists.iter().map(|(k, h)| h.snap(k)).collect();
    counters.sort_by(|a, b| a.0.cmp(&b.0));
    gauges.sort_by(|a, b| a.0.cmp(&b.0));
    hists.sort_by(|a, b| a.name.cmp(&b.name));
    Snapshot { counters, gauges, hists }
}

/// `a.b.c{label}` → (`a_b_c`, `Some(label)`): the Prometheus mangling.
fn prom_name(name: &str) -> (String, Option<&str>) {
    let (base, label) = match name.split_once('{') {
        Some((b, rest)) => (b, rest.strip_suffix('}')),
        None => (name, None),
    };
    (base.replace(['.', '-'], "_"), label)
}

/// Escape a label value per the exposition-format rules: `\`, `"` and
/// newline would otherwise break the line/quote structure of the scrape.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn prom_line(
    out: &mut String,
    name: &str,
    suffix: &str,
    extra: &str,
    value: impl std::fmt::Display,
) {
    let (base, label) = prom_name(name);
    out.push_str(&base);
    out.push_str(suffix);
    match (label.map(|l| escape_label(l)), extra.is_empty()) {
        (Some(l), true) => out.push_str(&format!("{{site=\"{l}\"}}")),
        (Some(l), false) => out.push_str(&format!("{{site=\"{l}\",{extra}}}")),
        (None, true) => {}
        (None, false) => out.push_str(&format!("{{{extra}}}")),
    }
    out.push(' ');
    out.push_str(&value.to_string());
    out.push('\n');
}

impl Snapshot {
    /// Counter value by exact registry name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    /// Gauge value by exact registry name.
    pub fn gauge(&self, name: &str) -> Option<i64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    /// Histogram snapshot by exact registry name.
    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|h| h.name == name)
    }

    /// Sum of every counter whose name starts with `prefix` (e.g.
    /// `fault.decisions{` sums the per-site decision series).
    pub fn counter_sum(&self, prefix: &str) -> u64 {
        self.counters.iter().filter(|(n, _)| n.starts_with(prefix)).map(|&(_, v)| v).sum()
    }

    /// Fold another process's snapshot into this one — the cluster-wide
    /// aggregation behind `hybridws stats`. Counters and gauges sum (a
    /// summed gauge is a fleet total: segments across brokers, in-flight
    /// across connections); histograms merge bucket-wise, so quantiles
    /// stay estimable over the union of observations.
    pub fn merge(&mut self, other: &Snapshot) {
        for (name, v) in &other.counters {
            match self.counters.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => *mine += *v,
                None => self.counters.push((name.clone(), *v)),
            }
        }
        for (name, v) in &other.gauges {
            match self.gauges.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => *mine += *v,
                None => self.gauges.push((name.clone(), *v)),
            }
        }
        for h in &other.hists {
            match self.hists.iter_mut().find(|mine| mine.name == h.name) {
                Some(mine) => {
                    mine.count += h.count;
                    mine.sum_us += h.sum_us;
                    if mine.buckets.len() < h.buckets.len() {
                        mine.buckets.resize(h.buckets.len(), 0);
                    }
                    for (m, v) in mine.buckets.iter_mut().zip(&h.buckets) {
                        *m += *v;
                    }
                }
                None => self.hists.push(h.clone()),
            }
        }
        self.counters.sort_by(|a, b| a.0.cmp(&b.0));
        self.gauges.sort_by(|a, b| a.0.cmp(&b.0));
        self.hists.sort_by(|a, b| a.name.cmp(&b.name));
    }

    /// Prometheus text exposition (format 0.0.4). Counters/gauges map to
    /// their types; histograms render as summaries (`{quantile="…"}` +
    /// `_sum`/`_count`), with quantiles estimated from the fixed buckets.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        // Each metric family gets its TYPE line exactly once. A set, not
        // a last-emitted comparison: the registry sorts by the *raw* name,
        // and `'.' < '{'`, so `a.b.c` sorts between `a.b` and `a.b{x}` —
        // same-family series are NOT guaranteed adjacent.
        let mut emitted = std::collections::HashSet::new();
        let mut type_line = |out: &mut String, base: &str, kind: &str| {
            if emitted.insert(base.to_string()) {
                out.push_str(&format!("# TYPE {base} {kind}\n"));
            }
        };
        for (name, v) in &self.counters {
            let (base, _) = prom_name(name);
            type_line(&mut out, &base, "counter");
            prom_line(&mut out, name, "_total", "", v);
        }
        for (name, v) in &self.gauges {
            let (base, _) = prom_name(name);
            type_line(&mut out, &base, "gauge");
            prom_line(&mut out, name, "", "", v);
        }
        for h in &self.hists {
            let (base, _) = prom_name(&h.name);
            type_line(&mut out, &base, "summary");
            prom_line(&mut out, &h.name, "", "quantile=\"0.5\"", h.p50_us());
            prom_line(&mut out, &h.name, "", "quantile=\"0.99\"", h.p99_us());
            prom_line(&mut out, &h.name, "", "quantile=\"0.999\"", h.p999_us());
            prom_line(&mut out, &h.name, "_sum", "", h.sum_us);
            prom_line(&mut out, &h.name, "_count", "", h.count);
        }
        out
    }

    /// Delta rendering for `hybridws stats --watch`: counters and
    /// histogram observation counts as per-second rates against `prev`
    /// (a snapshot taken `secs` ago), gauges absolute — a gauge is a
    /// level, not an accumulation, so a rate would be noise. Quantiles
    /// stay lifetime-cumulative (the fixed buckets cannot be
    /// differenced without losing the interpolation).
    pub fn render_text_delta(&self, prev: &Snapshot, secs: f64) -> String {
        let secs = if secs > 0.0 { secs } else { 1.0 };
        let mut out = String::new();
        if !self.counters.is_empty() {
            out.push_str("counters (/s):\n");
            for (name, v) in &self.counters {
                let rate = v.saturating_sub(prev.counter(name).unwrap_or(0)) as f64 / secs;
                out.push_str(&format!("  {name:<48} {rate:.1}\n"));
            }
        }
        if !self.gauges.is_empty() {
            out.push_str("gauges (absolute):\n");
            for (name, v) in &self.gauges {
                out.push_str(&format!("  {name:<48} {v}\n"));
            }
        }
        if !self.hists.is_empty() {
            out.push_str("histograms (µs, n/s):\n");
            for h in &self.hists {
                let before = prev.hist(&h.name).map(|p| p.count).unwrap_or(0);
                let rate = h.count.saturating_sub(before) as f64 / secs;
                out.push_str(&format!(
                    "  {:<48} n={rate:.1} mean={} p50={} p99={} p999={}\n",
                    h.name,
                    h.mean_us(),
                    h.p50_us(),
                    h.p99_us(),
                    h.p999_us(),
                ));
            }
        }
        if out.is_empty() {
            out.push_str("(no metrics registered)\n");
        }
        out
    }

    /// Human-readable table (the `hybridws stats` CLI rendering).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        if !self.counters.is_empty() {
            out.push_str("counters:\n");
            for (name, v) in &self.counters {
                out.push_str(&format!("  {name:<48} {v}\n"));
            }
        }
        if !self.gauges.is_empty() {
            out.push_str("gauges:\n");
            for (name, v) in &self.gauges {
                out.push_str(&format!("  {name:<48} {v}\n"));
            }
        }
        if !self.hists.is_empty() {
            out.push_str("histograms (µs):\n");
            for h in &self.hists {
                out.push_str(&format!(
                    "  {:<48} n={} mean={} p50={} p99={} p999={}\n",
                    h.name,
                    h.count,
                    h.mean_us(),
                    h.p50_us(),
                    h.p99_us(),
                    h.p999_us(),
                ));
            }
        }
        if out.is_empty() {
            out.push_str("(no metrics registered)\n");
        }
        out
    }
}

// ---- Prometheus HTTP exposition ---------------------------------------

/// Who this process is, for the `/healthz` endpoint (e.g. `broker
/// 127.0.0.1:9092 epoch 3`). Empty until [`set_identity`] is called.
static IDENTITY: Mutex<String> = Mutex::new(String::new());

/// Set the identity string `/healthz` reports (idempotent; last write
/// wins — brokers refresh it when their epoch moves).
pub fn set_identity(id: &str) {
    *IDENTITY.lock().unwrap() = id.to_string();
}

/// The identity string `/healthz` reports (empty when unset).
pub fn identity() -> String {
    IDENTITY.lock().unwrap().clone()
}

/// Handle to the `--metrics-addr` HTTP listener; dropping it (or calling
/// [`MetricsHttp::shutdown`]) stops the accept loop.
pub struct MetricsHttp {
    addr: SocketAddr,
    stop: std::sync::Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Serve the registry as Prometheus text exposition on `addr`. One
/// accept-loop thread, one short-lived response per connection. A GET of
/// `/healthz` answers a liveness probe (200 plus the process identity,
/// see [`set_identity`]); every other path returns the full snapshot.
/// Hand-rolled HTTP/1.1: this is a diagnostics endpoint, not a web
/// server.
pub fn serve_http(addr: &str) -> std::io::Result<MetricsHttp> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = std::sync::Arc::new(AtomicBool::new(false));
    let stop2 = std::sync::Arc::clone(&stop);
    let handle = std::thread::Builder::new().name("obs-http".into()).spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(mut sock) = conn else { continue };
            let _ = sock.set_read_timeout(Some(Duration::from_millis(500)));
            let mut head = [0u8; 1024];
            let n = sock.read(&mut head).unwrap_or(0);
            // `GET <path> HTTP/1.1` — only the path matters.
            let req = String::from_utf8_lossy(&head[..n]);
            let path = req.split_whitespace().nth(1).unwrap_or("/");
            let (body, ctype) = if path == "/healthz" || path.starts_with("/healthz?") {
                let id = identity();
                let body = if id.is_empty() { "ok\n".to_string() } else { format!("ok {id}\n") };
                (body, "text/plain; charset=utf-8")
            } else {
                (
                    snapshot().render_prometheus(),
                    "text/plain; version=0.0.4; charset=utf-8",
                )
            };
            let resp = format!(
                "HTTP/1.1 200 OK\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\n\
                 Connection: close\r\n\r\n{body}",
                body.len(),
            );
            let _ = sock.write_all(resp.as_bytes());
        }
    })?;
    Ok(MetricsHttp { addr: local, stop, handle: Some(handle) })
}

impl MetricsHttp {
    /// The bound address (port resolved when `addr` asked for `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the listener thread.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept so it observes the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsHttp {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::wire::Wire;

    #[test]
    fn counter_gauge_roundtrip() {
        let c = counter("test.obs.counter");
        let base = c.get();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), base + 5);
        // Same name → same instance.
        assert_eq!(counter("test.obs.counter").get(), base + 5);

        let g = gauge("test.obs.gauge");
        g.set(7);
        g.add(3);
        g.sub(4);
        assert_eq!(g.get(), 6);
    }

    #[test]
    fn bucket_math_covers_bounds() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(5), 3);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(1025), 11);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        // Every value lands in the bucket whose bound covers it.
        for v in [1u64, 2, 3, 9, 100, 4097, 1 << 20] {
            let i = bucket_of(v);
            assert!(bucket_bound(i) >= v, "bound of bucket {i} must cover {v}");
            if i > 0 {
                assert!(bucket_bound(i - 1) < v, "{v} must not fit the previous bucket");
            }
        }
    }

    #[test]
    fn histogram_quantiles_are_sane() {
        let h = histogram("test.obs.hist");
        // 90 fast observations, 10 slow ones.
        for _ in 0..90 {
            h.observe_us(100);
        }
        for _ in 0..10 {
            h.observe_us(60_000);
        }
        let snap = h.snap("test.obs.hist");
        assert_eq!(snap.count, 100);
        assert_eq!(snap.sum_us, 90 * 100 + 10 * 60_000);
        let p50 = snap.p50_us();
        assert!((64..=128).contains(&p50), "p50 {p50} must sit in the 100µs bucket");
        let p99 = snap.p99_us();
        assert!(p99 >= 32_768, "p99 {p99} must reflect the slow tail");
        assert!(snap.p999_us() >= p99);
        assert_eq!(snap.mean_us(), (90 * 100 + 10 * 60_000) / 100);
        // Empty histogram: all zeros.
        let empty = HistSnapshot::default();
        assert_eq!(empty.quantile_us(0.99), 0);
        assert_eq!(empty.mean_us(), 0);
    }

    #[test]
    fn snapshot_wire_roundtrip() {
        counter("test.obs.wire.c").add(3);
        gauge("test.obs.wire.g").set(-9);
        histogram("test.obs.wire.h").observe_us(1234);
        let snap = snapshot();
        let back = Snapshot::decode_exact(&snap.encode_vec()).unwrap();
        assert_eq!(back, snap);
        assert!(back.counter("test.obs.wire.c").unwrap() >= 3);
        assert_eq!(back.gauge("test.obs.wire.g"), Some(-9));
        assert!(back.hist("test.obs.wire.h").unwrap().count >= 1);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let c = counter("test.obs.disabled");
        let h = histogram("test.obs.disabled.h");
        let base = c.get();
        set_enabled(false);
        c.add(100);
        h.observe_us(5);
        set_enabled(true);
        assert_eq!(c.get(), base, "disabled counter must not move");
        c.inc();
        assert_eq!(c.get(), base + 1);
    }

    #[test]
    fn prometheus_rendering_mangles_names_and_labels() {
        counter("test.prom.plain").inc();
        counter("test.prom.labeled{seg.append}").add(2);
        gauge("test.prom.depth").set(4);
        histogram("test.prom.lat_us").observe_us(10);
        let text = snapshot().render_prometheus();
        assert!(text.contains("# TYPE test_prom_plain counter"));
        assert!(text.contains("test_prom_plain_total "));
        assert!(text.contains("test_prom_labeled_total{site=\"seg.append\"} 2"));
        assert!(text.contains("# TYPE test_prom_depth gauge"));
        assert!(text.contains("test_prom_depth 4"));
        assert!(text.contains("test_prom_lat_us{quantile=\"0.99\"}"));
        assert!(text.contains("test_prom_lat_us_count "));
        // Exposition lines are `name[{labels}] value` — no stray braces.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad exposition line: {line}");
        }
    }

    #[test]
    fn http_exposition_serves_snapshot() {
        counter("test.http.hits").inc();
        let srv = serve_http("127.0.0.1:0").unwrap();
        let mut sock = TcpStream::connect(srv.local_addr()).unwrap();
        sock.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        sock.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "got: {resp}");
        assert!(resp.contains("text/plain; version=0.0.4"));
        assert!(resp.contains("test_http_hits_total"));
        drop(srv); // shutdown must not hang
    }

    #[test]
    fn merge_sums_series_and_buckets() {
        let mut a = Snapshot {
            counters: vec![("c.one".into(), 3), ("c.two".into(), 1)],
            gauges: vec![("g.depth".into(), 2)],
            hists: vec![HistSnapshot {
                name: "h.lat".into(),
                count: 2,
                sum_us: 30,
                buckets: vec![1, 1],
            }],
        };
        let b = Snapshot {
            counters: vec![("c.one".into(), 4), ("c.three".into(), 9)],
            gauges: vec![("g.depth".into(), 5), ("g.other".into(), -1)],
            hists: vec![
                HistSnapshot {
                    name: "h.lat".into(),
                    count: 1,
                    sum_us: 100,
                    buckets: vec![0, 0, 1],
                },
                HistSnapshot { name: "h.new".into(), count: 1, sum_us: 7, buckets: vec![1] },
            ],
        };
        a.merge(&b);
        assert_eq!(a.counter("c.one"), Some(7));
        assert_eq!(a.counter("c.two"), Some(1));
        assert_eq!(a.counter("c.three"), Some(9));
        assert_eq!(a.gauge("g.depth"), Some(7));
        assert_eq!(a.gauge("g.other"), Some(-1));
        let h = a.hist("h.lat").unwrap();
        assert_eq!((h.count, h.sum_us), (3, 130));
        assert_eq!(h.buckets, vec![1, 1, 1]);
        assert_eq!(a.hist("h.new").unwrap().count, 1);
        // Merged output stays sorted (render paths rely on it).
        let names: Vec<&str> = a.counters.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["c.one", "c.three", "c.two"]);
    }

    #[test]
    fn snapshot_helpers_find_series() {
        counter("test.sum.a{x}").add(1);
        counter("test.sum.a{y}").add(2);
        let snap = snapshot();
        assert!(snap.counter_sum("test.sum.a{") >= 3);
        assert_eq!(snap.counter("test.sum.missing"), None);
        assert_eq!(snap.gauge("test.sum.missing"), None);
        assert!(snap.hist("test.sum.missing").is_none());
    }

    #[test]
    fn prometheus_label_values_are_escaped() {
        // A label value holding `\`, `"` and a newline must not break the
        // quote/line structure of the scrape.
        let snap = Snapshot {
            counters: vec![("t.esc{a\\b\"c\nd}".into(), 5)],
            gauges: vec![],
            hists: vec![],
        };
        let text = snap.render_prometheus();
        assert!(
            text.contains("t_esc_total{site=\"a\\\\b\\\"c\\nd\"} 5"),
            "unescaped label value in:\n{text}"
        );
        // TYPE line + one series line — the raw newline must not survive.
        assert_eq!(text.lines().count(), 2, "text:\n{text}");
    }

    #[test]
    fn prometheus_type_lines_emit_once_per_family() {
        // Registry order sorts by *raw* name and `'.' < '{'`, so `t.b.c`
        // sits between `t.b` and `t.b{x}`: the two `t_b` series are not
        // adjacent. The family must still get exactly one TYPE line.
        let snap = Snapshot {
            counters: vec![("t.b".into(), 1), ("t.b.c".into(), 2), ("t.b{x}".into(), 3)],
            gauges: vec![],
            hists: vec![],
        };
        let text = snap.render_prometheus();
        let type_lines: Vec<&str> =
            text.lines().filter(|l| l.starts_with("# TYPE t_b ")).collect();
        assert_eq!(type_lines, vec!["# TYPE t_b counter"], "text:\n{text}");
        assert_eq!(
            text.lines().filter(|l| *l == "# TYPE t_b_c counter").count(),
            1,
            "text:\n{text}"
        );
    }

    #[test]
    fn healthz_answers_liveness_with_identity() {
        set_identity("broker 127.0.0.1:9092 epoch 3");
        let srv = serve_http("127.0.0.1:0").unwrap();
        let mut sock = TcpStream::connect(srv.local_addr()).unwrap();
        sock.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        sock.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "got: {resp}");
        assert!(resp.contains("ok broker 127.0.0.1:9092 epoch 3"), "got: {resp}");
        assert!(!resp.contains("# TYPE"), "healthz must not dump the scrape: {resp}");
    }

    #[test]
    fn delta_rendering_rates_counters_but_not_gauges() {
        let hist = |count: u64, sum: u64| HistSnapshot {
            name: "h.lat".into(),
            count,
            sum_us: sum,
            buckets: vec![count],
        };
        let prev = Snapshot {
            counters: vec![("c.rate".into(), 10)],
            gauges: vec![("g.level".into(), 5)],
            hists: vec![hist(10, 100)],
        };
        let cur = Snapshot {
            counters: vec![("c.rate".into(), 30)],
            gauges: vec![("g.level".into(), 7)],
            hists: vec![hist(14, 140)],
        };
        let text = cur.render_text_delta(&prev, 2.0);
        // (30 - 10) / 2s = 10.0/s; the gauge stays the absolute level.
        assert!(text.contains("c.rate") && text.contains("10.0"), "text:\n{text}");
        assert!(text.contains("g.level") && text.contains(" 7\n"), "text:\n{text}");
        assert!(text.contains("n=2.0"), "hist count must rate: \n{text}");
        // A series absent from `prev` rates from zero instead of panicking.
        let fresh =
            Snapshot { counters: vec![("c.new".into(), 4)], ..Default::default() };
        let t2 = fresh.render_text_delta(&Snapshot::default(), 2.0);
        assert!(t2.contains("2.0"), "text:\n{t2}");
    }
}
