//! Minimal JSON parser (objects, arrays, strings, numbers, bools, null) —
//! just enough to read `artifacts/manifest.json` (no serde offline).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

/// Parse error with byte position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { at: self.pos, msg: msg.to_string() })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.s.len() && self.s[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a value"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.s[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected {word}"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.s[start..self.pos])
            .ok()
            .and_then(|t| t.parse().ok())
            .map(Json::Num)
            .ok_or(JsonError { at: start, msg: "bad number".into() })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let ch = self.unicode_escape()?;
                            out.push(ch);
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = &self.s[self.pos..];
                    let len = utf8_len(rest[0]);
                    if rest.len() < len {
                        return self.err("truncated UTF-8");
                    }
                    match std::str::from_utf8(&rest[..len]) {
                        Ok(ch) => out.push_str(ch),
                        Err(_) => return self.err("invalid UTF-8"),
                    }
                    self.pos += len;
                }
            }
        }
    }

    /// Four hex digits starting at `at`.
    fn hex4(&self, at: usize) -> Result<u32, JsonError> {
        self.s
            .get(at..at + 4)
            .and_then(|h| std::str::from_utf8(h).ok())
            .and_then(|h| u32::from_str_radix(h, 16).ok())
            .ok_or(JsonError { at: self.pos, msg: "bad \\u escape".into() })
    }

    /// Decode a `\uXXXX` escape (cursor on the `u`): any BMP code point
    /// directly, supplementary-plane characters as a UTF-16 surrogate
    /// **pair** (`\uD83D\uDE00` → 😀). Lone or mismatched surrogates are
    /// errors, not U+FFFD — a manifest with a torn escape should fail
    /// loudly. Leaves the cursor on the final hex digit (the caller's
    /// shared advance steps past it).
    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        let hi = self.hex4(self.pos + 1)?;
        self.pos += 4;
        let cp = match hi {
            0xD800..=0xDBFF => {
                if self.s.get(self.pos + 1).copied() != Some(b'\\')
                    || self.s.get(self.pos + 2).copied() != Some(b'u')
                {
                    return self.err("unpaired high surrogate");
                }
                let lo = self.hex4(self.pos + 3)?;
                if !(0xDC00..=0xDFFF).contains(&lo) {
                    return self.err("high surrogate not followed by a low surrogate");
                }
                self.pos += 6;
                0x1_0000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
            }
            0xDC00..=0xDFFF => return self.err("unpaired low surrogate"),
            bmp => bmp,
        };
        char::from_u32(cp).ok_or(JsonError { at: self.pos, msg: "bad \\u escape".into() })
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected , or }"),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { s: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.s.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
            "grid_h": 64,
            "models": [
                {"name": "heat_step", "inputs": [{"shape": [64, 64], "dtype": "float32"}],
                 "output": {"shape": [64, 64], "dtype": "float32"}, "file": "heat_step.hlo.txt"}
            ]
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("grid_h").unwrap().as_usize(), Some(64));
        let models = v.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models[0].get("name").unwrap().as_str(), Some("heat_step"));
        let shape = models[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 2);
        assert_eq!(shape[0].as_usize(), Some(64));
    }

    #[test]
    fn scalar_values() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn errors_report_position() {
        assert!(parse("[1, 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn unicode_escapes_decode_bmp_code_points() {
        // "é" both as raw UTF-8 and as \u00E9 must parse identically.
        assert_eq!(parse("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
        assert_eq!(parse("\"\\u00E9\"").unwrap(), parse("\"é\"").unwrap());
        // Higher BMP (snowman) and escapes embedded in surrounding text.
        assert_eq!(parse("\"\\u2603\"").unwrap(), Json::Str("☃".into()));
        assert_eq!(parse("\"a\\u00e9b\"").unwrap(), Json::Str("aéb".into()));
    }

    #[test]
    fn unicode_escapes_decode_surrogate_pairs() {
        // "😀" is U+1F600 — \uD83D\uDE00 as a UTF-16 surrogate pair.
        assert_eq!(parse("\"\\ud83d\\ude00\"").unwrap(), Json::Str("😀".into()));
        assert_eq!(parse("\"\\uD83D\\uDE00\"").unwrap(), parse("\"😀\"").unwrap());
        // A pair in context, followed by more escaped text.
        assert_eq!(parse("\"x\\uD83D\\uDE00\\u0021\"").unwrap(), Json::Str("x😀!".into()));
    }

    #[test]
    fn lone_surrogates_are_rejected() {
        // Unpaired high surrogate (end of string, or followed by non-escape).
        assert!(parse("\"\\ud83d\"").is_err());
        assert!(parse("\"\\ud83dx\"").is_err());
        // High surrogate followed by a non-surrogate escape.
        assert!(parse("\"\\ud83d\\u0041\"").is_err());
        // Unpaired low surrogate.
        assert!(parse("\"\\ude00\"").is_err());
        // Truncated hex.
        assert!(parse("\"\\u00\"").is_err());
    }
}
