//! Fixed-size thread pool with graceful shutdown (std-only; our `tokio`).
//!
//! Used by the broker/DistroStream TCP servers (connection handlers) and by
//! worker executors (one pool per worker, size = core slots — a pool slot
//! *is* a core in the paper's resource model).

use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: mpsc::Sender<Message>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
    /// Jobs queued or running, paired with the idle `Condvar` that
    /// [`ThreadPool::wait_idle`] parks on (no sleep-spin).
    in_flight: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// Spawn `size` threads named `{name}-{i}`.
    pub fn new(name: &str, size: usize) -> Self {
        assert!(size > 0, "pool needs at least one thread");
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            let in_flight = Arc::clone(&in_flight);
            let handle = std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Message::Run(job)) => {
                            job();
                            let (count, idle) = &*in_flight;
                            let mut n = count.lock().unwrap();
                            *n -= 1;
                            if *n == 0 {
                                idle.notify_all();
                            }
                        }
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
                .expect("spawn pool thread");
            handles.push(handle);
        }
        Self { tx, handles, size, in_flight }
    }

    /// Number of threads (== core slots for workers).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Jobs queued or running.
    pub fn in_flight(&self) -> usize {
        *self.in_flight.0.lock().unwrap()
    }

    /// Enqueue a job. Panics if the pool is shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        *self.in_flight.0.lock().unwrap() += 1;
        self.tx.send(Message::Run(Box::new(job))).expect("pool shut down");
    }

    /// Block until all submitted jobs completed — parked on the idle
    /// `Condvar`, woken by the worker that finishes the last job.
    pub fn wait_idle(&self) {
        let (count, idle) = &*self.in_flight;
        let mut n = count.lock().unwrap();
        while *n > 0 {
            n = idle.wait(n).unwrap();
        }
    }

    /// Stop accepting work and join all threads (runs queued jobs first).
    pub fn shutdown(mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Message::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Message::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new("t", 4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        pool.shutdown();
    }

    #[test]
    fn parallelism_is_bounded_by_size() {
        let pool = ThreadPool::new("t", 2);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let live = Arc::clone(&live);
            let peak = Arc::clone(&peak);
            pool.execute(move || {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(5));
                live.fetch_sub(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert!(peak.load(Ordering::SeqCst) <= 2);
    }

    #[test]
    fn wait_idle_parks_and_wakes_promptly() {
        let pool = ThreadPool::new("t", 2);
        // Idle pool: returns immediately.
        let t0 = std::time::Instant::now();
        pool.wait_idle();
        assert!(t0.elapsed() < std::time::Duration::from_millis(50));
        // Busy pool: wakes when the last job finishes, not on a poll tick.
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(20)));
        pool.wait_idle();
        assert_eq!(pool.in_flight(), 0);
        pool.shutdown();
    }

    #[test]
    fn drop_joins_threads() {
        let pool = ThreadPool::new("t", 2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(1)));
        drop(pool); // must not hang or panic
    }
}
