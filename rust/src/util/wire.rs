//! The [`Wire`] binary-codec trait and length-prefixed framing.
//!
//! `Wire` plays the role serde+bincode would: every protocol message and
//! every object parameter that crosses a process/socket boundary implements
//! it. [`write_frame`]/[`read_frame`] add u32 length prefixes over any
//! `Read`/`Write` (TCP sockets between master/workers, broker, DistroStream
//! server).

use std::collections::BTreeMap;
use std::io::{IoSlice, Read, Write};

use crate::util::bytes::{ByteReader, ByteWriter, DecodeError, SharedBytes, MAX_LEN};

/// Binary encode/decode. Implementations must round-trip:
/// `T::decode(&T::encode_vec(v)) == v`.
pub trait Wire: Sized {
    /// Append this value's encoding to `w`.
    fn encode(&self, w: &mut ByteWriter);
    /// Decode one value from `r`, advancing the cursor.
    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError>;

    /// Encode into a fresh buffer.
    fn encode_vec(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        self.encode(&mut w);
        w.into_vec()
    }

    /// Decode from a complete buffer, requiring full consumption.
    fn decode_exact(buf: &[u8]) -> Result<Self, DecodeError> {
        let mut r = ByteReader::new(buf);
        let v = Self::decode(&mut r)?;
        if !r.is_exhausted() {
            return Err(DecodeError::TooLong { at: r.position(), len: r.remaining() as u64 });
        }
        Ok(v)
    }

    /// Decode from a complete `Arc`-backed frame, requiring full
    /// consumption. [`Blob`] payloads come out as zero-copy sub-views of
    /// `frame` — the receive half of the PR 5 zero-copy wire plane.
    fn decode_exact_shared(frame: &SharedBytes) -> Result<Self, DecodeError> {
        let mut r = ByteReader::shared(frame);
        let v = Self::decode(&mut r)?;
        if !r.is_exhausted() {
            return Err(DecodeError::TooLong { at: r.position(), len: r.remaining() as u64 });
        }
        Ok(v)
    }
}

macro_rules! wire_primitive {
    ($ty:ty, $put:ident, $get:ident) => {
        impl Wire for $ty {
            fn encode(&self, w: &mut ByteWriter) {
                w.$put(*self);
            }
            fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
                r.$get()
            }
        }
    };
}

wire_primitive!(u8, put_u8, get_u8);
wire_primitive!(bool, put_bool, get_bool);
wire_primitive!(u16, put_u16, get_u16);
wire_primitive!(u32, put_u32, get_u32);
wire_primitive!(u64, put_u64, get_u64);
wire_primitive!(i64, put_i64, get_i64);
wire_primitive!(f32, put_f32, get_f32);
wire_primitive!(f64, put_f64, get_f64);

impl Wire for usize {
    fn encode(&self, w: &mut ByteWriter) {
        w.put_u64(*self as u64);
    }
    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        Ok(r.get_u64()? as usize)
    }
}

impl Wire for String {
    fn encode(&self, w: &mut ByteWriter) {
        w.put_str(self);
    }
    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        r.get_str()
    }
}

impl<T: Wire> Wire for Option<T> {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            None => w.put_u8(0),
            Some(v) => {
                w.put_u8(1);
                v.encode(w);
            }
        }
    }
    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        let at = r.position();
        match r.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            tag => Err(DecodeError::BadTag { at, tag: tag as u32, ty: "Option" }),
        }
    }
}

impl<T: Wire> Wire for Vec<T> {
    fn encode(&self, w: &mut ByteWriter) {
        debug_assert!(self.len() as u64 <= MAX_LEN);
        w.put_u32(self.len() as u32);
        for v in self {
            v.encode(w);
        }
    }
    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        let at = r.position();
        let n = r.get_u32()? as u64;
        if n > MAX_LEN {
            return Err(DecodeError::TooLong { at, len: n });
        }
        let mut out = Vec::with_capacity((n as usize).min(4096));
        for _ in 0..n {
            out.push(T::decode(r)?);
        }
        Ok(out)
    }
}

impl<K: Wire + Ord, V: Wire> Wire for BTreeMap<K, V> {
    fn encode(&self, w: &mut ByteWriter) {
        w.put_u32(self.len() as u32);
        for (k, v) in self {
            k.encode(w);
            v.encode(w);
        }
    }
    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        let n = r.get_u32()?;
        let mut out = BTreeMap::new();
        for _ in 0..n {
            let k = K::decode(r)?;
            let v = V::decode(r)?;
            out.insert(k, v);
        }
        Ok(out)
    }
}

impl<A: Wire, B: Wire> Wire for (A, B) {
    fn encode(&self, w: &mut ByteWriter) {
        self.0.encode(w);
        self.1.encode(w);
    }
    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        Ok((A::decode(r)?, B::decode(r)?))
    }
}

impl Wire for () {
    fn encode(&self, _w: &mut ByteWriter) {}
    fn decode(_r: &mut ByteReader) -> Result<Self, DecodeError> {
        Ok(())
    }
}

/// Raw byte payloads: encoded length-prefixed (distinct from `Vec<u8>` which
/// would also work but costs per-element dispatch in debug builds).
///
/// `Arc`-backed ([`SharedBytes`]): cloning a `Blob` shares the allocation,
/// so the embedded broker hot path (`publish → PartitionLog → fetch_many →
/// poll`) moves **zero** payload bytes. Since PR 5 the TCP path is
/// zero-copy too: encoding through a segmented writer records the payload
/// as an out-of-line segment (the vectored send writes it straight from
/// its `Arc`), and decoding from a received frame ([`ByteReader::shared`],
/// which every `recv` path uses) yields a sub-view of the frame buffer.
/// Dereferences to `[u8]`.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Blob(pub SharedBytes);

impl Blob {
    /// Wrap a buffer without copying it.
    pub fn new(bytes: Vec<u8>) -> Self {
        Blob(SharedBytes::new(bytes))
    }

    /// Share an existing `Arc<Vec<u8>>` allocation (zero-copy).
    pub fn from_arc(bytes: std::sync::Arc<Vec<u8>>) -> Self {
        Blob(SharedBytes::from_arc(bytes))
    }

    pub fn as_slice(&self) -> &[u8] {
        self.0.as_slice()
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// True when both blobs share one allocation (the zero-copy witness).
    pub fn ptr_eq(&self, other: &Blob) -> bool {
        self.0.ptr_eq(&other.0)
    }

    /// True when both blobs view the same allocation, whatever their
    /// ranges — the **remote** zero-copy witness: every payload decoded
    /// out of one received frame reports the frame's buffer.
    pub fn shares_buffer(&self, other: &Blob) -> bool {
        self.0.shares_buffer(&other.0)
    }
}

impl std::ops::Deref for Blob {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.0
    }
}

impl From<Vec<u8>> for Blob {
    fn from(v: Vec<u8>) -> Self {
        Blob::new(v)
    }
}

impl Wire for Blob {
    fn encode(&self, w: &mut ByteWriter) {
        // Segmented writers keep the payload out-of-line (written straight
        // from its Arc by the vectored send path); plain writers copy.
        w.put_shared(&self.0);
    }
    fn decode(r: &mut ByteReader) -> Result<Self, DecodeError> {
        // Shared readers hand back a zero-copy view of the frame buffer.
        Ok(Blob(r.get_shared()?))
    }
}

/// Declarative struct codec: field-by-field encode/decode.
///
/// ```ignore
/// wire_struct!(Foo { a: u32, b: String });
/// ```
#[macro_export]
macro_rules! wire_struct {
    ($name:ident { $($field:ident : $ty:ty),* $(,)? }) => {
        impl $crate::util::wire::Wire for $name {
            fn encode(&self, w: &mut $crate::util::bytes::ByteWriter) {
                $( $crate::util::wire::Wire::encode(&self.$field, w); )*
            }
            fn decode(
                r: &mut $crate::util::bytes::ByteReader,
            ) -> ::std::result::Result<Self, $crate::util::bytes::DecodeError> {
                Ok($name { $( $field: <$ty as $crate::util::wire::Wire>::decode(r)?, )* })
            }
        }
    };
}

/// Frame = u32 length + payload. Hard cap to survive corrupt peers.
pub const MAX_FRAME: usize = 1 << 30;

/// Write one length-prefixed frame: header + payload in a single vectored
/// write (one syscall) instead of two `write_all`s.
pub fn write_frame<W: Write>(sock: &mut W, payload: &[u8]) -> std::io::Result<()> {
    assert!(payload.len() <= MAX_FRAME, "frame too large");
    let len = (payload.len() as u32).to_le_bytes();
    write_all_vectored(sock, &[&len, payload])?;
    sock.flush()
}

/// Write one length-prefixed frame whose payload is `prefix` followed by
/// `body`'s byte stream, as a single vectored write: the length header,
/// the prefix (e.g. a correlation id), the encode scratch and every
/// out-of-line payload segment go down in one syscall — payload bytes are
/// written **straight from their `Arc`**, never memcpy'd into the encode
/// buffer. This is the send half of the PR 5 zero-copy wire plane.
pub fn write_frame_parts<W: Write>(
    sock: &mut W,
    prefix: &[u8],
    body: &ByteWriter,
) -> std::io::Result<()> {
    let total = prefix.len() + body.len();
    assert!(total <= MAX_FRAME, "frame too large");
    let len = (total as u32).to_le_bytes();
    let mut parts: Vec<&[u8]> = Vec::with_capacity(8);
    parts.push(&len);
    if !prefix.is_empty() {
        parts.push(prefix);
    }
    body.extend_chunks(&mut parts);
    write_all_vectored(sock, &parts)?;
    sock.flush()
}

/// Write every byte of `parts`, in order, using vectored writes. Handles
/// partial writes, `Interrupted`, and writers whose `write_vectored` only
/// consumes the first buffer (the `Write` default). The iovec list per
/// syscall is capped well under `IOV_MAX`.
pub fn write_all_vectored<W: Write>(sock: &mut W, parts: &[&[u8]]) -> std::io::Result<()> {
    const MAX_IOV: usize = 64;
    let mut idx = 0usize; // current part
    let mut off = 0usize; // bytes of parts[idx] already written
    while idx < parts.len() {
        if off >= parts[idx].len() {
            idx += 1;
            off = 0;
            continue;
        }
        let mut iov: Vec<IoSlice<'_>> = Vec::with_capacity((parts.len() - idx).min(MAX_IOV));
        iov.push(IoSlice::new(&parts[idx][off..]));
        for p in parts[idx + 1..].iter().take(MAX_IOV - 1) {
            if !p.is_empty() {
                iov.push(IoSlice::new(p));
            }
        }
        let mut n = match sock.write_vectored(&iov) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "vectored write made no progress",
                ))
            }
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        while n > 0 {
            let rem = parts[idx].len() - off;
            if n >= rem {
                n -= rem;
                idx += 1;
                off = 0;
            } else {
                off += n;
                n = 0;
            }
        }
    }
    Ok(())
}

/// Read one length-prefixed frame. Returns `None` on clean EOF at a frame
/// boundary (peer closed). One framing implementation exists — this is
/// [`read_frame_patient`] with an always-keep-going policy (blocking
/// sockets never surface `WouldBlock`).
pub fn read_frame<R: Read>(sock: &mut R) -> std::io::Result<Option<Vec<u8>>> {
    read_frame_patient(sock, || true)
}

/// Read one length-prefixed frame over a socket with a read timeout,
/// preserving partial-read state across timeouts so a slow peer never
/// desynchronises the framing. `keep_going()` is consulted on every
/// timeout tick: returning `false` between frames yields `Ok(None)` (treat
/// like a clean close — this is how server connection threads honour a
/// stop flag); returning `false` mid-frame is a `TimedOut` error.
pub fn read_frame_patient<R: Read>(
    sock: &mut R,
    mut keep_going: impl FnMut() -> bool,
) -> std::io::Result<Option<Vec<u8>>> {
    use std::io::ErrorKind;
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match sock.read(&mut len_buf[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(None) // clean EOF at a frame boundary
                } else {
                    Err(std::io::Error::new(
                        ErrorKind::UnexpectedEof,
                        "peer closed mid frame header",
                    ))
                };
            }
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                if !keep_going() {
                    return if got == 0 {
                        Ok(None) // stop requested between frames
                    } else {
                        Err(std::io::Error::new(ErrorKind::TimedOut, "stopped mid frame"))
                    };
                }
            }
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds cap"),
        ));
    }
    let mut payload = vec![0u8; len];
    let mut got = 0usize;
    while got < len {
        match sock.read(&mut payload[got..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "peer closed mid frame body",
                ))
            }
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                if !keep_going() {
                    return Err(std::io::Error::new(ErrorKind::TimedOut, "stopped mid frame"));
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(Some(payload))
}

/// [`recv_msg`] over [`read_frame_patient`]: survives read timeouts and
/// lets the caller bail out between frames via `keep_going`.
pub fn recv_msg_patient<R: Read, T: Wire>(
    sock: &mut R,
    keep_going: impl FnMut() -> bool,
) -> std::io::Result<Option<T>> {
    match read_frame_patient(sock, keep_going)? {
        None => Ok(None),
        Some(buf) => decode_frame(buf).map(Some),
    }
}

/// Decode one received frame, zero-copy: the buffer becomes an `Arc`-backed
/// frame and every [`Blob`] in the message is a sub-view of it.
fn decode_frame<T: Wire>(buf: Vec<u8>) -> std::io::Result<T> {
    let frame = SharedBytes::new(buf);
    T::decode_exact_shared(&frame)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
}

/// Send a `Wire` message as one frame (one vectored write; large payloads
/// go straight from their `Arc`, not through the encode buffer).
pub fn send_msg<W: Write, T: Wire>(sock: &mut W, msg: &T) -> std::io::Result<()> {
    let mut w = ByteWriter::segmented();
    msg.encode(&mut w);
    write_frame_parts(sock, &[], &w)
}

/// [`send_msg`] with a caller-owned encode buffer: `scratch` is cleared and
/// reused, so per-connection send loops skip the per-frame allocation.
pub fn send_msg_buf<W: Write, T: Wire>(
    sock: &mut W,
    msg: &T,
    scratch: &mut ByteWriter,
) -> std::io::Result<()> {
    scratch.clear();
    msg.encode(scratch);
    write_frame_parts(sock, &[], scratch)
}

/// Receive a `Wire` message from one frame; `None` on clean EOF. [`Blob`]
/// payloads are zero-copy views of the received frame.
pub fn recv_msg<R: Read, T: Wire>(sock: &mut R) -> std::io::Result<Option<T>> {
    match read_frame(sock)? {
        None => Ok(None),
        Some(buf) => decode_frame(buf).map(Some),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, PartialEq)]
    struct Demo {
        id: u64,
        name: String,
        tags: Vec<u32>,
        extra: Option<String>,
    }
    wire_struct!(Demo { id: u64, name: String, tags: Vec<u32>, extra: Option<String> });

    fn demo() -> Demo {
        Demo {
            id: 42,
            name: "stream".into(),
            tags: vec![1, 2, 3],
            extra: Some("x".into()),
        }
    }

    #[test]
    fn struct_roundtrip() {
        let d = demo();
        assert_eq!(Demo::decode_exact(&d.encode_vec()).unwrap(), d);
    }

    #[test]
    fn option_none_roundtrip() {
        let d = Demo { extra: None, ..demo() };
        assert_eq!(Demo::decode_exact(&d.encode_vec()).unwrap(), d);
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut buf = demo().encode_vec();
        buf.push(0);
        assert!(Demo::decode_exact(&buf).is_err());
    }

    #[test]
    fn map_roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), 1u64);
        m.insert("b".to_string(), 2u64);
        let buf = m.encode_vec();
        assert_eq!(BTreeMap::<String, u64>::decode_exact(&buf).unwrap(), m);
    }

    #[test]
    fn frames_over_pipe() {
        // Use an in-memory cursor pair to exercise framing.
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"");
        assert!(read_frame(&mut cur).unwrap().is_none()); // clean EOF
    }

    #[test]
    fn corrupt_length_is_io_error() {
        let mut cur = std::io::Cursor::new(vec![0xFF, 0xFF, 0xFF, 0xFF, 0, 0]);
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn blob_roundtrip() {
        let b = Blob::new(vec![0u8; 1024]);
        assert_eq!(Blob::decode_exact(&b.encode_vec()).unwrap(), b);
    }

    #[test]
    fn blob_clone_shares_the_allocation() {
        let b = Blob::new(vec![1, 2, 3]);
        let c = b.clone();
        assert!(b.ptr_eq(&c), "Blob clone must be an Arc clone, not a copy");
        assert_eq!(b[0], 1);
        assert_eq!(b.len(), 3);
        // The wire roundtrip is where the one copy happens.
        let d = Blob::decode_exact(&b.encode_vec()).unwrap();
        assert_eq!(b, d);
        assert!(!b.ptr_eq(&d));
    }

    /// A reader that delivers one byte per call and reports a read timeout
    /// (`WouldBlock`) on every other call — a socket with a short
    /// `set_read_timeout` and a slow peer.
    struct Choppy {
        data: Vec<u8>,
        pos: usize,
        starve: bool,
        /// Past the data: `true` reports clean EOF, `false` keeps timing
        /// out (a silent but alive peer).
        eof: bool,
    }

    impl std::io::Read for Choppy {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.starve = !self.starve;
            if self.starve {
                return Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "tick"));
            }
            if self.pos >= self.data.len() {
                return if self.eof {
                    Ok(0)
                } else {
                    Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "silent"))
                };
            }
            buf[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    #[test]
    fn patient_read_survives_timeouts() {
        let mut framed = Vec::new();
        write_frame(&mut framed, b"hello").unwrap();
        let mut sock = Choppy { data: framed, pos: 0, starve: false, eof: true };
        let got = read_frame_patient(&mut sock, || true).unwrap();
        assert_eq!(got.unwrap(), b"hello", "partial reads must not desync the framing");
        // Clean EOF after the frame.
        assert!(read_frame_patient(&mut sock, || true).unwrap().is_none());
    }

    #[derive(Debug, Clone, PartialEq)]
    struct TwoBlobs {
        a: Blob,
        b: Blob,
    }
    wire_struct!(TwoBlobs { a: Blob, b: Blob });

    #[test]
    fn shared_frame_decode_is_zero_copy() {
        let msg = TwoBlobs { a: Blob::new(vec![1; 100]), b: Blob::new(vec![2; 100]) };
        let frame = SharedBytes::new(msg.encode_vec());
        let back = TwoBlobs::decode_exact_shared(&frame).unwrap();
        assert_eq!(back, msg);
        let witness = Blob(frame.slice(0, 0));
        assert!(back.a.shares_buffer(&witness), "payload a must view the frame buffer");
        assert!(back.b.shares_buffer(&witness), "payload b must view the frame buffer");
        assert!(back.a.shares_buffer(&back.b));
        // The plain decode path still copies.
        let copied = TwoBlobs::decode_exact(frame.as_slice()).unwrap();
        assert!(!copied.a.shares_buffer(&witness));
    }

    #[test]
    fn vectored_frame_matches_plain_frame() {
        let blob = Blob::new(vec![0x5A; 300]); // out-of-line in segmented mode
        let mut w = ByteWriter::segmented();
        blob.encode(&mut w);
        let prefix = [7u8; 8];
        let mut framed = Vec::new();
        write_frame_parts(&mut framed, &prefix, &w).unwrap();
        let mut flat = prefix.to_vec();
        flat.extend(blob.encode_vec());
        let mut expect = Vec::new();
        write_frame(&mut expect, &flat).unwrap();
        assert_eq!(framed, expect, "segmented vectored frame must be byte-identical");
        let mut cur = std::io::Cursor::new(framed);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), flat);
    }

    /// A writer that accepts at most 3 bytes per call and only implements
    /// `write` — `write_vectored` falls back to the std default (first
    /// buffer only), exercising the partial-progress loop.
    struct Trickle(Vec<u8>);

    impl std::io::Write for Trickle {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = buf.len().min(3);
            self.0.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_all_vectored_survives_partial_writers() {
        let parts: Vec<&[u8]> = vec![b"he", b"", b"llo ", b"wire", b"", b" plane"];
        let mut sink = Trickle(Vec::new());
        write_all_vectored(&mut sink, &parts).unwrap();
        assert_eq!(sink.0, b"hello wire plane");
        // send_msg through the same trickle writer frames correctly.
        let mut sink = Trickle(Vec::new());
        let msg = TwoBlobs { a: Blob::new(vec![9; 80]), b: Blob::new(vec![8; 5]) };
        send_msg(&mut sink, &msg).unwrap();
        let mut cur = std::io::Cursor::new(sink.0);
        let back: TwoBlobs = recv_msg(&mut cur).unwrap().unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn send_msg_buf_reuses_the_scratch() {
        let mut scratch = ByteWriter::segmented();
        let mut out = Vec::new();
        for i in 0..3u8 {
            let msg = TwoBlobs { a: Blob::new(vec![i; 70]), b: Blob::new(vec![i]) };
            send_msg_buf(&mut out, &msg, &mut scratch).unwrap();
        }
        let mut cur = std::io::Cursor::new(out);
        for i in 0..3u8 {
            let back: TwoBlobs = recv_msg(&mut cur).unwrap().unwrap();
            assert_eq!(back.a.as_slice(), &vec![i; 70][..]);
            assert_eq!(back.b.as_slice(), &[i]);
        }
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn patient_read_honours_stop_between_frames() {
        // Stop requested while no frame is in flight: treated as a close.
        let mut idle = Choppy { data: Vec::new(), pos: 0, starve: false, eof: false };
        assert!(read_frame_patient(&mut idle, || false).unwrap().is_none());

        // Stop requested mid-frame: an error, never a silent truncation.
        let mut framed = Vec::new();
        write_frame(&mut framed, b"hello").unwrap();
        framed.truncate(6); // header + one body byte, then starvation
        let mut sock = Choppy { data: framed, pos: 0, starve: false, eof: false };
        let mut ticks = 0;
        let err = read_frame_patient(&mut sock, || {
            ticks += 1;
            ticks < 8 // give up after a few timeout ticks
        })
        .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
    }
}
