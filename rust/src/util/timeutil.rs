//! Time helpers: scaled durations (paper-seconds → bench-milliseconds),
//! stopwatches and human-readable formatting.
//!
//! The paper's experiments use minute-scale tasks on MareNostrum; the bench
//! harness reproduces the *shape* of each figure with durations scaled by
//! [`TimeScale`] (default 1/100), which leaves all reported gains — ratios
//! of execution times — unchanged.

use std::time::{Duration, Instant};

/// Multiplicative scale applied to paper durations.
#[derive(Debug, Clone, Copy)]
pub struct TimeScale {
    /// e.g. 0.01 → paper 60 000 ms becomes 600 ms.
    pub factor: f64,
}

impl TimeScale {
    pub const IDENTITY: TimeScale = TimeScale { factor: 1.0 };

    /// Default bench scale (1/100), overridable via `HYBRIDWS_TIME_SCALE`.
    pub fn from_env() -> Self {
        let factor = std::env::var("HYBRIDWS_TIME_SCALE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0.01);
        Self { factor }
    }

    pub fn new(factor: f64) -> Self {
        assert!(factor > 0.0, "scale must be positive");
        Self { factor }
    }

    /// Scale a duration given in *paper* milliseconds.
    pub fn paper_ms(&self, ms: u64) -> Duration {
        Duration::from_secs_f64(ms as f64 / 1000.0 * self.factor)
    }
}

/// Simple monotonic stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1000.0
    }
}

/// `1.23 s` / `45.6 ms` / `789 µs` style formatting.
pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.1} ms", s * 1e3)
    } else {
        format!("{:.0} µs", s * 1e6)
    }
}

/// Milliseconds of `d`, rounded **up**. Long-poll waits must use this
/// instead of `as_millis()` truncation: a sub-millisecond remainder that
/// truncates to 0 turns the final slice of a blocking wait into a
/// non-blocking busy-spin.
pub fn ceil_ms(d: Duration) -> u64 {
    let ms = d.as_millis() as u64;
    if Duration::from_millis(ms) < d {
        ms + 1
    } else {
        ms
    }
}

/// Poll `pred` (every few milliseconds) until it holds or `timeout`
/// elapses; reports whether it held. The bounded replacement for fixed
/// `thread::sleep` synchronisation in tests: a slow machine waits as
/// long as it needs, a fast one moves on in single-digit milliseconds,
/// and a hang still fails — at the timeout, with the predicate's name
/// in the assertion instead of a flaky downstream symptom.
pub fn wait_until(mut pred: impl FnMut() -> bool, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        if pred() {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// `12.3 MB/s` style throughput formatting.
pub fn human_rate(bytes: u64, d: Duration) -> String {
    let bps = bytes as f64 / d.as_secs_f64().max(1e-9);
    if bps >= 1e9 {
        format!("{:.2} GB/s", bps / 1e9)
    } else if bps >= 1e6 {
        format!("{:.2} MB/s", bps / 1e6)
    } else if bps >= 1e3 {
        format!("{:.2} kB/s", bps / 1e3)
    } else {
        format!("{bps:.0} B/s")
    }
}

/// Mean of a sample of f64s.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation, `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (rank - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_applies_factor() {
        let s = TimeScale::new(0.01);
        assert_eq!(s.paper_ms(60_000), Duration::from_millis(600));
        assert_eq!(TimeScale::IDENTITY.paper_ms(250), Duration::from_millis(250));
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_scale_rejected() {
        TimeScale::new(0.0);
    }

    #[test]
    fn human_duration_bands() {
        assert_eq!(human_duration(Duration::from_secs(2)), "2.00 s");
        assert_eq!(human_duration(Duration::from_millis(45)), "45.0 ms");
        assert_eq!(human_duration(Duration::from_micros(789)), "789 µs");
    }

    #[test]
    fn stats_helpers() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!(stddev(&xs) > 0.0);
    }

    #[test]
    fn stopwatch_measures_forward() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_ms() >= 1.0);
    }

    #[test]
    fn wait_until_polls_to_success_or_deadline() {
        assert!(wait_until(|| true, Duration::ZERO), "an already-true predicate needs no wait");
        let t0 = Instant::now();
        assert!(!wait_until(|| false, Duration::from_millis(20)));
        assert!(t0.elapsed() >= Duration::from_millis(20), "must wait out the full timeout");
        // A predicate that flips mid-wait is caught well before timeout.
        let flip = Instant::now() + Duration::from_millis(10);
        assert!(wait_until(|| Instant::now() >= flip, Duration::from_secs(5)));
    }

    #[test]
    fn ceil_ms_rounds_up_subms_remainders() {
        assert_eq!(ceil_ms(Duration::ZERO), 0);
        assert_eq!(ceil_ms(Duration::from_millis(5)), 5);
        assert_eq!(ceil_ms(Duration::from_micros(1)), 1, "sub-ms must not truncate to 0");
        assert_eq!(ceil_ms(Duration::from_micros(5_200)), 6);
    }
}
