//! Minimal `log` backend: level from `HYBRIDWS_LOG` (error|warn|info|debug|trace).
//!
//! Prints `YYYY-MM-DDTHH:MM:SS.mmmZ LEVEL target: message` to stderr (one
//! RFC 3339-style UTC stamp — earlier revisions printed a date-less
//! `HH:MM:SS` derived straight from the raw epoch seconds, which made logs
//! from different days indistinguishable). Install once with [`init`];
//! repeated calls are no-ops (safe from tests and examples alike).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static INSTALLED: AtomicBool = AtomicBool::new(false);
static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
        let stamp = format_utc(now.as_secs(), now.subsec_millis());
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("{stamp} {lvl} {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Epoch seconds + millis → `YYYY-MM-DDTHH:MM:SS.mmmZ`. Civil-date math
/// from days-since-epoch (valid for all of the Unix era), so the stamp
/// carries the date instead of a bare wall-clock remainder.
fn format_utc(secs: u64, millis: u32) -> String {
    let days = secs / 86_400;
    let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
    let (year, month, day) = civil_from_days(days as i64);
    format!("{year:04}-{month:02}-{day:02}T{h:02}:{m:02}:{s:02}.{millis:03}Z")
}

/// Days since 1970-01-01 → `(year, month, day)` in the proleptic Gregorian
/// calendar (the classic era-based algorithm).
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097); // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365; // [0, 399]
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11], March-based
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32; // [1, 31]
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32; // [1, 12]
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// Parse a level name; unknown names fall back to `Info`.
fn parse_level(s: &str) -> LevelFilter {
    match s.to_ascii_lowercase().as_str() {
        "off" => LevelFilter::Off,
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "info" => LevelFilter::Info,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        _ => LevelFilter::Info,
    }
}

/// Install the stderr logger (idempotent). Level from `HYBRIDWS_LOG`,
/// default `warn` so benches stay quiet.
pub fn init() {
    init_with(std::env::var("HYBRIDWS_LOG").as_deref().unwrap_or("warn"));
}

/// Install with an explicit level name.
pub fn init_with(level: &str) {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        log::set_max_level(parse_level(level));
        return;
    }
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(parse_level(level));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init_with("debug");
        init_with("info");
        assert_eq!(log::max_level(), LevelFilter::Info);
        log::info!("logging smoke test");
    }

    #[test]
    fn unknown_level_defaults_to_info() {
        assert_eq!(parse_level("nonsense"), LevelFilter::Info);
        assert_eq!(parse_level("TRACE"), LevelFilter::Trace);
    }

    #[test]
    fn timestamps_carry_the_date() {
        assert_eq!(format_utc(0, 0), "1970-01-01T00:00:00.000Z");
        // A modern date — the old `% 24`-only stamp would have shown a
        // bare time with no way to tell the day.
        assert_eq!(format_utc(1_786_147_200, 250), "2026-08-08T00:00:00.250Z");
        // Leap-year day.
        assert_eq!(format_utc(1_709_164_800, 0), "2024-02-29T00:00:00.000Z");
        // End-of-year rollover, mid-day.
        assert_eq!(format_utc(1_735_689_599, 999), "2024-12-31T23:59:59.999Z");
    }

    #[test]
    fn civil_from_days_matches_known_dates() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(365), (1971, 1, 1));
        assert_eq!(civil_from_days(11_017), (2000, 3, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1));
    }
}
