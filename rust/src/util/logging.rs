//! Minimal `log` backend: level from `HYBRIDWS_LOG` (error|warn|info|debug|trace).
//!
//! Prints `HH:MM:SS.mmm LEVEL target: message` to stderr. Install once with
//! [`init`]; repeated calls are no-ops (safe from tests and examples alike).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static INSTALLED: AtomicBool = AtomicBool::new(false);
static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
        let secs = now.as_secs();
        let millis = now.subsec_millis();
        let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("{h:02}:{m:02}:{s:02}.{millis:03} {lvl} {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Parse a level name; unknown names fall back to `Info`.
fn parse_level(s: &str) -> LevelFilter {
    match s.to_ascii_lowercase().as_str() {
        "off" => LevelFilter::Off,
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "info" => LevelFilter::Info,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        _ => LevelFilter::Info,
    }
}

/// Install the stderr logger (idempotent). Level from `HYBRIDWS_LOG`,
/// default `warn` so benches stay quiet.
pub fn init() {
    init_with(std::env::var("HYBRIDWS_LOG").as_deref().unwrap_or("warn"));
}

/// Install with an explicit level name.
pub fn init_with(level: &str) {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        log::set_max_level(parse_level(level));
        return;
    }
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(parse_level(level));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init_with("debug");
        init_with("info");
        assert_eq!(log::max_level(), LevelFilter::Info);
        log::info!("logging smoke test");
    }

    #[test]
    fn unknown_level_defaults_to_info() {
        assert_eq!(parse_level("nonsense"), LevelFilter::Info);
        assert_eq!(parse_level("TRACE"), LevelFilter::Trace);
    }
}
