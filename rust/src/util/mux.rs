//! Pipelined, multiplexed wire connections (PR 5).
//!
//! The lock-step RPC planes (broker, DistroStream registry) serialized one
//! request/response pair per socket round trip, bounding remote throughput
//! at `1/RTT`. This module multiplexes **many in-flight requests over one
//! socket**:
//!
//! - Every frame carries a **correlation id** (`[u32 len][u64 corr][body]`
//!   — the body is the unchanged `Wire` encoding of the request/response,
//!   so the one-shot codec survives as the frame format).
//! - A per-connection **writer thread** coalesces queued requests into
//!   single vectored writes; payload segments go straight from their `Arc`
//!   ([`crate::util::bytes::ByteWriter::segmented`]), never memcpy'd into
//!   the encode buffer.
//! - A per-connection **reader thread** dispatches response frames to the
//!   callers waiting on their id — responses may arrive in any order, so
//!   parked long-polls no longer block the requests pipelined behind them.
//!
//! Protocol negotiation: a mux client's first frame is a magic **hello**
//! ([`hello_frame`]); servers answer with their own hello and switch the
//! connection to mux framing. A legacy peer cannot decode the hello (the
//! magic is an invalid request tag) and closes the connection, which the
//! client reports as a clear handshake error — mixed old/new peers fail
//! fast instead of desynchronising.
//!
//! Since PR 9 the hello **negotiates the frame-header version**: the
//! server acks `min(peer_version, MUX_VERSION)` and both sides frame at
//! the negotiated version. Version 2 widens the per-frame header with a
//! propagated [`TraceCtx`] (`[u32 len][u64 corr][u64 trace_id]
//! [u64 span_id][body]`, on requests *and* responses) so distributed
//! traces cross the socket; version-1 peers keep the old 8-byte
//! `[corr]` header. Pre-negotiation v1 servers ack their own hello and
//! then drop mismatched connections, so a v2 client that receives a v1
//! ack redials and speaks v1 from the first frame.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::util::bytes::{ByteWriter, SharedBytes};
use crate::util::fault;
use crate::util::trace::{self, TraceCtx};
use crate::util::wire::{
    read_frame_patient, recv_msg_patient, send_msg_buf, write_all_vectored, write_frame,
    write_frame_parts, Wire, MAX_FRAME,
};

/// First bytes of a mux hello frame. Never a valid request tag in any of
/// the repo's protocols, so legacy servers reject the handshake instead of
/// misreading it.
pub const MUX_MAGIC: [u8; 4] = *b"HWMX";

/// Mux protocol version. The hello negotiates `min` across the peers:
/// - **1** — frames are `[u32 len][u64 corr][body]`.
/// - **2** — frames are `[u32 len][u64 corr][u64 trace_id][u64 span_id]
///   [body]`: every frame carries a trace context (zero = unsampled).
pub const MUX_VERSION: u32 = 2;

/// How long a connecting client waits for the server's hello ack before
/// declaring the peer incompatible (a legacy server closes immediately; a
/// silent one must not hang the connect forever).
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// The 8-byte hello/ack payload: magic + our version.
pub fn hello_frame() -> [u8; 8] {
    hello_frame_v(MUX_VERSION)
}

/// A hello/ack at an explicit version (downgrade redials, negotiation
/// acks).
pub fn hello_frame_v(version: u32) -> [u8; 8] {
    let mut buf = [0u8; 8];
    buf[..4].copy_from_slice(&MUX_MAGIC);
    buf[4..].copy_from_slice(&version.to_le_bytes());
    buf
}

/// Parse a frame payload as a mux hello; `Some(version)` when it is one.
pub fn parse_hello(buf: &[u8]) -> Option<u32> {
    if buf.len() == 8 && buf[..4] == MUX_MAGIC {
        Some(u32::from_le_bytes(buf[4..8].try_into().expect("4-byte slice")))
    } else {
        None
    }
}

/// Read one mux frame: `(corr, ctx, body)` where `body` is a zero-copy
/// view of the received frame buffer. `trace` selects the negotiated
/// header layout (v2 carries a [`TraceCtx`]; v1 frames decode with
/// `ctx == TraceCtx::NONE`). `None` on clean close / stop between frames.
pub fn read_mux_frame<R: Read>(
    sock: &mut R,
    trace: bool,
    keep_going: impl FnMut() -> bool,
) -> io::Result<Option<(u64, TraceCtx, SharedBytes)>> {
    let Some(buf) = read_frame_patient(sock, keep_going)? else {
        return Ok(None);
    };
    let hdr = if trace { 24 } else { 8 };
    if buf.len() < hdr {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "mux frame shorter than its header",
        ));
    }
    let corr = u64::from_le_bytes(buf[..8].try_into().expect("8-byte slice"));
    let ctx = if trace {
        TraceCtx {
            trace_id: u64::from_le_bytes(buf[8..16].try_into().expect("8-byte slice")),
            span_id: u64::from_le_bytes(buf[16..24].try_into().expect("8-byte slice")),
        }
    } else {
        TraceCtx::NONE
    };
    let frame = SharedBytes::new(buf);
    let body = frame.slice(hdr, frame.len());
    Ok(Some((corr, ctx, body)))
}

/// Write one mux frame (`corr` + optional trace context + `body`) as a
/// single vectored write, framed at the negotiated version.
pub fn write_mux_frame<W: Write>(
    sock: &mut W,
    corr: u64,
    ctx: TraceCtx,
    body: &ByteWriter,
    trace: bool,
) -> io::Result<()> {
    if trace {
        let mut prefix = [0u8; 24];
        prefix[..8].copy_from_slice(&corr.to_le_bytes());
        prefix[8..16].copy_from_slice(&ctx.trace_id.to_le_bytes());
        prefix[16..24].copy_from_slice(&ctx.span_id.to_le_bytes());
        write_frame_parts(sock, &prefix, body)
    } else {
        write_frame_parts(sock, &corr.to_le_bytes(), body)
    }
}

// ---- client side ---------------------------------------------------------

/// One request queued for the writer thread: correlation id, the trace
/// context captured at `submit` time, and the encoded body.
type OutFrame = (u64, TraceCtx, ByteWriter);

struct SendQueue {
    frames: VecDeque<OutFrame>,
    closed: bool,
}

struct PendingMap {
    /// corr → `None` (awaiting) / `Some((ctx, body))` (response arrived;
    /// `ctx` is the trace context the response frame carried).
    slots: HashMap<u64, Option<(TraceCtx, SharedBytes)>>,
    /// Set once, when the connection broke; every waiter observes it.
    dead: Option<String>,
}

struct Shared {
    /// The original socket, kept for `shutdown` (reader/writer own clones).
    sock: TcpStream,
    /// Negotiated v2 framing (per-frame trace headers)?
    trace: bool,
    queue: Mutex<SendQueue>,
    send_cv: Condvar,
    pending: Mutex<PendingMap>,
    recv_cv: Condvar,
    next_corr: AtomicU64,
}

impl Shared {
    /// Terminal: record the reason, fail every waiter, stop both threads.
    /// Lock order everywhere is `pending` before `queue`.
    fn fail(&self, why: String) {
        {
            let mut p = self.pending.lock().unwrap();
            if p.dead.is_none() {
                p.dead = Some(why);
            }
        }
        self.recv_cv.notify_all();
        {
            let mut q = self.queue.lock().unwrap();
            q.closed = true;
            q.frames.clear();
        }
        self.send_cv.notify_all();
        let _ = self.sock.shutdown(Shutdown::Both);
    }
}

/// A pipelined, multiplexed client connection: any number of threads call
/// [`MuxConn::call`] / [`MuxConn::submit`] concurrently over one socket;
/// responses resolve by correlation id in whatever order the server
/// completes them. Dropping the connection fails all outstanding calls.
pub struct MuxConn {
    shared: Arc<Shared>,
}

impl MuxConn {
    /// Connect and perform the mux handshake. Fails fast — with an error
    /// naming the handshake — against peers that only speak the legacy
    /// lock-step protocol or a different mux version.
    pub fn connect(addr: &str) -> io::Result<Self> {
        // Fault seam: a scripted connect refusal (simulated partition).
        if fault::active() && fault::check(fault::site::MUX_CONNECT, addr).is_some() {
            return Err(fault::injected_error(fault::site::MUX_CONNECT));
        }
        let sock = TcpStream::connect(addr)?;
        Self::establish(sock, addr)
    }

    /// Send a hello at `version` and return the version the peer acked.
    fn handshake(sock: &mut TcpStream, addr: &str, version: u32) -> io::Result<u32> {
        sock.set_nodelay(true).ok();
        sock.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
        write_frame(sock, &hello_frame_v(version))?;
        // `keep_going = false`: one timeout window is the whole budget — a
        // silent peer must fail the connect, not hang it.
        let ack = read_frame_patient(sock, || false).map_err(|e| {
            io::Error::new(
                e.kind(),
                format!("mux handshake with {addr}: {e} (legacy lock-step peer?)"),
            )
        })?;
        let Some(ack) = ack else {
            return Err(io::Error::new(
                io::ErrorKind::ConnectionReset,
                format!(
                    "{addr} closed or stayed silent during the mux handshake — peer \
                     speaks only the legacy lock-step protocol?"
                ),
            ));
        };
        let Some(acked) = parse_hello(&ack) else {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected mux handshake reply from {addr}"),
            ));
        };
        sock.set_read_timeout(None)?;
        Ok(acked)
    }

    fn establish(mut sock: TcpStream, addr: &str) -> io::Result<Self> {
        let acked = Self::handshake(&mut sock, addr, MUX_VERSION)?;
        let trace = match acked {
            v if v == MUX_VERSION => true,
            1 => {
                // A v1 peer. Negotiating servers serve v1 on this very
                // socket, but pre-negotiation servers ack their own hello
                // and then drop mismatched connections — the socket may
                // already be dead. Redial and speak v1 from the start;
                // that works against both generations.
                drop(sock);
                sock = TcpStream::connect(addr)?;
                let again = Self::handshake(&mut sock, addr, 1)?;
                if again != 1 {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!(
                            "mux version mismatch: {addr} acked downgrade hello 1 \
                             with {again}"
                        ),
                    ));
                }
                false
            }
            v => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("mux version mismatch: we speak {MUX_VERSION}, {addr} speaks {v}"),
                ));
            }
        };
        let rsock = sock.try_clone()?;
        let wsock = sock.try_clone()?;
        let shared = Arc::new(Shared {
            sock,
            trace,
            queue: Mutex::new(SendQueue { frames: VecDeque::new(), closed: false }),
            send_cv: Condvar::new(),
            pending: Mutex::new(PendingMap { slots: HashMap::new(), dead: None }),
            recv_cv: Condvar::new(),
            next_corr: AtomicU64::new(1),
        });
        let reader_shared = Arc::clone(&shared);
        if let Err(e) = std::thread::Builder::new()
            .name("mux-reader".into())
            .spawn(move || run_reader(rsock, reader_shared))
        {
            shared.fail(format!("spawn mux reader: {e}"));
            return Err(e);
        }
        let writer_shared = Arc::clone(&shared);
        if let Err(e) = std::thread::Builder::new()
            .name("mux-writer".into())
            .spawn(move || run_writer(wsock, writer_shared))
        {
            shared.fail(format!("spawn mux writer: {e}"));
            return Err(e);
        }
        Ok(Self { shared })
    }

    /// Enqueue one request and return a handle that resolves to its
    /// response — the pipelining primitive: submit many, wait later, and
    /// the writer thread coalesces everything queued into vectored writes.
    pub fn submit<T: Wire>(&self, msg: &T) -> io::Result<PendingReply> {
        let corr = self.shared.next_corr.fetch_add(1, Ordering::Relaxed);
        // Capture the submitting thread's ambient trace context here (the
        // writer thread has its own, unrelated, thread-locals).
        let ctx = trace::current();
        {
            let mut p = self.shared.pending.lock().unwrap();
            if let Some(why) = &p.dead {
                return Err(io::Error::new(io::ErrorKind::BrokenPipe, why.clone()));
            }
            // Registered before the frame is queued: the response cannot
            // race its waiter slot.
            p.slots.insert(corr, None);
            crate::obs_gauge!("mux.inflight").add(1);
        }
        let mut body = ByteWriter::segmented();
        msg.encode(&mut body);
        assert!(24 + body.len() <= MAX_FRAME, "mux frame too large");
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.closed {
                if self.shared.pending.lock().unwrap().slots.remove(&corr).is_some() {
                    crate::obs_gauge!("mux.inflight").sub(1);
                }
                return Err(io::Error::new(
                    io::ErrorKind::BrokenPipe,
                    "mux connection closed",
                ));
            }
            q.frames.push_back((corr, ctx, body));
        }
        self.shared.send_cv.notify_one();
        Ok(PendingReply { shared: Arc::clone(&self.shared), corr, taken: false })
    }

    /// One full round trip: submit + wait + decode.
    pub fn call<Q: Wire, R: Wire>(&self, req: &Q) -> io::Result<R> {
        self.submit(req)?.wait_msg()
    }

    /// True once the connection broke (subsequent submits fail fast).
    pub fn is_dead(&self) -> bool {
        self.shared.pending.lock().unwrap().dead.is_some()
    }
}

impl Drop for MuxConn {
    fn drop(&mut self) {
        // Stops both threads and fails any replies still pending.
        self.shared.fail("mux connection dropped".into());
    }
}

/// An in-flight request on a [`MuxConn`]. Dropping it abandons the call
/// (the response frame is discarded on arrival).
pub struct PendingReply {
    shared: Arc<Shared>,
    corr: u64,
    taken: bool,
}

impl PendingReply {
    /// Block until the response frame arrives; errors when the connection
    /// dies first. The returned body is a zero-copy view of the frame.
    pub fn wait(mut self) -> io::Result<SharedBytes> {
        self.taken = true;
        let mut p = self.shared.pending.lock().unwrap();
        loop {
            if matches!(p.slots.get(&self.corr), Some(Some(_))) {
                let slot = p.slots.remove(&self.corr).expect("slot present");
                crate::obs_gauge!("mux.inflight").sub(1);
                let (ctx, body) = slot.expect("slot filled");
                // Surface the server-side context the response carried to
                // the waiting thread (fetch wakeup → consumer poll).
                trace::set_reply(ctx);
                return Ok(body);
            }
            if let Some(why) = &p.dead {
                let why = why.clone();
                if p.slots.remove(&self.corr).is_some() {
                    crate::obs_gauge!("mux.inflight").sub(1);
                }
                return Err(io::Error::new(io::ErrorKind::BrokenPipe, why));
            }
            p = self.shared.recv_cv.wait(p).unwrap();
        }
    }

    /// [`PendingReply::wait`] + decode ([`Wire::decode_exact_shared`], so
    /// payloads stay views of the response frame).
    pub fn wait_msg<T: Wire>(self) -> io::Result<T> {
        let body = self.wait()?;
        T::decode_exact_shared(&body)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))
    }
}

impl Drop for PendingReply {
    fn drop(&mut self) {
        if !self.taken {
            // Abandoned call: free the slot; the reader drops unknown ids.
            if self.shared.pending.lock().unwrap().slots.remove(&self.corr).is_some() {
                crate::obs_gauge!("mux.inflight").sub(1);
            }
        }
    }
}

/// Reader thread body: route response frames to their waiters by id.
fn run_reader(mut sock: TcpStream, shared: Arc<Shared>) {
    let peer = sock.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    loop {
        // Fault seam: stall reply delivery, or drop the connection.
        if fault::active() {
            match fault::check(fault::site::MUX_READ, &peer) {
                Some(fault::FaultAction::Stall(ms)) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Some(_) => {
                    shared.fail("injected mux read drop".into());
                    return;
                }
                None => {}
            }
        }
        match read_mux_frame(&mut sock, shared.trace, || true) {
            Ok(Some((corr, ctx, body))) => {
                crate::obs_counter!("mux.rx_frames").inc();
                let mut p = shared.pending.lock().unwrap();
                if let Some(slot) = p.slots.get_mut(&corr) {
                    *slot = Some((ctx, body));
                    drop(p);
                    shared.recv_cv.notify_all();
                }
                // Unknown id: the caller abandoned the request — drop it.
            }
            Ok(None) => {
                shared.fail("mux peer closed the connection".into());
                return;
            }
            Err(e) => {
                shared.fail(format!("mux recv: {e}"));
                return;
            }
        }
    }
}

/// Writer thread body: drain everything queued and push it down the socket
/// as one vectored write per batch — requests submitted while a write is
/// in flight coalesce into the next one.
fn run_writer(mut sock: TcpStream, shared: Arc<Shared>) {
    let peer = sock.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    loop {
        let mut batch: Vec<OutFrame> = {
            let mut q = shared.queue.lock().unwrap();
            while q.frames.is_empty() && !q.closed {
                q = shared.send_cv.wait(q).unwrap();
            }
            if q.frames.is_empty() {
                return; // closed and drained
            }
            q.frames.drain(..).collect()
        };
        // Fault seam: drop / tear / stall / reorder the outgoing batch.
        if fault::active() {
            match fault::check(fault::site::MUX_WRITE, &peer) {
                Some(fault::FaultAction::Stall(ms)) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Some(fault::FaultAction::Reorder) => fault_shuffle(&mut batch),
                Some(fault::FaultAction::ShortWrite) => {
                    // A torn frame: a prefix of the first header escapes,
                    // then the connection dies mid-write.
                    let (corr, ctx, body) = &batch[0];
                    let (h, _) = frame_header(*corr, *ctx, body.len(), shared.trace);
                    let _ = sock.write_all(&h[..6]);
                    shared.fail("injected mux short write".into());
                    return;
                }
                Some(_) => {
                    shared.fail("injected mux connection drop".into());
                    return;
                }
                None => {}
            }
        }
        if let Err(e) = write_batch(&mut sock, &batch, shared.trace) {
            shared.fail(format!("mux send: {e}"));
            return;
        }
        let hdr = if shared.trace { HDR_V2 as u64 } else { HDR_V1 as u64 };
        crate::obs_counter!("mux.tx_frames").add(batch.len() as u64);
        let bytes: u64 = batch.iter().map(|(_, _, body)| hdr + body.len() as u64).sum();
        crate::obs_counter!("mux.tx_bytes").add(bytes);
        if trace::enabled() {
            // Mark the instant each sampled request hit the socket: a
            // zero-duration child of the submitting span, recording the
            // submit→write pipeline delay in the stitched timeline.
            let now = trace::now_us();
            for (_, ctx, _) in &batch {
                trace::record_at(*ctx, "mux.tx", now, 0);
            }
        }
    }
}

/// Fisher–Yates over an outgoing batch with the fault plane's seeded RNG
/// (reorder-window jitter: correlation-id routing must not care).
fn fault_shuffle(batch: &mut [OutFrame]) {
    for i in (1..batch.len()).rev() {
        let j = (fault::next_u64() % (i as u64 + 1)) as usize;
        batch.swap(i, j);
    }
}

/// On-the-wire header sizes (the `u32` length prefix + the per-frame
/// header `read_mux_frame` strips).
const HDR_V1: usize = 12; // [u32 len][u64 corr]
const HDR_V2: usize = 28; // [u32 len][u64 corr][u64 trace_id][u64 span_id]

/// Build one frame header at the negotiated version; returns the buffer
/// and how many of its bytes are live.
fn frame_header(corr: u64, ctx: TraceCtx, body_len: usize, trace: bool) -> ([u8; HDR_V2], usize) {
    let mut h = [0u8; HDR_V2];
    let (inner, hdr) = if trace { (24, HDR_V2) } else { (8, HDR_V1) };
    h[..4].copy_from_slice(&((inner + body_len) as u32).to_le_bytes());
    h[4..12].copy_from_slice(&corr.to_le_bytes());
    if trace {
        h[12..20].copy_from_slice(&ctx.trace_id.to_le_bytes());
        h[20..28].copy_from_slice(&ctx.span_id.to_le_bytes());
    }
    (h, hdr)
}

/// One vectored write for a whole batch of frames: per frame its header
/// (`len` + `corr` + the v2 trace context) followed by its body chunks,
/// payload segments straight from their `Arc`.
fn write_batch(sock: &mut TcpStream, batch: &[OutFrame], trace: bool) -> io::Result<()> {
    let mut headers = Vec::with_capacity(batch.len());
    for (corr, ctx, body) in batch {
        headers.push(frame_header(*corr, *ctx, body.len(), trace));
    }
    let mut parts: Vec<&[u8]> = Vec::with_capacity(batch.len() * 4);
    for ((_, _, body), (header, live)) in batch.iter().zip(&headers) {
        parts.push(&header[..*live]);
        body.extend_chunks(&mut parts);
    }
    write_all_vectored(sock, &parts)
}

/// A reconnectable slot holding one shared [`MuxConn`] — the client-side
/// transport state every mux client keeps per peer. The lock guards only
/// the slot: callers run their requests on a clone of the `Arc`, so any
/// number of them are in flight concurrently.
pub struct MuxSlot {
    addr: String,
    slot: Mutex<Option<Arc<MuxConn>>>,
}

impl MuxSlot {
    /// A slot over an already-established connection.
    pub fn connected(addr: &str, conn: Arc<MuxConn>) -> Self {
        Self { addr: addr.to_string(), slot: Mutex::new(Some(conn)) }
    }

    /// The peer address this slot (re)connects to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The live connection, (re)established on demand.
    pub fn get(&self) -> io::Result<Arc<MuxConn>> {
        let mut slot = self.slot.lock().unwrap();
        if let Some(c) = &*slot {
            return Ok(Arc::clone(c));
        }
        let c = Arc::new(MuxConn::connect(&self.addr)?);
        *slot = Some(Arc::clone(&c));
        Ok(c)
    }

    /// Forget `failed` so the next request reconnects (unless a concurrent
    /// caller already replaced it).
    pub fn invalidate(&self, failed: &Arc<MuxConn>) {
        let mut slot = self.slot.lock().unwrap();
        if slot.as_ref().is_some_and(|c| Arc::ptr_eq(c, failed)) {
            *slot = None;
        }
    }
}

// ---- server side ---------------------------------------------------------

/// Cap on concurrently parked long-polls per mux connection. Beyond it,
/// long-polls dispatch inline on the reader thread (correct, just
/// serialized) instead of spawning yet another park thread.
pub const MAX_PARKED_PER_CONN: usize = 64;

/// What the serve loop does with one decoded request.
pub enum ServeAction {
    /// Dispatch inline on the reader thread (keeps submission order —
    /// publish acks rely on this).
    Inline,
    /// Dispatch on a park thread (a long-poll that blocks); its response
    /// completes out of order, routed back by correlation id.
    Park,
    /// Answer, then close the connection (shutdown frames). The classifier
    /// performs its side effect (setting the stop flag) itself.
    Terminal,
}

/// Outcome of sniffing a connection's first frame (servers call this with
/// the raw payload before touching their protocol decoder).
pub enum Sniff {
    /// Not a hello: serve the legacy lock-step protocol, starting with
    /// this frame.
    Legacy,
    /// A compatible hello, already acked: serve mux frames from here on,
    /// with v2 trace headers iff `trace`.
    Mux { trace: bool },
    /// A hello we cannot speak with (an unusable version or a broken ack
    /// write): drop the connection.
    Reject,
}

/// Server half of the protocol negotiation: if `first` is a mux hello, ack
/// `min(peer_version, MUX_VERSION)` and frame at that version.
pub fn sniff_first_frame<W: Write>(sock: &mut W, first: &[u8], peer: &str) -> Sniff {
    let Some(version) = parse_hello(first) else {
        return Sniff::Legacy;
    };
    let negotiated = version.min(MUX_VERSION);
    if write_frame(sock, &hello_frame_v(negotiated)).is_err() {
        return Sniff::Reject;
    }
    if negotiated < 1 {
        log::warn!("mux conn {peer}: cannot negotiate version {version} (ours {MUX_VERSION})");
        return Sniff::Reject;
    }
    Sniff::Mux { trace: negotiated >= 2 }
}

/// Serve one upgraded mux connection (the shared body of the broker and
/// DistroStream servers): decode `Q` frames, classify, dispatch — inline
/// for ordered fast requests, on `park_name` threads for long-polls (capped
/// at [`MAX_PARKED_PER_CONN`], overflowing back to inline) — and answer
/// through one shared [`MuxResponder`]. Returns when the peer closes,
/// `keep_going` goes false between frames, a send breaks, or a
/// [`ServeAction::Terminal`] request was answered. Known cost: one
/// short-lived thread per parked long-poll slice (~4/s per idle consumer);
/// promoting parks to persistent per-connection workers is the natural
/// next step if profiles show the spawn mattering.
pub fn serve_mux_conn<Q, R, D>(
    mut sock: TcpStream,
    peer: &str,
    park_name: &str,
    trace: bool,
    mut keep_going: impl FnMut() -> bool,
    classify: impl Fn(&Q) -> ServeAction,
    dispatch: Arc<D>,
) where
    Q: Wire + Send + 'static,
    R: Wire,
    D: Fn(Q) -> R + Send + Sync + 'static,
{
    let responder = match sock.try_clone() {
        Ok(w) => Arc::new(MuxResponder::new(w, trace)),
        Err(e) => {
            log::debug!("mux conn {peer} clone failed: {e}");
            return;
        }
    };
    // Dispatch with the frame's trace context ambient, and answer with
    // whatever reply context the dispatch stashed (the server-side span a
    // client wrapper chains onto — fetch wakeup → consumer poll).
    let traced = move |ctx: TraceCtx, req: Q, dispatch: &D| -> (TraceCtx, R) {
        let prev = trace::set_current(ctx);
        let resp = dispatch(req);
        let reply = trace::take_reply();
        trace::set_current(prev);
        (reply, resp)
    };
    let parked = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    loop {
        if responder.is_broken() {
            break;
        }
        let (corr, ctx, body) = match read_mux_frame(&mut sock, trace, &mut keep_going) {
            Ok(Some(frame)) => frame,
            Ok(None) => break, // clean close, or stop requested while idle
            Err(e) => {
                log::debug!("mux conn {peer} read error: {e}");
                break;
            }
        };
        let req = match Q::decode_exact_shared(&body) {
            Ok(r) => r,
            Err(e) => {
                log::debug!("mux conn {peer} bad frame: {e}");
                break;
            }
        };
        match classify(&req) {
            ServeAction::Terminal => {
                let (reply, resp) = traced(ctx, req, &dispatch);
                responder.send_ctx(corr, reply, &resp);
                break;
            }
            ServeAction::Park if parked.load(Ordering::SeqCst) < MAX_PARKED_PER_CONN => {
                parked.fetch_add(1, Ordering::SeqCst);
                crate::obs_gauge!("mux.parked_polls").add(1);
                // The request rides in a take-once slot so a failed spawn
                // (thread exhaustion) can recover it and degrade to inline
                // dispatch — the same graceful overflow as the park cap —
                // instead of panicking the connection.
                let job = Arc::new(Mutex::new(Some(req)));
                let spawned = std::thread::Builder::new().name(park_name.to_string()).spawn({
                    let job = Arc::clone(&job);
                    let dispatch = Arc::clone(&dispatch);
                    let responder = Arc::clone(&responder);
                    let parked = Arc::clone(&parked);
                    move || {
                        if let Some(req) = job.lock().unwrap().take() {
                            let (reply, resp) = traced(ctx, req, &*dispatch);
                            responder.send_ctx(corr, reply, &resp);
                        }
                        parked.fetch_sub(1, Ordering::SeqCst);
                        crate::obs_gauge!("mux.parked_polls").sub(1);
                    }
                });
                if spawned.is_err() {
                    parked.fetch_sub(1, Ordering::SeqCst);
                    crate::obs_gauge!("mux.parked_polls").sub(1);
                    let Some(req) = job.lock().unwrap().take() else {
                        continue;
                    };
                    let (reply, resp) = traced(ctx, req, &dispatch);
                    if !responder.send_ctx(corr, reply, &resp) {
                        break;
                    }
                }
            }
            _ => {
                let (reply, resp) = traced(ctx, req, &dispatch);
                if !responder.send_ctx(corr, reply, &resp) {
                    break;
                }
            }
        }
    }
    // Parked threads still hold the responder Arc and finish on their own;
    // their sends fail harmlessly once the peer is gone.
}

/// Serve one legacy lock-step connection (the shared pre-PR 5 loop of the
/// broker and DistroStream servers, kept for old peers and raw-socket
/// tools): one request, one response, strictly serial — long-polls simply
/// park this thread inside `dispatch`. The encode buffer is reused across
/// frames and every reply is one vectored write. `first` is the request
/// the caller already read while sniffing the protocol; a
/// [`ServeAction::Terminal`] classification answers, then closes.
pub fn serve_legacy_conn<Q, R, D>(
    mut sock: TcpStream,
    peer: &str,
    mut keep_going: impl FnMut() -> bool,
    classify: impl Fn(&Q) -> ServeAction,
    dispatch: Arc<D>,
    first: Q,
) where
    Q: Wire,
    R: Wire,
    D: Fn(Q) -> R,
{
    let mut scratch = ByteWriter::segmented();
    let mut req = first;
    loop {
        let terminal = matches!(classify(&req), ServeAction::Terminal);
        let resp = (*dispatch)(req);
        if let Err(e) = send_msg_buf(&mut sock, &resp, &mut scratch) {
            log::debug!("legacy conn {peer} write error: {e}");
            return;
        }
        if terminal {
            return;
        }
        req = match recv_msg_patient(&mut sock, &mut keep_going) {
            Ok(Some(r)) => r,
            Ok(None) => return, // clean close, or stop requested while idle
            Err(e) => {
                log::debug!("legacy conn {peer} read error: {e}");
                return;
            }
        };
    }
}

/// The write half of a server-side mux connection, shared by the reader
/// loop (inline dispatches) and parked long-poll threads (out-of-order
/// completions). Each response reuses the per-connection encode buffer and
/// goes down in one vectored write.
pub struct MuxResponder {
    inner: Mutex<ResponderInner>,
    broken: AtomicBool,
    /// Negotiated v2 framing (responses carry a trace context)?
    trace: bool,
}

struct ResponderInner {
    sock: TcpStream,
    scratch: ByteWriter,
}

impl MuxResponder {
    pub fn new(sock: TcpStream, trace: bool) -> Self {
        Self {
            inner: Mutex::new(ResponderInner { sock, scratch: ByteWriter::segmented() }),
            broken: AtomicBool::new(false),
            trace,
        }
    }

    /// Send one response frame with no trace context.
    pub fn send<T: Wire>(&self, corr: u64, msg: &T) -> bool {
        self.send_ctx(corr, TraceCtx::NONE, msg)
    }

    /// Send one response frame carrying `ctx` (dropped on v1 framing);
    /// `false` once the socket broke (the connection is beyond saving —
    /// the serve loop should exit).
    pub fn send_ctx<T: Wire>(&self, corr: u64, ctx: TraceCtx, msg: &T) -> bool {
        let mut g = self.inner.lock().unwrap();
        let ResponderInner { sock, scratch } = &mut *g;
        scratch.clear();
        msg.encode(scratch);
        match write_mux_frame(sock, corr, ctx, scratch, self.trace) {
            Ok(()) => true,
            Err(_) => {
                self.broken.store(true, Ordering::SeqCst);
                false
            }
        }
    }

    /// True once a send failed; reads from this peer are pointless.
    pub fn is_broken(&self) -> bool {
        self.broken.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::wire::read_frame;
    use std::net::TcpListener;

    /// Minimal mux echo server at the current (v2) framing: ack the
    /// handshake, then answer every frame with its own body — echoing the
    /// request's trace context back on the response — optionally deferring
    /// batches to force reordering.
    fn echo_server(reorder: bool) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            let hello = read_frame(&mut sock).unwrap().unwrap();
            assert_eq!(parse_hello(&hello), Some(MUX_VERSION));
            write_frame(&mut sock, &hello_frame()).unwrap();
            let responder = MuxResponder::new(sock.try_clone().unwrap(), true);
            let mut held: Vec<(u64, TraceCtx, SharedBytes)> = Vec::new();
            loop {
                match read_mux_frame(&mut sock, true, || true) {
                    Ok(Some((corr, ctx, body))) => {
                        if reorder {
                            // Hold a few frames, answer them newest-first.
                            held.push((corr, ctx, body));
                            if held.len() >= 3 {
                                while let Some((c, x, b)) = held.pop() {
                                    responder.send_ctx(c, x, &crate::util::wire::Blob(b));
                                }
                            }
                        } else {
                            responder.send_ctx(corr, ctx, &crate::util::wire::Blob(body));
                        }
                    }
                    Ok(None) | Err(_) => break,
                }
            }
            while let Some((c, x, b)) = held.pop() {
                responder.send_ctx(c, x, &crate::util::wire::Blob(b));
            }
        });
        (addr, handle)
    }

    #[test]
    fn calls_resolve_by_correlation_id_across_reordering() {
        let (addr, server) = echo_server(true);
        let conn = MuxConn::connect(&addr.to_string()).unwrap();
        // Submit a window of requests, then wait them all: replies come
        // back newest-first and must still land on the right callers.
        let payloads: Vec<crate::util::wire::Blob> =
            (0..9u8).map(|i| crate::util::wire::Blob::new(vec![i; 10])).collect();
        let pending: Vec<PendingReply> =
            payloads.iter().map(|p| conn.submit(p).unwrap()).collect();
        for (p, sent) in pending.into_iter().zip(&payloads) {
            let got: crate::util::wire::Blob = p.wait_msg().unwrap();
            assert_eq!(&got, sent, "reply must match its own request");
        }
        drop(conn);
        server.join().unwrap();
    }

    #[test]
    fn dropped_connection_fails_pending_calls() {
        let (addr, server) = echo_server(true);
        let conn = MuxConn::connect(&addr.to_string()).unwrap();
        // One frame: held by the reordering server (needs 3 to flush).
        let a = conn.submit(&crate::util::wire::Blob::new(vec![1])).unwrap();
        drop(conn); // kills the socket; server flushes into the void
        assert!(a.wait().is_err(), "pending call must observe the death");
        server.join().unwrap();
    }

    #[test]
    fn legacy_peer_fails_the_handshake_fast() {
        // A legacy server reads one frame, cannot decode it, closes.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            let _ = read_frame(&mut sock);
            // Close without answering — exactly what the old loop does on
            // a BadTag decode error.
        });
        let err = MuxConn::connect(&addr.to_string()).unwrap_err();
        assert!(
            err.to_string().contains("handshake"),
            "error must name the handshake: {err}"
        );
        server.join().unwrap();
    }

    #[test]
    fn version_mismatch_is_a_clear_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            let _ = read_frame(&mut sock);
            let mut ack = hello_frame();
            ack[4..].copy_from_slice(&99u32.to_le_bytes());
            write_frame(&mut sock, &ack).unwrap();
        });
        let err = MuxConn::connect(&addr.to_string()).unwrap_err();
        assert!(err.to_string().contains("version"), "got: {err}");
        server.join().unwrap();
    }

    #[test]
    fn hello_roundtrip_and_rejections() {
        assert_eq!(parse_hello(&hello_frame()), Some(MUX_VERSION));
        assert_eq!(parse_hello(&hello_frame_v(1)), Some(1));
        assert_eq!(parse_hello(b"HWMX"), None, "length matters");
        assert_eq!(parse_hello(&[0u8; 8]), None, "magic matters");
    }

    #[test]
    fn frame_headers_roundtrip_at_both_versions() {
        let ctx = TraceCtx { trace_id: 0xdead_beef, span_id: 42 };
        let mut body = ByteWriter::segmented();
        body.put_raw(b"payload");
        for trace in [true, false] {
            let mut buf = Vec::new();
            write_mux_frame(&mut buf, 7, ctx, &body, trace).unwrap();
            let mut rd = &buf[..];
            let (corr, got, bytes) = read_mux_frame(&mut rd, trace, || true).unwrap().unwrap();
            assert_eq!(corr, 7);
            assert_eq!(&bytes[..], b"payload");
            // v2 carries the context; v1 degrades it to NONE.
            assert_eq!(got, if trace { ctx } else { TraceCtx::NONE });
        }
    }

    #[test]
    fn pre_negotiation_v1_server_downgrades_via_redial() {
        // Emulate an old (pre-PR 9) server: ack with its own v1 hello,
        // then drop the mismatched connection. The v2 client must redial
        // speaking v1, after which calls work (sans trace headers).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            // First dial: v2 hello → ack v1, close (the old reject path).
            let (mut sock, _) = listener.accept().unwrap();
            let hello = read_frame(&mut sock).unwrap().unwrap();
            assert_eq!(parse_hello(&hello), Some(MUX_VERSION));
            write_frame(&mut sock, &hello_frame_v(1)).unwrap();
            drop(sock);
            // Redial: v1 hello → ack v1, serve v1 echo frames.
            let (mut sock, _) = listener.accept().unwrap();
            let hello = read_frame(&mut sock).unwrap().unwrap();
            assert_eq!(parse_hello(&hello), Some(1), "redial must speak v1");
            write_frame(&mut sock, &hello_frame_v(1)).unwrap();
            let responder = MuxResponder::new(sock.try_clone().unwrap(), false);
            while let Ok(Some((corr, ctx, body))) = read_mux_frame(&mut sock, false, || true) {
                assert_eq!(ctx, TraceCtx::NONE);
                responder.send(corr, &crate::util::wire::Blob(body));
            }
        });
        let conn = MuxConn::connect(&addr.to_string()).unwrap();
        let sent = crate::util::wire::Blob::new(vec![9; 16]);
        let got: crate::util::wire::Blob = conn.call(&sent).unwrap();
        assert_eq!(got, sent);
        drop(conn);
        server.join().unwrap();
    }
}
