//! Seeded fault-injection plane: scripted chaos for the wire, storage and
//! cluster planes (the first slice of ROADMAP item 5).
//!
//! Production-in-the-large means surviving torn writes, dropped
//! connections and partitioned brokers *continuously*, not only in the
//! one kill+restart each suite can physically stage. This module gives
//! the test tree a way to script those failures deterministically:
//!
//! - **Seams.** Hot paths in `util::mux`, `broker::server`,
//!   `broker::storage`, `broker::cluster::client` and `dstream::server`
//!   ask [`check`] whether an injected fault applies to them. When the
//!   plane is disabled (always, outside fault tests) the seam is a
//!   single relaxed atomic load — see [`active`] — so production code
//!   pays nothing.
//! - **Rules.** A [`Rule`] arms one [`FaultAction`] at one site,
//!   optionally filtered by a context substring (e.g. a peer address),
//!   skipping the first `after(n)` hits and firing `times(n)` times.
//! - **Scenarios.** A [`Scenario`] is a scripted schedule ("at t=150 ms:
//!   kill broker 1", "drop the next frame to :9001", "corrupt the
//!   segment tail") executed by a timer thread, plus the installed rule
//!   set. Everything random — payload shapes, cut points, reorder
//!   shuffles — must come from the scenario's SplitMix64 [`Rng`] so a
//!   failing run is reproducible byte-for-byte from the single printed
//!   seed (`HYBRIDWS_FAULT_SEED=<n>`, see [`resolve_seed`]).
//! - **Invariants.** [`invariants`] holds the plane-agnostic checkers
//!   every scenario asserts afterwards: no acked record lost, per-group
//!   offsets monotone, recovered watermark covering the last commit,
//!   cluster meta converged.
//!
//! The plane is process-global (the seams are reached from server
//! threads that no test handle can parameterise), so fault tests must
//! serialise on a shared mutex and uninstall the plane before releasing
//! it — `rust/tests/fault_plane.rs` shows the pattern.

use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::rng::Rng;

/// Injection-site names, shared between the seams and the tests so a
/// typo cannot silently arm nothing.
pub mod site {
    /// Client-side mux connect (`MuxConn::connect`): refuse outright.
    pub const MUX_CONNECT: &str = "mux.connect";
    /// Client-side mux writer: drop / short-write / stall / reorder the
    /// outgoing frame batch. Context is the peer address.
    pub const MUX_WRITE: &str = "mux.write";
    /// Client-side mux reader: stall or drop before reading a frame.
    pub const MUX_READ: &str = "mux.read";
    /// Broker server accept path: drop the fresh connection on the
    /// floor (a server-side partition). Context is the peer address.
    pub const BROKER_CONN: &str = "broker.conn";
    /// DistroStream server accept path, same semantics.
    pub const DSTREAM_CONN: &str = "dstream.conn";
    /// Segment record append: fail / short-write / corrupt the frame.
    pub const SEG_APPEND: &str = "storage.segment.append";
    /// Segment seal (the fsync point): fail without syncing.
    pub const SEG_SEAL: &str = "storage.segment.seal";
    /// Log-start metadata write (`meta.bin`): fail the tmp+rename.
    pub const LOG_META: &str = "storage.log.meta";
    /// Consumer-offset journal append: fail the frame write.
    pub const OFFSETS_NOTE: &str = "storage.offsets.note";
    /// Cluster client's per-member connection factory: refuse, i.e. a
    /// scripted client↔member partition. Context is the member address.
    pub const CLUSTER_CONNECT: &str = "cluster.connect";
    /// Partition-migration state machine (PR 10): checked before every
    /// catch-up fetch and before the fence. `Stall` stretches the
    /// dual-accept window in place; anything else fails the step.
    /// Context is `topic[partition]@source`.
    pub const CLUSTER_MIGRATE: &str = "cluster.migrate";
}

/// What an armed [`Rule`] does when it fires. Sites implement the
/// subset that makes sense for them (documented per [`site`] constant);
/// an action a site does not understand is treated as its most
/// disruptive native one, so a scripted fault never silently no-ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Refuse a connection attempt (connect seams).
    Refuse,
    /// Drop the connection / frame on the floor.
    Drop,
    /// Stall the operation for the given milliseconds, then proceed.
    Stall(u64),
    /// Write only a prefix of the bytes, then fail (a torn write).
    ShortWrite,
    /// Flip a byte in the written frame, then fail (CRC-visible rot).
    Corrupt,
    /// Shuffle the outgoing frame batch with the plane's seeded RNG.
    Reorder,
    /// Fail the operation with [`injected_error`] without side effects.
    Fail,
}

/// One armed fault: `action` at `site`, optionally only for contexts
/// containing `matching`, skipping the first `after` qualifying hits
/// and firing on the next `times` of them.
#[derive(Debug, Clone)]
pub struct Rule {
    site: &'static str,
    matcher: Option<String>,
    action: FaultAction,
    skip: u32,
    remaining: u32,
}

impl Rule {
    /// A rule that fires once, on the first hit at `site`.
    pub fn new(site: &'static str, action: FaultAction) -> Self {
        Self { site, matcher: None, action, skip: 0, remaining: 1 }
    }

    /// Only fire when the seam's context contains `needle` (peer
    /// address, file path, …).
    pub fn matching(mut self, needle: impl Into<String>) -> Self {
        self.matcher = Some(needle.into());
        self
    }

    /// Fire on `n` qualifying hits instead of one.
    pub fn times(mut self, n: u32) -> Self {
        self.remaining = n;
        self
    }

    /// Let the first `n` qualifying hits pass unharmed.
    pub fn after(mut self, n: u32) -> Self {
        self.skip = n;
        self
    }
}

struct State {
    seed: u64,
    rng: Rng,
    rules: Vec<Rule>,
    log: Vec<String>,
    t0: Instant,
}

/// The zero-overhead gate: seams check this single relaxed load before
/// touching the mutex. False whenever no fault plane is installed,
/// i.e. always in production and in every non-fault test.
static ACTIVE: AtomicBool = AtomicBool::new(false);

static STATE: Mutex<Option<State>> = Mutex::new(None);

#[inline]
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

fn lock() -> std::sync::MutexGuard<'static, Option<State>> {
    // A panicking fault test must not wedge every later scenario.
    STATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Install the plane with `seed`. Replaces any leftover plane (a
/// previously panicked scenario) rather than compounding with it.
pub fn install(seed: u64) {
    let mut st = lock();
    *st = Some(State {
        seed,
        rng: Rng::new(seed),
        rules: Vec::new(),
        log: vec![format!("[+     0 ms] install seed={seed}")],
        t0: Instant::now(),
    });
    ACTIVE.store(true, Ordering::SeqCst);
}

/// Tear the plane down; returns the event log (empty when none was
/// installed). Every scenario must end here — a leaked plane would
/// bleed rules into unrelated tests.
pub fn uninstall() -> Vec<String> {
    let mut st = lock();
    ACTIVE.store(false, Ordering::SeqCst);
    st.take().map(|s| s.log).unwrap_or_default()
}

/// Arm `rule` on the installed plane (panics when none is installed —
/// that is a scripting bug, not a runtime condition).
pub fn inject(rule: Rule) {
    let mut st = lock();
    let state = st.as_mut().expect("fault::inject without fault::install");
    let elapsed = state.t0.elapsed().as_millis();
    state.log.push(format!(
        "[+{elapsed:>6} ms] arm {} {:?} match={:?} after={} times={}",
        rule.site, rule.action, rule.matcher, rule.skip, rule.remaining
    ));
    state.rules.push(rule);
}

/// Append a free-form line to the scenario log (timer events, test
/// milestones) so the uploaded artifact tells the whole story.
pub fn note(msg: &str) {
    let mut st = lock();
    if let Some(state) = st.as_mut() {
        let elapsed = state.t0.elapsed().as_millis();
        state.log.push(format!("[+{elapsed:>6} ms] {msg}"));
    }
}

/// The seam entry point: does an armed rule fire for `site` with this
/// `ctx`? Consumes the rule's skip/fire budget and logs the hit. Callers
/// must guard with [`active`] first; this slow path takes the mutex.
pub fn check(site: &str, ctx: &str) -> Option<FaultAction> {
    if !active() {
        return None;
    }
    let mut st = lock();
    let state = st.as_mut()?;
    let mut fired = None;
    for rule in state.rules.iter_mut() {
        if rule.remaining == 0 || rule.site != site {
            continue;
        }
        if let Some(m) = &rule.matcher {
            if !ctx.contains(m.as_str()) {
                continue;
            }
        }
        if rule.skip > 0 {
            rule.skip -= 1;
            continue;
        }
        rule.remaining -= 1;
        fired = Some(rule.action);
        break;
    }
    let action = fired?;
    // PR 8: fired decisions are also counted per seam in the observability
    // registry, so tests can assert "the fault plane fired here" without
    // parsing the scenario log.
    crate::util::obs::counter(&format!("fault.decisions{{{site}}}")).inc();
    crate::obs_counter!("fault.decisions").inc();
    let elapsed = state.t0.elapsed().as_millis();
    state.log.push(format!("[+{elapsed:>6} ms] fire {site} ({ctx}): {action:?}"));
    Some(action)
}

/// Seeded randomness for seams that need it (reorder shuffles). Falls
/// back to a fixed constant when no plane is installed so callers need
/// no special case.
pub fn next_u64() -> u64 {
    lock().as_mut().map(|s| s.rng.next_u64()).unwrap_or(0x9E37_79B9_7F4A_7C15)
}

/// The installed plane's seed, if any.
pub fn seed() -> Option<u64> {
    lock().as_ref().map(|s| s.seed)
}

/// Take the event log accumulated so far (the plane stays installed).
pub fn drain_log() -> Vec<String> {
    lock().as_mut().map(|s| std::mem::take(&mut s.log)).unwrap_or_default()
}

/// The error every failing seam returns: recognisable in assertions and
/// in degraded-storage logs.
pub fn injected_error(site: &str) -> io::Error {
    io::Error::other(format!("injected fault at {site}"))
}

/// Resolve the scenario seed: `HYBRIDWS_FAULT_SEED` wins, else
/// `default`. Tests print the resolved seed so any failure reproduces
/// with `HYBRIDWS_FAULT_SEED=<n> cargo test --test fault_plane`.
pub fn resolve_seed(default: u64) -> u64 {
    std::env::var("HYBRIDWS_FAULT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

// ---- scenario runner ----------------------------------------------------

/// What a scheduled event does when its time comes.
pub enum EventAction {
    /// Arm a rule on the running plane.
    Inject(Rule),
    /// Arbitrary chaos: kill a server, corrupt a file at rest, … Runs
    /// on the timer thread.
    Custom(Box<dyn FnOnce() + Send>),
}

struct ScheduledEvent {
    at: Duration,
    label: String,
    action: EventAction,
}

/// A scripted fault schedule. Build with [`Scenario::new`], add events
/// with [`Scenario::at`] / [`Scenario::at_do`], start with
/// [`Scenario::run`], and always call [`ScenarioHandle::finish`].
pub struct Scenario {
    name: String,
    seed: u64,
    events: Vec<ScheduledEvent>,
}

impl Scenario {
    pub fn new(name: impl Into<String>, seed: u64) -> Self {
        Self { name: name.into(), seed, events: Vec::new() }
    }

    /// At `ms` after start: arm `rule`.
    pub fn at(mut self, ms: u64, label: &str, rule: Rule) -> Self {
        self.events.push(ScheduledEvent {
            at: Duration::from_millis(ms),
            label: label.to_string(),
            action: EventAction::Inject(rule),
        });
        self
    }

    /// At `ms` after start: run `f` (kill/restart a server, corrupt a
    /// file at rest, partition repair, …).
    pub fn at_do(mut self, ms: u64, label: &str, f: impl FnOnce() + Send + 'static) -> Self {
        self.events.push(ScheduledEvent {
            at: Duration::from_millis(ms),
            label: label.to_string(),
            action: EventAction::Custom(Box::new(f)),
        });
        self
    }

    /// Install the plane (seeded) and start the timer thread that
    /// executes the schedule. The returned handle joins the timer and
    /// uninstalls the plane in [`ScenarioHandle::finish`].
    pub fn run(mut self) -> ScenarioHandle {
        install(self.seed);
        note(&format!("scenario '{}' starts ({} events)", self.name, self.events.len()));
        self.events.sort_by_key(|e| e.at);
        let events = std::mem::take(&mut self.events);
        let timer = std::thread::spawn(move || {
            let t0 = Instant::now();
            for ev in events {
                let now = t0.elapsed();
                if ev.at > now {
                    std::thread::sleep(ev.at - now);
                }
                note(&format!("event: {}", ev.label));
                match ev.action {
                    EventAction::Inject(rule) => inject(rule),
                    EventAction::Custom(f) => f(),
                }
            }
        });
        ScenarioHandle { name: self.name, seed: self.seed, timer: Some(timer) }
    }
}

/// Running scenario: join it with [`ScenarioHandle::finish`].
pub struct ScenarioHandle {
    name: String,
    seed: u64,
    timer: Option<std::thread::JoinHandle<()>>,
}

impl ScenarioHandle {
    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Wait for every scheduled event to have run, tear the plane down
    /// and return the full event log for assertion/archival.
    pub fn finish(mut self) -> Vec<String> {
        if let Some(t) = self.timer.take() {
            let _ = t.join();
        }
        note(&format!("scenario '{}' finished", self.name));
        uninstall()
    }
}

impl Drop for ScenarioHandle {
    fn drop(&mut self) {
        // A panicking test must still tear the global plane down.
        if let Some(t) = self.timer.take() {
            let _ = t.join();
            let _ = uninstall();
        }
    }
}

// ---- invariant checkers -------------------------------------------------

/// Plane-agnostic postcondition checkers over plain data, so the util
/// layer needs no broker types. Each returns `Err(description)` instead
/// of panicking: scenario tests attach the seed before asserting.
pub mod invariants {
    /// No acked record lost: every acked `(partition, offset)` must sit
    /// below that partition's high watermark.
    pub fn no_acked_lost(acked: &[(usize, u64)], watermarks: &[u64]) -> Result<(), String> {
        for &(p, off) in acked {
            let hw = watermarks
                .get(p)
                .ok_or_else(|| format!("acked partition {p} missing from watermarks"))?;
            if off >= *hw {
                return Err(format!("acked record ({p}, {off}) lost: watermark {hw}"));
            }
        }
        Ok(())
    }

    /// Offsets observed over time must never move backwards.
    pub fn monotone(xs: &[u64], what: &str) -> Result<(), String> {
        for w in xs.windows(2) {
            if w[1] < w[0] {
                return Err(format!("{what} went backwards: {} -> {}", w[0], w[1]));
            }
        }
        Ok(())
    }

    /// A recovered watermark must cover every commit for its partition
    /// (commits are `(partition, committed)` pairs).
    pub fn watermark_covers_commits(
        watermarks: &[u64],
        commits: &[(usize, u64)],
    ) -> Result<(), String> {
        for &(p, c) in commits {
            let hw = watermarks
                .get(p)
                .ok_or_else(|| format!("committed partition {p} missing from watermarks"))?;
            if c > *hw {
                return Err(format!("partition {p}: committed {c} past watermark {hw}"));
            }
        }
        Ok(())
    }

    /// Every member's `(epoch, sorted member list)` view must agree.
    pub fn meta_converged(views: &[(u64, Vec<String>)]) -> Result<(), String> {
        let Some(first) = views.first() else {
            return Ok(());
        };
        for (i, v) in views.iter().enumerate().skip(1) {
            if v != first {
                return Err(format!("cluster meta diverged: view 0 = {first:?}, view {i} = {v:?}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The plane is process-global; these unit tests serialise on their
    /// own gate and use sites no real seam reports, so concurrently
    /// running lib tests only ever see a no-match slow path.
    static GATE: Mutex<()> = Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_plane_is_inert() {
        let _g = locked();
        assert!(!active());
        assert_eq!(check("test.nowhere", "ctx"), None);
        assert_eq!(seed(), None);
        assert!(uninstall().is_empty());
    }

    #[test]
    fn rules_match_skip_and_exhaust() {
        let _g = locked();
        install(7);
        inject(Rule::new("test.a", FaultAction::Fail).after(1).times(2));
        inject(Rule::new("test.a", FaultAction::Drop).matching(":9001"));
        // First hit is skipped, next two fire, then the budget is gone.
        assert_eq!(check("test.a", "x"), None);
        assert_eq!(check("test.a", "x"), Some(FaultAction::Fail));
        assert_eq!(check("test.a", "x"), Some(FaultAction::Fail));
        // The matcher-gated rule only fires for its context.
        assert_eq!(check("test.a", "host:9002"), None);
        assert_eq!(check("test.a", "host:9001"), Some(FaultAction::Drop));
        assert_eq!(check("test.a", "host:9001"), None);
        // Other sites never fire.
        assert_eq!(check("test.b", "x"), None);
        let log = uninstall();
        assert!(log.iter().any(|l| l.contains("fire test.a")), "{log:?}");
        assert!(!active());
    }

    #[test]
    fn same_seed_same_random_stream() {
        let _g = locked();
        install(42);
        let a: Vec<u64> = (0..4).map(|_| next_u64()).collect();
        uninstall();
        install(42);
        let b: Vec<u64> = (0..4).map(|_| next_u64()).collect();
        uninstall();
        assert_eq!(a, b, "fault randomness must be a pure function of the seed");
    }

    #[test]
    fn scenario_runs_events_in_order_and_cleans_up() {
        let _g = locked();
        let hits = std::sync::Arc::new(Mutex::new(Vec::new()));
        let (h1, h2) = (hits.clone(), hits.clone());
        let handle = Scenario::new("unit", 3)
            .at(5, "arm a fail", Rule::new("test.sc", FaultAction::Fail))
            .at_do(1, "first", move || h1.lock().unwrap().push("first"))
            .at_do(10, "second", move || h2.lock().unwrap().push("second"))
            .run();
        assert_eq!(handle.seed(), 3);
        let log = handle.finish();
        assert_eq!(*hits.lock().unwrap(), vec!["first", "second"]);
        assert!(!active(), "finish must uninstall the plane");
        let armed = log.iter().any(|l| l.contains("arm test.sc"));
        assert!(armed, "scheduled Inject must arm its rule: {log:?}");
        assert!(log.first().unwrap().contains("seed=3"));
    }

    #[test]
    fn invariant_checkers_accept_good_and_reject_bad() {
        use invariants::*;
        assert!(no_acked_lost(&[(0, 4), (1, 0)], &[5, 1]).is_ok());
        assert!(no_acked_lost(&[(0, 5)], &[5]).is_err());
        assert!(no_acked_lost(&[(2, 0)], &[5]).is_err());
        assert!(monotone(&[1, 1, 2, 9], "pos").is_ok());
        assert!(monotone(&[3, 2], "pos").is_err());
        assert!(watermark_covers_commits(&[10, 3], &[(0, 10), (1, 3)]).is_ok());
        assert!(watermark_covers_commits(&[10, 3], &[(1, 4)]).is_err());
        let a = (1u64, vec!["a:1".to_string(), "b:2".to_string()]);
        assert!(meta_converged(&[a.clone(), a.clone()]).is_ok());
        assert!(meta_converged(&[a.clone(), (2u64, a.1.clone())]).is_err());
        assert!(meta_converged(&[]).is_ok());
    }

    #[test]
    fn injected_error_names_its_site() {
        let e = injected_error(site::SEG_APPEND);
        assert!(e.to_string().contains("storage.segment.append"));
    }

    #[test]
    fn env_seed_overrides_default() {
        // Avoid touching the real env (parallel tests): exercise the
        // parse path only when the variable is absent.
        if std::env::var("HYBRIDWS_FAULT_SEED").is_err() {
            assert_eq!(resolve_seed(99), 99);
        }
    }
}
