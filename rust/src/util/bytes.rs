//! Growable byte writer and cursor reader — primitives under the wire codec.
//!
//! All multi-byte integers are little-endian. Errors are reported through
//! [`DecodeError`] so corrupt frames never panic the runtime.

use std::fmt;
use std::ops::Deref;
use std::sync::Arc;

/// Error produced when decoding runs past the buffer or finds bad data.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// Needed `needed` more bytes at `at` but the buffer ended.
    Eof { at: usize, needed: usize },
    /// A tag/discriminant byte had no known mapping.
    BadTag { at: usize, tag: u32, ty: &'static str },
    /// A length prefix exceeded the sanity limit.
    TooLong { at: usize, len: u64 },
    /// String bytes were not valid UTF-8.
    BadUtf8 { at: usize },
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecodeError::Eof { at, needed } => {
                write!(f, "unexpected EOF at byte {at} (needed {needed} more)")
            }
            DecodeError::BadTag { at, tag, ty } => {
                write!(f, "bad tag {tag} for {ty} at byte {at}")
            }
            DecodeError::TooLong { at, len } => {
                write!(f, "length {len} at byte {at} exceeds sanity limit")
            }
            DecodeError::BadUtf8 { at } => write!(f, "invalid UTF-8 at byte {at}"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Sanity cap for decoded collection/string/byte lengths (1 GiB).
pub const MAX_LEN: u64 = 1 << 30;

/// Immutable byte buffer that is **O(1) to clone** (`Arc`-backed).
///
/// The streaming hot path stores every payload exactly once: a producer's
/// `Vec<u8>` is wrapped (not copied) at construction, the partition log,
/// every consumer-group fetch and the typed decode on the embedded backend
/// all share the same allocation. Dereferences to `[u8]`, so slice methods
/// and indexing work directly.
#[derive(Clone, Default)]
pub struct SharedBytes(Arc<Vec<u8>>);

impl SharedBytes {
    /// Wrap a buffer without copying it.
    pub fn new(bytes: Vec<u8>) -> Self {
        Self(Arc::new(bytes))
    }

    /// Share an existing `Arc` allocation (zero-copy hand-off from stores
    /// that already keep `Arc<Vec<u8>>`, e.g. the worker data registry).
    pub fn from_arc(bytes: Arc<Vec<u8>>) -> Self {
        Self(bytes)
    }

    /// Borrow the underlying `Arc` (for stores that keep `Arc<Vec<u8>>`).
    pub fn as_arc(&self) -> &Arc<Vec<u8>> {
        &self.0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.0
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// True when both handles share one allocation — the zero-copy
    /// property the embedded data plane is tested against.
    pub fn ptr_eq(&self, other: &SharedBytes) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }
}

impl Deref for SharedBytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.0
    }
}

impl From<Vec<u8>> for SharedBytes {
    fn from(v: Vec<u8>) -> Self {
        Self::new(v)
    }
}

impl From<&[u8]> for SharedBytes {
    fn from(v: &[u8]) -> Self {
        Self::new(v.to_vec())
    }
}

impl PartialEq for SharedBytes {
    fn eq(&self, other: &Self) -> bool {
        // Content equality (identity is `ptr_eq`); skip the compare when
        // both handles share one allocation.
        self.ptr_eq(other) || self.as_slice() == other.as_slice()
    }
}

impl Eq for SharedBytes {}

impl PartialOrd for SharedBytes {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for SharedBytes {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.as_slice().cmp(other.as_slice())
    }
}

impl std::hash::Hash for SharedBytes {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.as_slice().hash(state);
    }
}

impl fmt::Debug for SharedBytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self.as_slice(), f)
    }
}

/// Append-only byte buffer with fixed-width little-endian put methods.
#[derive(Default, Debug, Clone)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// New empty writer.
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    /// New writer with reserved capacity (hot-path friendliness).
    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap) }
    }

    /// Finish and take the underlying buffer.
    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    /// Drop everything written so far but keep the allocation — lets hot
    /// paths (batched stream encodes) reuse one writer across records.
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True if nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Borrow the bytes written so far.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Raw bytes, no length prefix.
    pub fn put_raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Length-prefixed (u32) byte blob.
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        debug_assert!(bytes.len() as u64 <= MAX_LEN);
        self.put_u32(bytes.len() as u32);
        self.buf.extend_from_slice(bytes);
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }
}

/// Cursor over a byte slice with fixed-width little-endian take methods.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// New reader over the whole slice.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Current cursor position.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True when the cursor consumed the whole buffer.
    pub fn is_exhausted(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.remaining() < n {
            return Err(DecodeError::Eof { at: self.pos, needed: n - self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_bool(&mut self) -> Result<bool, DecodeError> {
        Ok(self.get_u8()? != 0)
    }

    pub fn get_u16(&mut self) -> Result<u16, DecodeError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_i64(&mut self) -> Result<i64, DecodeError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> Result<f32, DecodeError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64, DecodeError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Length-prefixed (u32) byte blob; borrows from the underlying slice.
    pub fn get_bytes(&mut self) -> Result<&'a [u8], DecodeError> {
        let at = self.pos;
        let len = self.get_u32()? as u64;
        if len > MAX_LEN {
            return Err(DecodeError::TooLong { at, len });
        }
        self.take(len as usize)
    }

    /// Length-prefixed UTF-8 string.
    pub fn get_str(&mut self) -> Result<String, DecodeError> {
        let at = self.pos;
        let bytes = self.get_bytes()?;
        String::from_utf8(bytes.to_vec()).map_err(|_| DecodeError::BadUtf8 { at })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_primitives() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_bool(true);
        w.put_u16(0xBEEF);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 3);
        w.put_i64(-42);
        w.put_f32(1.5);
        w.put_f64(-2.25);
        w.put_str("héllo");
        w.put_bytes(&[1, 2, 3]);

        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_u16().unwrap(), 0xBEEF);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.get_i64().unwrap(), -42);
        assert_eq!(r.get_f32().unwrap(), 1.5);
        assert_eq!(r.get_f64().unwrap(), -2.25);
        assert_eq!(r.get_str().unwrap(), "héllo");
        assert_eq!(r.get_bytes().unwrap(), &[1, 2, 3]);
        assert!(r.is_exhausted());
    }

    #[test]
    fn eof_reports_position() {
        let mut r = ByteReader::new(&[1, 2]);
        assert_eq!(r.get_u8().unwrap(), 1);
        match r.get_u32() {
            Err(DecodeError::Eof { at, needed }) => {
                assert_eq!(at, 1);
                assert_eq!(needed, 3);
            }
            other => panic!("expected Eof, got {other:?}"),
        }
    }

    #[test]
    fn bad_utf8_is_error_not_panic() {
        let mut w = ByteWriter::new();
        w.put_bytes(&[0xFF, 0xFE]);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert!(matches!(r.get_str(), Err(DecodeError::BadUtf8 { at: 0 })));
    }

    #[test]
    fn oversized_length_rejected() {
        let mut w = ByteWriter::new();
        w.put_u32(u32::MAX); // fake huge length prefix
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert!(matches!(r.get_bytes(), Err(DecodeError::TooLong { .. })));
    }

    #[test]
    fn shared_bytes_clone_is_zero_copy() {
        let a = SharedBytes::new(vec![1, 2, 3]);
        let b = a.clone();
        assert!(a.ptr_eq(&b), "clone must share the allocation");
        assert_eq!(a, b);
        // A content-equal but separately-allocated buffer is == but not
        // pointer-identical.
        let c = SharedBytes::new(vec![1, 2, 3]);
        assert_eq!(a, c);
        assert!(!a.ptr_eq(&c));
    }

    #[test]
    fn shared_bytes_derefs_to_slice() {
        let a = SharedBytes::new(vec![9, 8, 7]);
        assert_eq!(a[0], 9);
        assert_eq!(a.len(), 3);
        assert_eq!(a.iter().copied().max(), Some(9));
        assert_eq!(&a[1..], &[8, 7]);
        assert!(SharedBytes::default().is_empty());
    }

    #[test]
    fn shared_bytes_orders_by_content() {
        let a = SharedBytes::new(vec![1]);
        let b = SharedBytes::new(vec![2]);
        assert!(a < b);
        assert_eq!(a.cmp(&a.clone()), std::cmp::Ordering::Equal);
    }
}
